//! Properties of the deadline-aware bounded decode queue and the
//! deadline-bounded waits the serving layer is built from — all pinned
//! deterministically under virtual clocks:
//!
//! * **FIFO**: permits are granted strictly in enqueue order;
//! * **typed rejection**: a full queue rejects immediately with
//!   `QueueFull`, an expired deadline with `DeadlineExceeded` — at the
//!   queue level and through [`ArtifactServer`] with exact `waited_ms`;
//! * **no permit leak**: a waiter whose deadline expires removes its
//!   ticket, and the permit it never got grants again afterwards;
//! * **no orphaned waiters**: an owner that panics between registering
//!   its single-flight slot and filling it wakes every waiter with a
//!   typed error (the [`FillGuard`]/`OwnerGuard` drop path);
//! * **watchdog + breaker**: repeated slow decodes (manufactured from
//!   retry backoffs on a [`RecordingClock`], whose `sleep` advances
//!   virtual time) open a per-tensor circuit breaker; cold requests
//!   shed typed while cached copies keep serving; after the cooldown a
//!   half-open probe closes (fast) or re-opens (slow) the breaker.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use owf::artifact::queue::{
    AcquireError, DecodeQueue, FillGuard, Slot, WaitOutcome,
};
use owf::artifact::retry::{GateClock, RecordingClock, RetryPolicy};
use owf::artifact::server::ArtifactServer;
use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
use owf::artifact::{Artifact, ArtifactError, Clock, Codec, Deadline};
use owf::tensorstore::{Store, Tensor};
use owf::util::faultfs::{ByteSource, FaultFs};
use owf::util::json::Json;
use owf::util::rng::Rng;

/// Pack a three-tensor container and return its bytes.
fn packed_bytes(tag: &str) -> Vec<u8> {
    let mut rng = Rng::new(0xDECAF);
    let mut store = Store::new(Json::obj().push("kind", "queue-props"));
    for (name, n) in [("a", 3072usize), ("b", 4096), ("c", 2048)] {
        let data = rng.student_t_vec(5.0, n);
        store.push(Tensor::from_f32(name, vec![n], &data));
    }
    let dir = std::env::temp_dir().join("owf_queue_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}_{}.owq", std::process::id()));
    pack_store(
        &store,
        &std::collections::HashMap::new(),
        &PackOptions {
            spec: "cbrt-t5@4:block64-absmax:compress".to_string(),
            alloc: AllocMode::Flat,
            codec: Codec::Huffman,
            lanes: 4,
            target_bits: None,
            meta: Json::obj().push("source", "test"),
        },
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    raw
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..5000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("timed out waiting for: {what}");
}

/// A server over a faulted container: `budget` transient read faults
/// aimed at tensor `a`'s payload, so decodes of `a` park in retry
/// backoffs (GateClock) or consume virtual backoff time (RecordingClock).
fn faulted_server(
    raw: &[u8],
    budget: u64,
    clock: Arc<dyn Clock>,
    cap_bytes: usize,
) -> ArtifactServer {
    let clean = Artifact::from_bytes(raw.to_vec()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("a", "payload").unwrap();
    let fs = FaultFs::new(raw.to_vec())
        .with_transient_at(p_off + p_len / 2, budget);
    let art = Artifact::from_source_with(
        ByteSource::Fault(fs),
        RetryPolicy::default(),
        clock,
    )
    .unwrap();
    ArtifactServer::new(art, cap_bytes)
}

// ---------------------------------------------------------------- queue

#[test]
fn permits_grant_in_strict_fifo_order() {
    let q = Arc::new(DecodeQueue::new(
        1,
        8,
        Arc::new(RecordingClock::new()),
    ));
    let holder = q.acquire(None).unwrap();
    let order = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..4 {
            let q = q.clone();
            let order = order.clone();
            handles.push(scope.spawn(move || {
                let p = q.acquire(None).unwrap();
                assert!(p.waited, "late arrival must have waited");
                order.lock().unwrap().push(i);
                drop(p);
            }));
            // enqueue one at a time so arrival order is pinned
            wait_until("waiter enqueued", || {
                q.waiting() == i + 1
            });
        }
        drop(holder);
        for h in handles {
            h.join().unwrap();
        }
    });
    assert_eq!(
        *order.lock().unwrap(),
        vec![0, 1, 2, 3],
        "grants must follow enqueue order"
    );
    assert_eq!(q.waiting(), 0);
    assert_eq!(q.active(), 0);
}

#[test]
fn full_queue_rejects_typed_without_blocking() {
    let q = Arc::new(DecodeQueue::new(
        1,
        2,
        Arc::new(RecordingClock::new()),
    ));
    let holder = q.acquire(None).unwrap();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            handles.push(
                scope.spawn(move || drop(q.acquire(None).unwrap())),
            );
        }
        wait_until("two waiters parked", || q.waiting() == 2);
        // the third would-be waiter is rejected immediately, typed
        assert_eq!(
            q.acquire(None).unwrap_err(),
            AcquireError::QueueFull { depth: 2 }
        );
        drop(holder);
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn expired_waiter_removes_its_ticket_and_leaks_no_permit() {
    let clock = Arc::new(RecordingClock::new());
    let q = Arc::new(DecodeQueue::new(1, 4, clock.clone()));
    let holder = q.acquire(None).unwrap();
    let deadline = Deadline::at(Duration::from_millis(10));
    std::thread::scope(|scope| {
        let waiter = {
            let q = q.clone();
            scope.spawn(move || q.acquire(Some(deadline)).unwrap_err())
        };
        wait_until("waiter parked in FIFO", || q.waiting() == 1);
        clock.advance(Duration::from_millis(15));
        match waiter.join().unwrap() {
            AcquireError::DeadlineExceeded { waited } => {
                assert_eq!(
                    waited,
                    Duration::from_millis(15),
                    "waited exactly the virtual time that passed"
                );
            }
            other => panic!("expected deadline, got {other:?}"),
        }
    });
    assert_eq!(q.waiting(), 0, "expired ticket removed from the FIFO");
    drop(holder);
    // the permit the expired waiter never got is still grantable
    let p = q.acquire(None).unwrap();
    assert!(!p.waited);
    assert_eq!(q.active(), 1);
}

#[test]
fn panicked_owner_wakes_every_waiter_typed() {
    let slot: Arc<Slot<u32>> = Arc::new(Slot::new());
    let clock = RecordingClock::new();
    std::thread::scope(|scope| {
        let waiters: Vec<_> = (0..3)
            .map(|_| {
                let slot = slot.clone();
                scope.spawn(move || {
                    let c = RecordingClock::new();
                    slot.wait_deadline(&c, None)
                })
            })
            .collect();
        let owner = {
            let slot = slot.clone();
            scope.spawn(move || {
                let _guard = FillGuard::new(
                    &slot,
                    ArtifactError::corrupt(
                        "t", "decode", "owner unwound",
                    ),
                );
                panic!("owner dies before filling");
            })
        };
        assert!(owner.join().is_err());
        for w in waiters {
            match w.join().unwrap() {
                WaitOutcome::Filled(Err(e)) => {
                    assert!(e.is_corrupt(), "{e}")
                }
                other => {
                    panic!("expected typed wake-up, got {other:?}")
                }
            }
        }
    });
    assert!(matches!(
        slot.wait_deadline(&clock, None),
        WaitOutcome::Filled(Err(_))
    ));
}

// ----------------------------------------------- server: queue/deadline

#[test]
fn server_deadline_expires_in_queue_with_exact_wait() {
    let raw = packed_bytes("dlq");
    let gate = Arc::new(GateClock::new());
    let server = faulted_server(&raw, 1, gate.clone(), 1 << 30)
        .with_max_decodes(1)
        .with_queue_depth(4);
    std::thread::scope(|scope| {
        let owner = scope.spawn(|| server.get("a"));
        wait_until("owner parked in backoff", || gate.waiting() == 1);
        // owner holds the only permit; this request must queue, then
        // expire exactly when virtual time reaches its deadline
        let waiter = scope.spawn(|| {
            server.get_deadline(
                "b",
                Some(Deadline::at(Duration::from_millis(30))),
            )
        });
        wait_until("waiter parked in FIFO", || {
            server.decode_queue().waiting() == 1
        });
        gate.advance(Duration::from_millis(30));
        match waiter.join().unwrap().unwrap_err() {
            ArtifactError::DeadlineExceeded { tensor, waited_ms } => {
                assert_eq!(tensor, "b");
                assert_eq!(
                    waited_ms, 30,
                    "waited exactly the advanced virtual time"
                );
            }
            other => panic!("expected deadline, got {other}"),
        }
        assert_eq!(
            server.decode_queue().waiting(),
            0,
            "expired ticket left the FIFO"
        );
        gate.open();
        assert!(owner.join().unwrap().is_ok());
    });
    // the permit was never leaked: a fresh request decodes
    assert!(server.get("b").is_ok());
    let s = server.stats();
    assert_eq!(s.deadline_exceeded_queued, 1);
    assert_eq!(s.deadline_exceeded_waiting, 0);
    assert_eq!(s.misses, 2, "owner's a + the fresh b");
    assert!(s.partition_closed(), "{s:?}");
}

#[test]
fn server_deadline_expires_waiting_on_coalesced_decode() {
    let raw = packed_bytes("dlw");
    let gate = Arc::new(GateClock::new());
    let server = faulted_server(&raw, 1, gate.clone(), 1 << 30)
        .with_max_decodes(1)
        .with_queue_depth(4);
    std::thread::scope(|scope| {
        let owner = scope.spawn(|| server.get("a"));
        wait_until("owner parked in backoff", || gate.waiting() == 1);
        // same tensor: attaches to the owner's slot, no queue ticket
        let waiter = scope.spawn(|| {
            server.get_deadline(
                "a",
                Some(Deadline::at(Duration::from_millis(20))),
            )
        });
        wait_until("waiter attached", || server.stats().coalesced == 1);
        assert_eq!(server.decode_queue().waiting(), 0);
        gate.advance(Duration::from_millis(20));
        match waiter.join().unwrap().unwrap_err() {
            ArtifactError::DeadlineExceeded { tensor, waited_ms } => {
                assert_eq!(tensor, "a");
                assert_eq!(waited_ms, 20);
            }
            other => panic!("expected deadline, got {other}"),
        }
        // the owner is untouched by its waiter's deadline
        gate.open();
        assert!(owner.join().unwrap().is_ok());
    });
    let s = server.stats();
    assert_eq!(s.deadline_exceeded_waiting, 1);
    assert_eq!(s.deadline_exceeded_queued, 0);
    assert_eq!(s.coalesced, 1);
    assert_eq!(s.misses, 1, "one decode despite the expired waiter");
    assert!(s.partition_closed(), "{s:?}");
}

#[test]
fn server_queue_admits_fifo_and_overflow_rejects_typed() {
    let raw = packed_bytes("sq");
    let gate = Arc::new(GateClock::new());
    let server = faulted_server(&raw, 1, gate.clone(), 1 << 30)
        .with_max_decodes(1)
        .with_queue_depth(1);
    std::thread::scope(|scope| {
        let owner = scope.spawn(|| server.get("a"));
        wait_until("owner parked in backoff", || gate.waiting() == 1);
        let queued = scope.spawn(|| server.get("b"));
        wait_until("first waiter queued", || {
            server.decode_queue().waiting() == 1
        });
        // depth 1 is occupied: the next cold request rejects typed
        match server.get("c").unwrap_err() {
            ArtifactError::QueueFull { depth } => assert_eq!(depth, 1),
            other => panic!("expected queue-full, got {other}"),
        }
        gate.open();
        assert!(owner.join().unwrap().is_ok());
        assert!(queued.join().unwrap().is_ok());
    });
    let s = server.stats();
    assert_eq!(s.queue_full, 1);
    assert_eq!(s.queued, 1, "the queued request was granted after all");
    assert_eq!(s.overloads, 0, "queueing replaces the legacy shed gate");
    assert_eq!(s.misses, 2);
    assert!(s.partition_closed(), "{s:?}");
}

// -------------------------------------------- server: watchdog/breaker

#[test]
fn breaker_opens_after_repeated_slow_decodes_and_probe_recovers() {
    let raw = packed_bytes("brk");
    let clock = Arc::new(RecordingClock::new());
    // six transient faults on a's payload: each of the first three
    // decodes retries twice (5 + 10 ms virtual backoff), putting three
    // consecutive decodes over the 1 ms budget
    let server = faulted_server(&raw, 6, clock.clone(), 0)
        .with_slow_budget(Duration::from_millis(1))
        .with_breaker(3, Duration::from_millis(250));
    for strike in 1..=3u64 {
        assert!(server.get("a").is_ok(), "slow but successful");
        assert_eq!(server.stats().slow_decodes, strike);
    }
    // third strike opened the breaker: cold requests shed typed
    match server.get("a").unwrap_err() {
        ArtifactError::BreakerOpen { tensor } => assert_eq!(tensor, "a"),
        other => panic!("expected breaker, got {other}"),
    }
    let s = server.stats();
    assert_eq!(s.breaker_open, 1);
    assert_eq!(s.breakers_open, 1);
    assert_eq!(s.io_retries, 6, "two injected retries per slow decode");
    // other tensors are untouched by a's breaker
    assert!(server.get("b").is_ok());
    // after the cooldown one probe is admitted; transients are spent,
    // so it is fast and closes the breaker
    clock.advance(Duration::from_millis(250));
    assert!(server.get("a").is_ok(), "half-open probe");
    let s = server.stats();
    assert_eq!(s.breaker_probes, 1);
    assert_eq!(s.breakers_open, 0, "fast probe closed the breaker");
    assert_eq!(s.slow_decodes, 3, "probe was not slow");
    assert!(server.get("a").is_ok(), "closed: serving normally again");
    assert!(server.stats().partition_closed());
}

#[test]
fn open_breaker_serves_cached_copies_and_slow_probe_reopens() {
    let raw = packed_bytes("brk2");
    let clock = Arc::new(RecordingClock::new());
    // budget 6: strike 1 (get, cached), strike 2 (decode_into) open the
    // breaker at threshold 2; the remaining 2 faults make the first
    // half-open probe slow again, re-opening it
    let server = faulted_server(&raw, 6, clock.clone(), 1 << 30)
        .with_slow_budget(Duration::from_millis(1))
        .with_breaker(2, Duration::from_millis(100));
    let n = server.get("a").unwrap().len();
    let mut buf = vec![0f32; n];
    server.decode_into("a", &mut buf).unwrap();
    assert_eq!(server.stats().slow_decodes, 2);
    assert_eq!(server.stats().breakers_open, 1, "threshold 2 tripped");
    // graceful degradation: the cached copy keeps serving while the
    // breaker sheds cold decodes — the same contract as quarantine
    assert!(server.get("a").is_ok(), "cache hit bypasses the breaker");
    assert!(matches!(
        server.decode_into("a", &mut buf).unwrap_err(),
        ArtifactError::BreakerOpen { .. }
    ));
    clock.advance(Duration::from_millis(100));
    // slow probe (2 faults left → 5 + 10 ms virtual) re-opens
    server.decode_into("a", &mut buf).unwrap();
    let s = server.stats();
    assert_eq!(s.breaker_probes, 1);
    assert_eq!(s.slow_decodes, 3);
    assert_eq!(s.breakers_open, 1, "slow probe re-opened the breaker");
    assert!(matches!(
        server.decode_into("a", &mut buf).unwrap_err(),
        ArtifactError::BreakerOpen { .. }
    ));
    // second cooldown: faults exhausted, the probe is fast and closes
    clock.advance(Duration::from_millis(100));
    server.decode_into("a", &mut buf).unwrap();
    let s = server.stats();
    assert_eq!(s.breaker_probes, 2);
    assert_eq!(s.breakers_open, 0);
    assert_eq!(s.breaker_open, 2, "two typed sheds along the way");
    assert!(s.partition_closed(), "{s:?}");
}
