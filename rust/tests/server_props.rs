//! Concurrency properties of the fault-tolerant [`ArtifactServer`]:
//!
//! * **single-flight**: N threads cold-missing one tensor perform exactly
//!   one decode (`misses == 1`, `decoded_bytes == 4·n`), and every waiter
//!   shares the *same* `Arc` as the owner;
//! * waiters attached to a failing decode inherit the owner's error
//!   verbatim; the tensor is quarantined with its cause and subsequent
//!   requests fail fast while clean (and cached) tensors keep serving;
//! * the admission gate sheds load with a typed `Overloaded` while a
//!   decode is parked in a retry backoff (pinned deterministically with
//!   [`GateClock`] — a blocked retry holds its decode permit), and
//!   same-tensor requests still coalesce instead of being shed;
//! * stats invariants hold under a concurrent request storm:
//!   `hits + misses == requests` fault-free, byte accounting exact
//!   against [`ArtifactServer::cache_audit`] across racing insert/evict,
//!   and `cap_bytes == 0` disables caching without breaking coalescing;
//! * `params()` routes through the serving path (a quarantined tensor
//!   fails the bulk decode typed), the LRU stamp clock advances only on
//!   cache hits/inserts, and `decode_into` rides the same queue/deadline
//!   admission as `get` (see `tests/queue_props.rs` for the queue,
//!   deadline and circuit-breaker state-machine properties).

use std::sync::{Arc, Barrier};
use std::time::Duration;

use owf::artifact::retry::{GateClock, RetryPolicy};
use owf::artifact::server::ArtifactServer;
use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
use owf::artifact::{Artifact, ArtifactError, Codec, Deadline};
use owf::tensorstore::{Store, Tensor};
use owf::util::faultfs::{ByteSource, FaultFs};
use owf::util::json::Json;
use owf::util::rng::Rng;

/// Pack a three-tensor container and return its bytes.
fn packed_bytes(tag: &str) -> Vec<u8> {
    let mut rng = Rng::new(0x5E17E5);
    let mut store = Store::new(Json::obj().push("kind", "server-props"));
    for (name, n) in [("a", 3072usize), ("b", 4096), ("c", 2048)] {
        let data = rng.student_t_vec(5.0, n);
        store.push(Tensor::from_f32(name, vec![n], &data));
    }
    let dir = std::env::temp_dir().join("owf_server_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path =
        dir.join(format!("{tag}_{}.owq", std::process::id()));
    pack_store(
        &store,
        &std::collections::HashMap::new(),
        &PackOptions {
            spec: "cbrt-t5@4:block64-absmax:compress".to_string(),
            alloc: AllocMode::Flat,
            codec: Codec::Huffman,
            lanes: 4,
            target_bits: None,
            meta: Json::obj().push("source", "test"),
        },
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    raw
}

fn clean_decodes(raw: &[u8]) -> Vec<(String, Vec<f32>)> {
    let art = Artifact::from_bytes(raw.to_vec()).unwrap();
    art.tensors
        .iter()
        .enumerate()
        .map(|(i, r)| (r.name.clone(), art.decode_tensor(i).unwrap()))
        .collect()
}

fn assert_bit_exact(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}");
    }
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..5000 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("timed out waiting for: {what}");
}

/// The headline regression: N threads cold-missing the same tensor must
/// coalesce onto exactly one decode.
#[test]
fn n_concurrent_cold_misses_perform_exactly_one_decode() {
    let raw = packed_bytes("coalesce");
    let expected = clean_decodes(&raw);
    let want_a = &expected[0].1;
    let server = ArtifactServer::new(
        Artifact::from_bytes(raw.clone()).unwrap(),
        1 << 30,
    );
    let n = 8;
    let barrier = Barrier::new(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let server = &server;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    server.get("a").unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_bit_exact(&h.join().unwrap(), want_a, "a");
        }
    });
    let s = server.stats();
    assert_eq!(s.requests, n as u64);
    assert_eq!(
        s.misses, 1,
        "N concurrent cold misses must decode exactly once"
    );
    assert_eq!(s.hits, (n - 1) as u64);
    assert_eq!(
        s.decoded_bytes,
        4 * want_a.len() as u64,
        "decoded_bytes proves a single decode"
    );
    assert!(s.coalesced <= (n - 1) as u64);
    assert_eq!(s.hits + s.misses, s.requests);
    assert_eq!(s.cached_tensors, 1);
    // a later request is a plain cache hit
    assert_bit_exact(&server.get("a").unwrap(), want_a, "warm");
    assert_eq!(server.stats().hits, n as u64);
}

#[test]
fn waiters_inherit_owner_error_and_tensor_quarantines() {
    let raw = packed_bytes("quarantine");
    let expected = clean_decodes(&raw);
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("a", "payload").unwrap();
    let mut damaged = raw.clone();
    damaged[p_off + p_len / 2] ^= 0x10;
    let server = ArtifactServer::new(
        Artifact::from_bytes(damaged).unwrap(),
        1 << 30,
    );
    // warm the clean tensor so graceful degradation is observable below
    let want_b = &expected[1].1;
    assert_bit_exact(&server.get("b").unwrap(), want_b, "b cold");

    let n = 6;
    let barrier = Barrier::new(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let server = &server;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    server.get("a")
                })
            })
            .collect();
        for h in handles {
            let err = h.join().unwrap().unwrap_err();
            assert!(
                matches!(
                    err.kind_name(),
                    "corrupt" | "quarantined"
                ),
                "{err}"
            );
        }
    });
    let s = server.stats();
    assert_eq!(s.misses, 2, "one decode of b, one failed decode of a");
    assert_eq!(s.decode_errors, 1);
    assert_eq!(
        s.coalesced_errors + s.quarantine_hits,
        (n - 1) as u64,
        "every other requester inherited or fast-failed"
    );
    assert_eq!(s.quarantined, 1);

    // fast-fail path carries the original cause
    match server.get("a").unwrap_err() {
        ArtifactError::Quarantined { tensor, cause } => {
            assert_eq!(tensor, "a");
            assert!(cause.is_corrupt(), "{cause}");
        }
        other => panic!("expected quarantine, got {other}"),
    }
    assert_eq!(
        server.stats().misses,
        2,
        "quarantined tensor must not be re-decoded"
    );
    // clean tensors — cached or cold — keep serving
    assert_bit_exact(&server.get("b").unwrap(), want_b, "b warm");
    assert_bit_exact(
        &server.get("c").unwrap(),
        &expected[2].1,
        "c cold",
    );

    // ops path: lifting the quarantine re-attempts (and re-poisons)
    let cause = server.clear_quarantine("a").expect("was quarantined");
    assert!(cause.is_corrupt());
    assert!(server.get("a").unwrap_err().is_corrupt());
    let s = server.stats();
    assert_eq!(s.misses, 4, "re-decode after clear (plus c)");
    assert_eq!(s.quarantined, 1, "re-poisoned");
}

/// Deterministic admission-gate pinning: a decode parked in a retry
/// backoff (via [`GateClock`]) holds its permit, so a different-tensor
/// request is shed with `Overloaded` while a same-tensor request
/// coalesces and shares the owner's buffer.
#[test]
fn admission_gate_sheds_while_same_tensor_requests_coalesce() {
    let raw = packed_bytes("gate");
    let expected = clean_decodes(&raw);
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("a", "payload").unwrap();
    // one transient fault aimed at a's payload: open-time reads are
    // untouched, the first decode of a parks in a backoff sleep
    let fs = FaultFs::new(raw.clone())
        .with_transient_at(p_off + p_len / 2, 1);
    let gate = Arc::new(GateClock::new());
    let art = Artifact::from_source_with(
        ByteSource::Fault(fs),
        RetryPolicy::default(),
        gate.clone(),
    )
    .unwrap();
    let server = ArtifactServer::new(art, 1 << 30).with_max_decodes(1);

    std::thread::scope(|scope| {
        let owner = scope.spawn(|| server.get("a"));
        wait_until("owner parked in backoff", || gate.waiting() == 1);
        // the parked decode holds the only permit
        match server.get("b").unwrap_err() {
            ArtifactError::Overloaded { limit } => assert_eq!(limit, 1),
            other => panic!("expected overload, got {other}"),
        }
        // ...but a request for the same tensor attaches, not sheds
        let waiter = scope.spawn(|| server.get("a"));
        wait_until("waiter attached", || server.stats().coalesced == 1);
        assert_eq!(server.stats().overloads, 1);
        gate.open();
        let got_owner = owner.join().unwrap().unwrap();
        let got_waiter = waiter.join().unwrap().unwrap();
        assert!(
            Arc::ptr_eq(&got_owner, &got_waiter),
            "waiter must share the owner's buffer"
        );
        assert_bit_exact(&got_owner, &expected[0].1, "a");
    });
    let s = server.stats();
    assert_eq!(s.misses, 1, "one decode despite retry + waiter");
    assert_eq!(s.io_retries, 1, "the injected transient retried once");
    assert_eq!(s.hits, 1, "the coalesced waiter");
    assert_eq!(s.coalesced, 1);
    assert_eq!(s.overloads, 1);
    assert_eq!(s.decode_errors, 0, "transient faults never fail a decode");
    // permit released: the shed tensor now decodes
    assert_bit_exact(&server.get("b").unwrap(), &expected[1].1, "b");
}

#[test]
fn stats_invariants_hold_under_a_concurrent_storm() {
    let raw = packed_bytes("storm");
    let expected = clean_decodes(&raw);
    // cap holds roughly 1.5 tensors → constant racing insert/evict
    let server = ArtifactServer::new(
        Artifact::from_bytes(raw.clone()).unwrap(),
        20_000,
    );
    let threads = 8;
    let per_thread = 60;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let server = &server;
            let expected = &expected;
            scope.spawn(move || {
                for i in 0..per_thread {
                    let (name, want) = &expected[(t + i) % expected.len()];
                    let got = server.get(name).unwrap();
                    assert_bit_exact(&got, want, name);
                }
            });
        }
    });
    // one bad name to exercise the not_found leg of the partition
    assert!(matches!(
        server.get("nope").unwrap_err(),
        ArtifactError::NotFound { .. }
    ));
    let s = server.stats();
    let total = (threads * per_thread) as u64 + 1;
    assert_eq!(s.requests, total);
    assert_eq!(
        s.hits + s.misses + s.not_found,
        total,
        "fault-free partition"
    );
    assert_eq!(s.not_found, 1);
    assert_eq!(
        (s.decode_errors, s.coalesced_errors, s.quarantine_hits,
         s.overloads, s.quarantined),
        (0, 0, 0, 0, 0),
        "no fault legs on a clean container"
    );
    // every successful decode was inserted; entries leave only by
    // eviction — so the books must balance exactly
    assert_eq!(
        s.misses,
        s.evictions + s.cached_tensors as u64,
        "insert/evict accounting"
    );
    // incremental byte accounting matches a from-scratch recount
    let (audit_tensors, audit_bytes) = server.cache_audit();
    assert_eq!(audit_tensors, s.cached_tensors);
    assert_eq!(audit_bytes, s.cached_bytes);
    assert!(
        s.cached_bytes <= 20_000 + 4 * 4096,
        "resident bytes bounded by cap + newest tensor"
    );
    assert_eq!(s.decoded_bytes % 4, 0);
    assert!(s.evictions > 0, "the cap must have forced evictions");
}

#[test]
fn cap_zero_disables_caching_but_still_coalesces() {
    let raw = packed_bytes("capzero");
    let expected = clean_decodes(&raw);
    let want_a = &expected[0].1;
    let server = ArtifactServer::new(
        Artifact::from_bytes(raw.clone()).unwrap(),
        0,
    );
    let n = 8;
    let barrier = Barrier::new(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let server = &server;
                let barrier = &barrier;
                scope.spawn(move || {
                    barrier.wait();
                    server.get("a").unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_bit_exact(&h.join().unwrap(), want_a, "a");
        }
    });
    let s = server.stats();
    assert_eq!(s.requests, n as u64);
    assert_eq!(s.hits + s.misses, n as u64);
    assert_eq!(
        s.hits, s.coalesced,
        "with no cache every hit is a coalesced share"
    );
    assert_eq!(s.cached_tensors, 0);
    assert_eq!(s.cached_bytes, 0);
    assert_eq!(
        s.decoded_bytes,
        s.misses * 4 * want_a.len() as u64,
        "each miss decoded the full tensor"
    );
    // clear_cache on an empty cache is a harmless no-op
    server.clear_cache();
    assert_eq!(server.cache_audit(), (0, 0));
}

#[test]
fn decode_into_respects_quarantine_and_accounting() {
    let raw = packed_bytes("into");
    let expected = clean_decodes(&raw);
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("a", "payload").unwrap();
    let mut damaged = raw.clone();
    damaged[p_off + p_len / 2] ^= 0x04;
    let server = ArtifactServer::new(
        Artifact::from_bytes(damaged).unwrap(),
        1 << 30,
    );
    let mut buf = vec![0f32; expected[0].1.len()];
    assert!(server.decode_into("a", &mut buf).unwrap_err().is_corrupt());
    match server.decode_into("a", &mut buf).unwrap_err() {
        ArtifactError::Quarantined { tensor, cause } => {
            assert_eq!(tensor, "a");
            assert!(cause.is_corrupt());
        }
        other => panic!("expected quarantine, got {other}"),
    }
    let s = server.stats();
    assert_eq!(s.requests, 2);
    assert_eq!(s.misses, 1);
    assert_eq!(s.decode_errors, 1);
    assert_eq!(s.quarantine_hits, 1);
    assert_eq!(s.quarantined, 1);
    // the clean tensor decodes into a caller-owned buffer bit-exactly,
    // bypassing the cache
    let mut buf = vec![0f32; expected[1].1.len()];
    server.decode_into("b", &mut buf).unwrap();
    assert_bit_exact(&buf, &expected[1].1, "b");
    let s = server.stats();
    assert_eq!(s.decoded_bytes, 4 * buf.len() as u64);
    assert_eq!(s.cached_tensors, 0, "decode_into never populates cache");
}

/// PR 8 satellite: `params()` routes every tensor through the serving
/// path, so the quarantine (and the stats) apply to bulk decodes too.
#[test]
fn params_routes_through_serving_path_and_respects_quarantine() {
    let raw = packed_bytes("params");
    let expected = clean_decodes(&raw);
    let server = ArtifactServer::new(
        Artifact::from_bytes(raw.clone()).unwrap(),
        1 << 30,
    );
    let params = server.params().unwrap();
    assert_eq!(params.len(), expected.len());
    for (name, want) in &expected {
        assert_bit_exact(&params[name], want, name);
    }
    let s = server.stats();
    assert_eq!(s.requests, 3, "params counts like any other caller");
    assert_eq!(s.misses, 3);
    // a second bulk decode is served from the cache
    server.params().unwrap();
    let s = server.stats();
    assert_eq!(s.requests, 6);
    assert_eq!(s.hits, 3);

    // a quarantined tensor fails the whole map typed, without ever
    // re-decoding the damaged bytes
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("a", "payload").unwrap();
    let mut damaged = raw.clone();
    damaged[p_off + p_len / 2] ^= 0x20;
    let server = ArtifactServer::new(
        Artifact::from_bytes(damaged).unwrap(),
        1 << 30,
    );
    assert!(server.get("a").unwrap_err().is_corrupt());
    match server.params().unwrap_err() {
        ArtifactError::Quarantined { tensor, cause } => {
            assert_eq!(tensor, "a");
            assert!(cause.is_corrupt());
        }
        other => panic!("expected quarantined params, got {other}"),
    }
    let s = server.stats();
    assert_eq!(s.quarantine_hits, 1);
    assert_eq!(s.misses, 1, "params never re-decoded the poisoned bytes");
}

/// PR 8 satellite: the LRU stamp clock moves only on a cache hit or
/// insert — failed or cache-bypassing requests leave it untouched (the
/// old gate bumped it on every request whenever caching was enabled).
#[test]
fn cache_clock_advances_only_on_hit_or_insert() {
    let raw = packed_bytes("stamp");
    let expected = clean_decodes(&raw);
    let server = ArtifactServer::new(
        Artifact::from_bytes(raw.clone()).unwrap(),
        1 << 30,
    );
    assert_eq!(server.cache_clock(), 0);
    server.get("a").unwrap(); // cold miss → insert
    assert_eq!(server.cache_clock(), 1);
    server.get("a").unwrap(); // hit
    assert_eq!(server.cache_clock(), 2);
    assert!(server.get("nope").is_err());
    assert_eq!(
        server.cache_clock(),
        2,
        "a failed lookup must not advance the stamp clock"
    );
    let mut buf = vec![0f32; expected[1].1.len()];
    server.decode_into("b", &mut buf).unwrap();
    assert_eq!(
        server.cache_clock(),
        2,
        "decode_into bypasses the cache and its clock"
    );
    server.get("b").unwrap();
    assert_eq!(server.cache_clock(), 3);
    // audit asserts stamp uniqueness and the clock bound internally
    let (tensors, _) = server.cache_audit();
    assert_eq!(tensors, 2);
}

/// PR 8 satellite: `decode_into` rides the same queue/deadline admission
/// as `get` — it queues for a permit, overflows typed, and expires with
/// an exact `waited_ms` under a virtual clock.
#[test]
fn decode_into_queues_and_expires_like_get() {
    let raw = packed_bytes("diq");
    let expected = clean_decodes(&raw);
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("a", "payload").unwrap();
    let fs = FaultFs::new(raw.clone())
        .with_transient_at(p_off + p_len / 2, 1);
    let gate = Arc::new(GateClock::new());
    let art = Artifact::from_source_with(
        ByteSource::Fault(fs),
        RetryPolicy::default(),
        gate.clone(),
    )
    .unwrap();
    let server = ArtifactServer::new(art, 1 << 30)
        .with_max_decodes(1)
        .with_queue_depth(1);
    std::thread::scope(|scope| {
        let owner = scope.spawn(|| server.get("a"));
        wait_until("owner parked in backoff", || gate.waiting() == 1);
        // decode_into queues for the busy permit like get...
        let waiter = scope.spawn(|| {
            let mut buf = vec![0f32; expected[1].1.len()];
            server.decode_into_deadline(
                "b",
                &mut buf,
                Some(Deadline::at(Duration::from_millis(40))),
            )
        });
        wait_until("decode_into parked in FIFO", || {
            server.decode_queue().waiting() == 1
        });
        // ...and overflows typed past the configured depth
        let mut buf = vec![0f32; expected[2].1.len()];
        match server.decode_into("c", &mut buf).unwrap_err() {
            ArtifactError::QueueFull { depth } => assert_eq!(depth, 1),
            other => panic!("expected queue-full, got {other}"),
        }
        gate.advance(Duration::from_millis(40));
        match waiter.join().unwrap().unwrap_err() {
            ArtifactError::DeadlineExceeded { tensor, waited_ms } => {
                assert_eq!(tensor, "b");
                assert_eq!(waited_ms, 40);
            }
            other => panic!("expected deadline, got {other}"),
        }
        gate.open();
        assert!(owner.join().unwrap().is_ok());
    });
    // the permit was never leaked: a cold decode_into succeeds
    let mut buf = vec![0f32; expected[1].1.len()];
    server.decode_into("b", &mut buf).unwrap();
    assert_bit_exact(&buf, &expected[1].1, "b");
    let s = server.stats();
    assert_eq!(s.queue_full, 1);
    assert_eq!(s.deadline_exceeded_queued, 1);
    assert_eq!(s.misses, 2, "owner's a + the final b");
    assert!(s.partition_closed(), "{s:?}");
}
