//! Property tests for the entropy coders (via `util::testing::check`):
//! rANS and Huffman encode→decode recover exact symbol streams over
//! adversarial count distributions, and canonical Huffman codes are
//! prefix-free with Kraft sum ≤ 1.

use owf::compress::huffman::HuffmanCode;
use owf::compress::rans::{rans_decode, rans_encode, RansModel};
use owf::util::testing::{check, Gen};

/// Draw a stream whose empirical distribution follows `counts`.
fn stream(counts: &[u64], len: usize, g: &mut Gen) -> Vec<u16> {
    let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    (0..len)
        .map(|_| g.rng.categorical(&weights) as u16)
        .collect()
}

/// Random counts: mixes zeros, singletons and heavy spikes.
fn random_counts(g: &mut Gen, n_symbols: usize) -> Vec<u64> {
    (0..n_symbols)
        .map(|_| match g.rng.below(4) {
            0 => 0,
            1 => 1,
            2 => g.rng.below(50) as u64 + 1,
            _ => g.rng.below(100_000) as u64 + 1,
        })
        .collect()
}

#[test]
fn rans_roundtrips_exactly() {
    check("rans-roundtrip-adversarial", 60, |g: &mut Gen| {
        let n_symbols = 2 + g.rng.below(60);
        let mut counts = random_counts(g, n_symbols);
        if counts.iter().all(|&c| c == 0) {
            counts[0] = 1;
        }
        let model = RansModel::from_counts(&counts);
        let len = g.rng.below(4000);
        let symbols = stream(&counts, len, g);
        let enc = rans_encode(&model, &symbols);
        let dec = rans_decode(&model, &enc, symbols.len());
        assert_eq!(dec, symbols, "rANS corrupted the stream");
    });
}

#[test]
fn huffman_roundtrips_exactly() {
    check("huffman-roundtrip-adversarial", 60, |g: &mut Gen| {
        let n_symbols = 1 + g.rng.below(60);
        let mut counts = random_counts(g, n_symbols);
        if counts.iter().all(|&c| c == 0) {
            counts[0] = 1;
        }
        let code = HuffmanCode::from_counts(&counts);
        // stream over the *seen* symbols only
        let len = g.rng.below(2000);
        let symbols = stream(&counts, len, g);
        let (bytes, bit_count) = code.encode(&symbols);
        assert!(bytes.len() as u64 * 8 >= bit_count);
        let dec = code.decode(&bytes, symbols.len());
        assert_eq!(dec, symbols, "Huffman corrupted the stream");
    });
}

#[test]
fn huffman_codes_are_prefix_free() {
    check("huffman-prefix-free", 60, |g: &mut Gen| {
        let n_symbols = 2 + g.rng.below(40);
        let counts = random_counts(g, n_symbols);
        if counts.iter().filter(|&&c| c > 0).count() < 2 {
            return; // degenerate alphabets are covered elsewhere
        }
        let code = HuffmanCode::from_counts(&counts);
        let active: Vec<usize> =
            (0..counts.len()).filter(|&i| counts[i] > 0).collect();
        // every seen symbol has a code, every unseen symbol has none
        for (i, &c) in counts.iter().enumerate() {
            assert_eq!(
                code.lengths[i] > 0,
                c > 0,
                "length table wrong at {i}"
            );
        }
        // Kraft: Σ 2^-len ≤ 1 (an optimal complete code sums to exactly 1)
        let kraft: f64 = active
            .iter()
            .map(|&i| 2f64.powi(-(code.lengths[i] as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft {kraft}");
        assert!(kraft > 1.0 - 1e-9, "huffman must be complete: {kraft}");
        // no codeword is a prefix of another
        for &a in &active {
            for &b in &active {
                if a == b {
                    continue;
                }
                let (la, lb) =
                    (code.lengths[a] as u32, code.lengths[b] as u32);
                if la <= lb {
                    assert_ne!(
                        code.codes[a],
                        code.codes[b] >> (lb - la),
                        "code {a} prefixes {b}"
                    );
                }
            }
        }
    });
}

#[test]
fn coders_agree_on_quantiser_output() {
    // the fig.-24 pipeline end to end: quantise simulated weights, entropy
    // code the indices with both coders, decode, reconstruct identically
    use owf::dist::{Dist, Family};
    use owf::formats::cbrt::{cbrt_rms, CBRT_ALPHA};
    use owf::formats::Variant;
    use owf::util::rng::Rng;

    let mut rng = Rng::new(0xC0DEC);
    let data = Dist::standard(Family::StudentT, 5.0)
        .sample_vec(&mut rng, 50_000);
    let cb = cbrt_rms(Family::StudentT, 5.0, 4, Variant::Symmetric, CBRT_ALPHA);
    let symbols: Vec<u16> = data.iter().map(|&x| cb.quantise(x)).collect();
    let mut counts = vec![0u64; cb.len()];
    for &s in &symbols {
        counts[s as usize] += 1;
    }
    let huff = HuffmanCode::from_counts(&counts);
    let (hbytes, _) = huff.encode(&symbols);
    assert_eq!(huff.decode(&hbytes, symbols.len()), symbols);
    let model = RansModel::from_counts(&counts);
    let renc = rans_encode(&model, &symbols);
    assert_eq!(rans_decode(&model, &renc, symbols.len()), symbols);
}
