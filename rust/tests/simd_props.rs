//! Forced-ISA parity matrix for the explicit SIMD kernels
//! (`util::simd`): every vector path must be *bit-exact* against the
//! scalar oracle it shadows — on adversarial floats (NaN, ±inf,
//! subnormals, exact midpoints), on every interleave K ∈ {1,2,4,8}, and
//! on every input length for the checksum hash.  The kernels take the
//! ISA as an explicit argument, so a single test process exercises the
//! scalar oracle *and* each path the host can run; `OWF_ISA` is the
//! production override, `resolve` its unit-testable core.

use owf::compress::rans::{
    rans_decode_interleaved_checked_with, rans_decode_interleaved_with,
    rans_encode_interleaved, RansModel,
};
use owf::coordinator::config::Scheme;
use owf::dist::{Dist, Family};
use owf::util::simd::{
    self, detected, fnv1a64_ref, fnv1a64_with, fnv1a64_words, lanes_for,
    resolve, supported, Isa,
};
use owf::util::testing::{check, Gen};

const ALL_ISAS: [Isa; 3] = [Isa::Scalar, Isa::Avx2, Isa::Neon];

/// The ISAs this host can actually execute (always includes Scalar).
fn runnable() -> Vec<Isa> {
    ALL_ISAS.iter().copied().filter(|&i| supported(i)).collect()
}

#[test]
fn resolve_covers_the_full_override_matrix() {
    let det = detected();
    // no override → detected, whatever it is
    assert_eq!(resolve(None, det), Ok(det));
    // scalar is always forceable, with any casing/padding
    for raw in ["scalar", "SCALAR", " Scalar "] {
        assert_eq!(resolve(Some(raw), det), Ok(Isa::Scalar));
    }
    // forcing a runnable vector ISA selects it; forcing an unrunnable
    // one is a hard error (silently falling back would time the wrong
    // kernel and void every [simd] bench row)
    for isa in [Isa::Avx2, Isa::Neon] {
        let r = resolve(Some(isa.name()), det);
        if supported(isa) {
            assert_eq!(r, Ok(isa));
        } else {
            let e = r.expect_err("unrunnable ISA must not resolve");
            assert!(e.contains(isa.name()), "error names the ISA: {e}");
        }
    }
    // garbage is a hard error too, naming the knob
    let e = resolve(Some("avx512"), det).expect_err("unknown ISA");
    assert!(e.contains("OWF_ISA"), "error names the env knob: {e}");
    // the host always supports its own detection, and lane counts match
    // the vector widths the kernels were written for
    assert!(supported(det));
    assert_eq!(lanes_for(Isa::Avx2), 8);
    assert_eq!(lanes_for(Isa::Neon), 4);
    assert_eq!(lanes_for(Isa::Scalar), 4);
}

#[test]
fn lut_slots_is_bit_exact_on_adversarial_probes() {
    // real LUT geometries from built codebooks, probed with the shared
    // adversarial set (±inf, NaN, subnormals, exact midpoints, ULP
    // neighbours) plus heavy random tails — slot indices must agree
    // exactly, since one slot off is one quantised index off
    let mut rng = owf::util::rng::Rng::new(11);
    let data =
        Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, 1 << 12);
    for spec in [
        "cbrt-t5@4:block128-absmax",
        "nf@4:block128-absmax",
        "int@8:block128-absmax",
    ] {
        let scheme = Scheme::parse(spec).unwrap();
        let cb = scheme.build_codebook(128, Some(&data), &[]).unwrap();
        let (lo, inv_step, top) =
            cb.lut_params().unwrap_or_else(|| panic!("{spec}: no LUT"));
        let mut probes = data.clone();
        probes.extend(cb.adversarial_probes());
        // odd lengths exercise every remainder path (8-wide AVX2 body +
        // tail, 4-wide NEON body + tail)
        for len in [0, 1, 3, 7, 8, 9, 15, 16, 17, probes.len()] {
            let ys = &probes[..len.min(probes.len())];
            let mut want = vec![u32::MAX; ys.len()];
            simd::lut_slots(Isa::Scalar, ys, lo, inv_step, top, &mut want);
            for isa in runnable() {
                let mut got = vec![u32::MAX; ys.len()];
                simd::lut_slots(isa, ys, lo, inv_step, top, &mut got);
                assert_eq!(
                    got,
                    want,
                    "{spec}: lut_slots {} != scalar at len {}",
                    isa.name(),
                    ys.len()
                );
            }
        }
    }
}

#[test]
fn gather_is_bit_exact_including_nan_table_entries() {
    check("simd-gather-parity", 40, |g: &mut Gen| {
        let table_len = 1 + g.rng.below(300);
        // tables with NaN/±inf/subnormal payloads: parity is compared on
        // *bits*, so a gather that canonicalised a NaN would fail
        let table: Vec<f32> = (0..table_len)
            .map(|_| match g.rng.below(8) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => f32::MIN_POSITIVE / 2.0,
                _ => (g.rng.f64() * 2.0 - 1.0) as f32,
            })
            .collect();
        let n = g.rng.below(100);
        let indices: Vec<u16> =
            (0..n).map(|_| g.rng.below(table_len) as u16).collect();
        let mut want = vec![0f32; n];
        simd::gather_u16_f32(Isa::Scalar, &table, &indices, &mut want);
        for isa in runnable() {
            let mut got = vec![0f32; n];
            simd::gather_u16_f32(isa, &table, &indices, &mut got);
            let (gb, wb): (Vec<u32>, Vec<u32>) = (
                got.iter().map(|x| x.to_bits()).collect(),
                want.iter().map(|x| x.to_bits()).collect(),
            );
            assert_eq!(gb, wb, "gather {} != scalar", isa.name());
        }
    });
}

#[test]
fn gather_panics_identically_on_out_of_bounds_indices() {
    // the scalar oracle panics on an OOB index (its bounds-checked
    // indexing); the vector paths pre-validate and re-run the scalar
    // loop to surface the *same* panic rather than a hardware gather
    // from hyperspace — so both must panic, on the same input
    let table = vec![1.0f32; 16];
    let indices: Vec<u16> = vec![0, 3, 15, 16, 2, 1, 0, 4, 9]; // 16 is OOB
    for isa in runnable() {
        let r = std::panic::catch_unwind(|| {
            let mut out = vec![0f32; indices.len()];
            simd::gather_u16_f32(isa, &table, &indices, &mut out);
        });
        assert!(r.is_err(), "gather {} must panic on OOB", isa.name());
    }
}

#[test]
fn rans_interleaved_parity_across_isa_and_lane_counts() {
    check("simd-rans-parity", 25, |g: &mut Gen| {
        let n_symbols = 2 + g.rng.below(60);
        let mut counts: Vec<u64> = (0..n_symbols)
            .map(|_| match g.rng.below(4) {
                0 => 0,
                1 => 1,
                2 => g.rng.below(50) as u64 + 1,
                _ => g.rng.below(100_000) as u64 + 1,
            })
            .collect();
        if counts.iter().all(|&c| c == 0) {
            counts[0] = 1;
        }
        let model = RansModel::from_counts(&counts);
        let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let len = g.rng.below(3000);
        let symbols: Vec<u16> = (0..len)
            .map(|_| g.rng.categorical(&weights) as u16)
            .collect();
        for k in [1usize, 2, 4, 8] {
            let container = rans_encode_interleaved(&model, &symbols, k);
            let oracle = rans_decode_interleaved_with(
                &model,
                &container,
                symbols.len(),
                Isa::Scalar,
            );
            assert_eq!(oracle, symbols, "scalar x{k} roundtrip");
            for isa in runnable() {
                // full decode, plus a prefix (the SIMD rounds hand off
                // mid-stream to the scalar loop at the remainder)
                for count in [symbols.len(), symbols.len() / 2] {
                    let fast = rans_decode_interleaved_with(
                        &model, &container, count, isa,
                    );
                    assert_eq!(
                        fast,
                        &symbols[..count],
                        "rans x{k} {} != stream at count {count}",
                        isa.name()
                    );
                }
                // the checked (serving) variant shares the SIMD rounds;
                // its verdict and output must match the scalar oracle
                let checked = rans_decode_interleaved_checked_with(
                    &model,
                    &container,
                    symbols.len(),
                    isa,
                );
                assert_eq!(
                    checked.as_deref(),
                    Ok(&symbols[..]),
                    "checked rans x{k} {} diverged",
                    isa.name()
                );
            }
        }
    });
}

#[test]
fn fnv_known_vectors_and_every_length_up_to_64() {
    // published FNV-1a 64-bit test vectors pin the constants
    for isa in ALL_ISAS {
        assert_eq!(fnv1a64_with(isa, b""), 0xcbf29ce484222325, "{isa:?}");
        assert_eq!(fnv1a64_with(isa, b"a"), 0xaf63dc4c8601ec8c, "{isa:?}");
        assert_eq!(
            fnv1a64_with(isa, b"foobar"),
            0x85944171f73967e8,
            "{isa:?}"
        );
    }
    // every length 0..=64 covers all word/remainder splits of the
    // 8-byte-block path; the hash chain is serial, so any word-load slip
    // shows up as a different digest
    let buf: Vec<u8> = (0..64u8).map(|i| i.wrapping_mul(37) ^ 0xA5).collect();
    for len in 0..=64 {
        let want = fnv1a64_ref(&buf[..len]);
        assert_eq!(fnv1a64_words(&buf[..len]), want, "words @ len {len}");
        for isa in ALL_ISAS {
            assert_eq!(
                fnv1a64_with(isa, &buf[..len]),
                want,
                "{isa:?} @ len {len}"
            );
        }
    }
    // misaligned starts: word-at-a-time must not assume 8-byte alignment
    for off in 0..8 {
        assert_eq!(
            fnv1a64_words(&buf[off..]),
            fnv1a64_ref(&buf[off..]),
            "offset {off}"
        );
    }
}

#[test]
fn packed_artifact_decodes_identically_via_pread_and_memory() {
    // end-to-end over the seek/pread reader (satellite: the serving
    // reader now preads sections at recorded offsets instead of slicing
    // a whole-file buffer): pack once, open the same container both
    // ways, require bit-identical tensors — and the FNV checksums the
    // reader verifies flow through the dispatched hash, so this also
    // pins the word-at-a-time path against real container bytes
    use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
    use owf::artifact::{Artifact, Codec};
    use owf::tensorstore::{Store, Tensor};
    use owf::util::json::Json;
    use std::collections::HashMap;

    let n = 8 * 1024;
    let mut rng = owf::util::rng::Rng::new(29);
    let data =
        Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let mut store = Store::new(Json::obj().push("kind", "simd-props"));
    let mut t = Tensor::from_f32("probe.w", vec![n / 1024, 1024], &data);
    t.channel_axis = Some(1);
    store.push(t);
    let opts = PackOptions {
        spec: "cbrt-t5@4:block64-absmax:compress".to_string(),
        alloc: AllocMode::Flat,
        codec: Codec::Rans,
        lanes: simd::preferred_lanes(),
        target_bits: None,
        meta: Json::obj(),
    };
    let path = std::env::temp_dir().join(format!(
        "owf_simd_props_{}.owq",
        std::process::id()
    ));
    let empty: HashMap<String, f64> = HashMap::new();
    pack_store(&store, &empty, &opts, &path).unwrap();

    let via_pread = Artifact::open(&path).unwrap();
    let via_mem =
        Artifact::from_bytes(std::fs::read(&path).unwrap()).unwrap();
    assert_eq!(via_pread.tensors.len(), via_mem.tensors.len());
    let (a, b) = (
        via_pread.decode_tensor(0).unwrap(),
        via_mem.decode_tensor(0).unwrap(),
    );
    assert!(
        a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
        "pread and in-memory decodes diverge"
    );
    let _ = std::fs::remove_file(&path);
}
