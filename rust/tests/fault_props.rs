//! Fault-model property tests for the `OWQ1`/`OWQ2` artifact layer,
//! driven by the deterministic fault harness in `owf::util::faultfs`:
//!
//! * **exhaustive single-bit-flip sweep**: every bit of a packed
//!   container is flipped in turn; each flip must either fail with a
//!   typed [`ArtifactError`] naming the damaged tensor + section, or
//!   leave every tensor's decode bit-identical — never a panic, never
//!   silently wrong data (detection is guaranteed because each FNV-1a
//!   step is a bijection of the running state, so any one-byte change
//!   always changes the digest); the same sweep runs over the OWQ2
//!   forms: a `:rot` container (rotation seed in the manifest, inverse
//!   rotation on decode) and a `grid` container (dense codepoint table
//!   in `codebook`, dense u16 indices in `payload`, dense histogram in
//!   `counts`);
//! * truncation at any point is rejected as torn (or, if only trailing
//!   padding is cut, decodes stay bit-exact);
//! * transient read faults retry on the injected clock with the exact
//!   exponential backoff schedule, then succeed; exhaustion surfaces a
//!   typed transient-I/O error; corruption never retries;
//! * a decoder panic on damage that *evades* checksums (forged section
//!   checksum) is contained at the artifact boundary as `Corrupt`;
//! * the on-disk helpers (`write_torn_copy`, `flip_bit_in_file`) that
//!   back `owf fault-inject` produce damage the reader detects.

use std::sync::Arc;
use std::time::Duration;

use owf::artifact::retry::{RecordingClock, RetryPolicy};
use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
use owf::artifact::{fnv1a64, u64_to_hex, Artifact, ArtifactError, Codec};
use owf::tensorstore::{Store, Tensor};
use owf::util::faultfs::{
    flip_bit_in_file, write_torn_copy, ByteSource, FaultFs,
};
use owf::util::json::Json;
use owf::util::rng::Rng;

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

/// Pack a small two-tensor container (with outliers, so all six section
/// classes are non-empty for at least one tensor) and return its bytes.
fn packed_bytes(codec: Codec, lanes: usize, tag: &str) -> Vec<u8> {
    let mut rng = Rng::new(0xFA117);
    let mut store = Store::new(Json::obj().push("kind", "fault-props"));
    let mut w: Vec<f32> = rng.student_t_vec(5.0, 96);
    w[7] = 40.0; // spikes → sparse overlay → outlier sections
    w[61] = -35.0;
    store.push(Tensor::from_f32("w", vec![96], &w));
    let v: Vec<f32> = rng.student_t_vec(5.0, 64);
    store.push(Tensor::from_f32("v", vec![64], &v));
    let dir = std::env::temp_dir().join("owf_fault_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!(
        "{tag}_{}_{lanes}_{}.owq",
        codec.name(),
        std::process::id()
    ));
    pack_store(
        &store,
        &std::collections::HashMap::new(),
        &PackOptions {
            spec: "cbrt-t5@4:block32-absmax:sparse0.02,compress"
                .to_string(),
            alloc: AllocMode::Flat,
            codec,
            lanes,
            target_bits: None,
            meta: Json::obj().push("source", "test"),
        },
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    raw
}

/// Like [`packed_bytes`] but with a caller-chosen spec, a 2-D tensor
/// ("m", 12×8, so `:rot` actually rotates) and the spiky 1-D "w" (so
/// the recorded-identity rotation path is swept alongside).
fn packed_bytes_spec(
    spec: &str,
    codec: Codec,
    lanes: usize,
    tag: &str,
) -> Vec<u8> {
    let mut rng = Rng::new(0xFA117);
    let mut store = Store::new(Json::obj().push("kind", "fault-props"));
    let m: Vec<f32> = rng.student_t_vec(5.0, 12 * 8);
    store.push(Tensor::from_f32("m", vec![12, 8], &m));
    let mut w: Vec<f32> = rng.student_t_vec(5.0, 96);
    w[7] = 40.0;
    w[61] = -35.0;
    store.push(Tensor::from_f32("w", vec![96], &w));
    let dir = std::env::temp_dir().join("owf_fault_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!(
        "{tag}_{}_{lanes}_{}.owq",
        codec.name(),
        std::process::id()
    ));
    pack_store(
        &store,
        &std::collections::HashMap::new(),
        &PackOptions {
            spec: spec.to_string(),
            alloc: AllocMode::Flat,
            codec,
            lanes,
            target_bits: None,
            meta: Json::obj().push("source", "test"),
        },
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    raw
}

/// A v3 fractional container (2.5/3.3-style mixed tensors) for the flip
/// sweeps: same two tensors as [`packed_bytes_spec`], packed with the
/// fractional allocator at a non-lattice budget so at least one tensor
/// carries a `mix` record + `block_schemes` section.
fn packed_fractional_bytes(tag: &str) -> Vec<u8> {
    let mut rng = Rng::new(0xFA117);
    let mut store = Store::new(Json::obj().push("kind", "fault-props"));
    let m: Vec<f32> = rng.student_t_vec(5.0, 12 * 8);
    store.push(Tensor::from_f32("m", vec![12, 8], &m));
    let mut w: Vec<f32> = rng.student_t_vec(5.0, 96);
    w[7] = 40.0;
    w[61] = -35.0;
    store.push(Tensor::from_f32("w", vec![96], &w));
    let dir = std::env::temp_dir().join("owf_fault_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path =
        dir.join(format!("{tag}_{}.owq", std::process::id()));
    pack_store(
        &store,
        &std::collections::HashMap::new(),
        &PackOptions {
            spec: "int@4:block32-absmax".to_string(),
            alloc: AllocMode::Fractional,
            codec: Codec::Huffman,
            lanes: 2,
            target_bits: Some(3.3),
            meta: Json::obj().push("source", "test"),
        },
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let art = Artifact::from_bytes(raw.clone()).unwrap();
    assert!(
        art.tensors.iter().any(|r| r.mix.is_some()),
        "the fractional fault fixture must contain a mixed tensor"
    );
    raw
}

fn manifest_len(raw: &[u8]) -> usize {
    u32::from_le_bytes(raw[4..8].try_into().unwrap()) as usize
}

fn clean_decodes(raw: &[u8]) -> Vec<(String, Vec<f32>)> {
    let art = Artifact::from_bytes(raw.to_vec()).unwrap();
    art.tensors
        .iter()
        .enumerate()
        .map(|(i, r)| (r.name.clone(), art.decode_tensor(i).unwrap()))
        .collect()
}

/// Which (tensor, section) owns file byte `off`, if any (zero-length
/// sections own no bytes; everything else in the payload is padding).
fn owner_of(art: &Artifact, off: usize) -> Option<(String, String)> {
    for rec in &art.tensors {
        for (sname, _) in rec.sections() {
            if let Some((s_off, s_len)) =
                art.section_file_range(&rec.name, sname)
            {
                if s_len > 0 && off >= s_off && off < s_off + s_len {
                    return Some((rec.name.clone(), sname.to_string()));
                }
            }
        }
    }
    None
}

fn assert_bit_exact(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}");
    }
}

/// The tentpole property: flip every single bit of the container; the
/// reader must return a typed error naming the damage or stay bit-exact.
fn exhaustive_flip_sweep(raw: &[u8]) {
    let clean = Artifact::from_bytes(raw.to_vec()).unwrap();
    let expected = clean_decodes(raw);
    let base = 8 + manifest_len(raw) + 8;
    for off in 0..raw.len() {
        for bit in 0..8u8 {
            let mut damaged = raw.to_vec();
            damaged[off] ^= 1 << bit;
            let opened = Artifact::from_bytes(damaged);
            if off < 4 {
                // magic: structurally rejected
                let e = opened.err().expect("magic flip must fail open");
                assert_eq!(e.kind_name(), "torn", "off {off} bit {bit}");
                continue;
            }
            if off < 8 {
                // manifest length: either out of range (torn) or a
                // shifted checksum window (corrupt)
                let e = opened.err().expect("mlen flip must fail open");
                assert!(
                    matches!(e.kind_name(), "torn" | "corrupt"),
                    "off {off} bit {bit}: {e}"
                );
                continue;
            }
            if off < base {
                // manifest body or its trailing checksum: the FNV-1a
                // digest is guaranteed to change on any one-byte change
                let e =
                    opened.err().expect("manifest flip must fail open");
                match &e {
                    ArtifactError::Corrupt { tensor, section, .. } => {
                        assert_eq!(tensor, "", "off {off} bit {bit}");
                        assert_eq!(
                            section, "manifest",
                            "off {off} bit {bit}"
                        );
                    }
                    other => panic!(
                        "off {off} bit {bit}: expected manifest \
                         corruption, got {other}"
                    ),
                }
                continue;
            }
            // payload region: the container opens (bounds intact)...
            let art = opened.unwrap_or_else(|e| {
                panic!("off {off} bit {bit}: payload flip broke open: {e}")
            });
            match owner_of(&clean, off) {
                Some((tname, sname)) => {
                    // ...and exactly the owning tensor fails its decode,
                    // naming the damaged section; the rest stay bit-exact
                    for (i, (name, want)) in expected.iter().enumerate() {
                        let got = art.decode_tensor(i);
                        if *name == tname {
                            match got.err().unwrap_or_else(|| {
                                panic!(
                                    "off {off} bit {bit}: flip in \
                                     {tname}/{sname} decoded silently"
                                )
                            }) {
                                ArtifactError::Corrupt {
                                    tensor,
                                    section,
                                    ..
                                } => {
                                    assert_eq!(tensor, tname);
                                    assert_eq!(
                                        section, sname,
                                        "off {off} bit {bit}"
                                    );
                                }
                                other => panic!(
                                    "off {off} bit {bit}: {other}"
                                ),
                            }
                        } else {
                            assert_bit_exact(
                                &got.unwrap(),
                                want,
                                &format!(
                                    "off {off} bit {bit}: tensor {name}"
                                ),
                            );
                        }
                    }
                }
                None => {
                    // alignment padding: no observable effect at all
                    for (i, (name, want)) in expected.iter().enumerate() {
                        assert_bit_exact(
                            &art.decode_tensor(i).unwrap(),
                            want,
                            &format!("off {off} bit {bit} pad: {name}"),
                        );
                    }
                }
            }
        }
    }
}

/// Run exhaustively for interleaved Huffman (the on-disk default).
#[test]
fn every_single_bit_flip_is_detected_or_bit_exact() {
    exhaustive_flip_sweep(&packed_bytes(Codec::Huffman, 2, "sweep"));
}

/// The OWQ2 durable forms obey the same fault contract: the rotation
/// seed and grid δ/bucket records live under the manifest checksum, and
/// the grid codepoint table / dense index stream / dense histogram live
/// in checksummed sections — so every single-bit flip is detected or
/// provably without effect.
#[test]
fn every_single_bit_flip_is_detected_or_bit_exact_for_rot_and_grid() {
    for (spec, tag) in [
        ("cbrt-t5@4:block32-absmax:sparse0.02,compress,rot", "rotsweep"),
        ("grid@4:tensor-rms:compress", "gridsweep"),
    ] {
        exhaustive_flip_sweep(&packed_bytes_spec(
            spec,
            Codec::Huffman,
            2,
            tag,
        ));
    }
}

/// The OWQ3 mixed form obeys the same fault contract: the `mix` record
/// lives under the manifest checksum, and the per-part concatenated
/// sections plus the `block_schemes` id stream live in checksummed
/// sections — so every single-bit flip in a fractional container is
/// detected (naming the damaged section, `block_schemes` included) or
/// provably without effect.
#[test]
fn every_single_bit_flip_is_detected_or_bit_exact_for_fractional() {
    exhaustive_flip_sweep(&packed_fractional_bytes("fracsweep"));
}

/// Seeded (non-exhaustive) flip sweeps for the other codecs share the
/// same contract.
#[test]
fn seeded_flip_sweep_holds_for_rans_and_raw() {
    for (codec, lanes) in [(Codec::Rans, 3), (Codec::Raw, 1)] {
        let raw = packed_bytes(codec, lanes, "seeded");
        let clean = Artifact::from_bytes(raw.clone()).unwrap();
        let expected = clean_decodes(&raw);
        let base = 8 + manifest_len(&raw) + 8;
        let mut rng = Rng::new(0x5EED + lanes as u64);
        for _ in 0..256 {
            let off = base + rng.below(raw.len() - base);
            let bit = rng.below(8) as u8;
            let mut damaged = raw.clone();
            damaged[off] ^= 1 << bit;
            let art = Artifact::from_bytes(damaged).unwrap();
            match owner_of(&clean, off) {
                Some((tname, sname)) => {
                    let i = clean.position(&tname).unwrap();
                    match art.decode_tensor(i) {
                        Err(ArtifactError::Corrupt {
                            tensor,
                            section,
                            ..
                        }) => {
                            assert_eq!(tensor, tname);
                            assert_eq!(section, sname);
                        }
                        other => panic!(
                            "{} off {off} bit {bit}: {other:?}",
                            codec.name()
                        ),
                    }
                }
                None => {
                    for (i, (name, want)) in expected.iter().enumerate()
                    {
                        assert_bit_exact(
                            &art.decode_tensor(i).unwrap(),
                            want,
                            name,
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn truncation_is_torn_or_padding_only() {
    let raw = packed_bytes(Codec::Huffman, 2, "trunc");
    let expected = clean_decodes(&raw);
    for cut in 0..raw.len() {
        match Artifact::from_bytes(raw[..cut].to_vec()) {
            Err(e) => assert!(
                matches!(e.kind_name(), "torn" | "corrupt"),
                "cut {cut}: {e}"
            ),
            // only trailing padding was cut: everything still decodes
            Ok(art) => {
                for (i, (name, want)) in expected.iter().enumerate() {
                    assert_bit_exact(
                        &art.decode_tensor(i).unwrap(),
                        want,
                        &format!("cut {cut}: {name}"),
                    );
                }
            }
        }
    }
    // the FaultFs truncation view is exactly the prefix view
    let f = FaultFs::new(raw.clone()).with_truncation(raw.len() / 2);
    assert_eq!(f.image(), raw[..raw.len() / 2].to_vec());
    assert!(Artifact::from_source(ByteSource::Fault(f)).is_err());
}

#[test]
fn transient_reads_retry_with_exact_backoff_then_succeed() {
    let raw = packed_bytes(Codec::Huffman, 2, "eintr");
    let expected = clean_decodes(&raw);
    let fs = FaultFs::new(raw).with_transient_reads(2);
    let clock = Arc::new(RecordingClock::new());
    let policy = RetryPolicy {
        attempts: 4,
        base: ms(10),
        cap: ms(1000),
    };
    let art = Artifact::from_source_with(
        ByteSource::Fault(fs),
        policy,
        clock.clone(),
    )
    .unwrap();
    // both injected faults hit the very first (header) read
    assert_eq!(art.io_retries(), 2);
    assert_eq!(clock.slept(), vec![ms(10), ms(20)]);
    for (i, (name, want)) in expected.iter().enumerate() {
        assert_bit_exact(&art.decode_tensor(i).unwrap(), want, name);
    }
    assert_eq!(art.io_retries(), 2, "decodes saw no further faults");
}

#[test]
fn transient_exhaustion_is_a_typed_io_error() {
    let raw = packed_bytes(Codec::Huffman, 2, "exhaust");
    let fs = FaultFs::new(raw).with_transient_reads(1_000);
    let clock = Arc::new(RecordingClock::new());
    let policy = RetryPolicy {
        attempts: 3,
        base: ms(1),
        cap: ms(8),
    };
    let err = Artifact::from_source_with(
        ByteSource::Fault(fs),
        policy,
        clock.clone(),
    )
    .unwrap_err();
    assert_eq!(err.kind_name(), "io-transient", "{err}");
    assert!(err.is_transient_io());
    assert_eq!(clock.slept(), vec![ms(1), ms(2)]);
}

#[test]
fn corruption_fails_immediately_without_sleeping() {
    let raw = packed_bytes(Codec::Huffman, 2, "noretry");
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let (p_off, p_len) =
        clean.section_file_range("w", "payload").unwrap();
    let fs = FaultFs::new(raw).with_flip(p_off + p_len / 2, 3);
    let clock = Arc::new(RecordingClock::new());
    let art = Artifact::from_source_with(
        ByteSource::Fault(fs),
        RetryPolicy::default(),
        clock.clone(),
    )
    .unwrap();
    let i = art.position("w").unwrap();
    let err = art.decode_tensor(i).unwrap_err();
    assert!(err.is_corrupt(), "{err}");
    assert!(
        clock.slept().is_empty(),
        "corruption must never trigger a backoff sleep"
    );
    assert_eq!(art.io_retries(), 0);
    // the clean tensor still serves
    let j = art.position("v").unwrap();
    assert!(art.decode_tensor(j).is_ok());
}

/// Forge the payload checksum so damage *evades* verification: the
/// decoder then sees garbage and may panic — the artifact boundary must
/// contain it as a typed `Corrupt`, never an abort.
#[test]
fn decoder_panic_on_checksum_evading_damage_is_contained() {
    let raw = packed_bytes(Codec::Huffman, 2, "panic");
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let mlen = manifest_len(&raw);
    let rec = &clean.tensors[clean.position("w").unwrap()];
    let (p_off, p_len) =
        clean.section_file_range("w", "payload").unwrap();
    assert!(p_len > 0);
    let mut damaged = raw.clone();
    // zero the whole entropy stream (invalid lane header, torn prefix)
    for b in &mut damaged[p_off..p_off + p_len] {
        *b = 0;
    }
    // ...then forge its section checksum in the manifest
    let new_fnv = fnv1a64(&damaged[p_off..p_off + p_len]);
    let manifest =
        String::from_utf8(damaged[8..8 + mlen].to_vec()).unwrap();
    let old_hex = u64_to_hex(rec.payload.fnv);
    assert!(
        manifest.contains(&old_hex),
        "payload fnv hex not found in manifest"
    );
    let patched =
        manifest.replacen(&old_hex, &u64_to_hex(new_fnv), 1);
    assert_eq!(patched.len(), manifest.len());
    damaged[8..8 + mlen].copy_from_slice(patched.as_bytes());
    // ...and the manifest's own checksum
    let want = fnv1a64(&damaged[8..8 + mlen]);
    damaged[8 + mlen..8 + mlen + 8]
        .copy_from_slice(&want.to_le_bytes());

    let art = Artifact::from_bytes(damaged).expect("forged open");
    let i = art.position("w").unwrap();
    let err = art.decode_tensor(i).unwrap_err();
    assert!(err.is_corrupt(), "contained as Corrupt, got: {err}");
    // the sibling tensor is untouched
    let j = art.position("v").unwrap();
    assert!(art.decode_tensor(j).is_ok());
}

/// The on-disk helpers behind `owf fault-inject`: a torn partial write is
/// rejected at open; a per-section bit flip is caught by `verify_section`
/// naming exactly that section (the `owf fsck` verdict path).
#[test]
fn on_disk_damage_helpers_drive_fsck_style_verdicts() {
    let raw = packed_bytes(Codec::Huffman, 2, "disk");
    let clean = Artifact::from_bytes(raw.clone()).unwrap();
    let dir = std::env::temp_dir().join("owf_fault_props");
    std::fs::create_dir_all(&dir).unwrap();

    let torn = dir.join(format!("torn_{}.owq", std::process::id()));
    write_torn_copy(&torn, &raw, 0.6).unwrap();
    let err = Artifact::open(&torn).unwrap_err();
    assert!(
        matches!(err.kind_name(), "torn" | "corrupt"),
        "{err}"
    );
    std::fs::remove_file(&torn).unwrap();

    for (ti, rec) in clean.tensors.iter().enumerate() {
        for (sname, _) in rec.sections() {
            let Some((off, len)) =
                clean.section_file_range(&rec.name, sname)
            else {
                continue;
            };
            if len == 0 {
                continue;
            }
            let path = dir.join(format!(
                "flip_{ti}_{sname}_{}.owq",
                std::process::id()
            ));
            std::fs::write(&path, &raw).unwrap();
            flip_bit_in_file(&path, off + len / 2, 5).unwrap();
            let art = Artifact::open(&path).unwrap();
            assert!(art.verify_all().is_err());
            match art.verify_section(ti, sname) {
                Some(Err(ArtifactError::Corrupt {
                    tensor,
                    section,
                    ..
                })) => {
                    assert_eq!(tensor, rec.name);
                    assert_eq!(section, sname);
                }
                other => panic!("{}/{sname}: {other:?}", rec.name),
            }
            // every other tensor passes eager verification
            for other in 0..clean.tensors.len() {
                if other != ti {
                    assert!(art.verify_tensor(other).is_ok());
                }
            }
            std::fs::remove_file(&path).unwrap();
        }
    }
}
