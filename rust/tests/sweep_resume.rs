//! Acceptance test for the sweep engine: a 105-point grid expands, runs
//! across `OWF_THREADS` pool workers, writes exactly one JSONL row per
//! point, and a second `--resume` invocation re-runs zero completed points.

use owf::coordinator::config::expand_grid;
use owf::coordinator::sweep::{params_tag, point_key, SIM_SIZE};
use owf::coordinator::{run_sweep, SweepOpts};
use owf::util::json::Json;

const GRID: &str =
    "{int,cbrt-t5,cbrt-normal,cbrt-laplace,nf}@{2..8}:block{32,64,128}-absmax";
const POINTS: usize = 5 * 7 * 3;

fn opts(out: std::path::PathBuf) -> SweepOpts {
    SweepOpts {
        out,
        samples: 1 << 12,
        ..Default::default()
    }
}

fn read_rows(path: &std::path::Path) -> Vec<Json> {
    std::fs::read_to_string(path)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect()
}

#[test]
fn hundred_point_sweep_resumes_with_zero_reruns() {
    // worker width comes from OWF_THREADS (scripts/check.sh pins it to 4;
    // setting it here would race the other tests' env reads)
    let out = std::env::temp_dir().join("owf_sweep_resume_accept.jsonl");
    let _ = std::fs::remove_file(&out);

    let specs = expand_grid(GRID).unwrap();
    assert_eq!(specs.len(), POINTS, "grid must expand to ≥100 points");

    // first run: everything executes, one row per point
    let stats = run_sweep(GRID, &opts(out.clone())).unwrap();
    assert_eq!(stats.planned, POINTS);
    assert_eq!(stats.skipped, 0);
    assert_eq!(stats.ran, POINTS);
    assert_eq!(stats.failed, 0);
    let rows = read_rows(&out);
    assert_eq!(rows.len(), POINTS, "one JSONL row per point");
    // every expanded spec appears exactly once, with sane metrics
    let mut keys: Vec<String> = rows
        .iter()
        .map(|r| {
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
            assert_eq!(r.get("size").unwrap().as_str(), Some(SIM_SIZE));
            let bits = r.get("bits").unwrap().as_f64().unwrap();
            let rr = r.get("r").unwrap().as_f64().unwrap();
            assert!(bits > 1.0 && bits < 10.0, "bits {bits}");
            assert!(rr > 0.0 && rr < 1.0, "r {rr}");
            format!(
                "{}|{}|{}|{}",
                r.get("scheme").unwrap().as_str().unwrap(),
                r.get("size").unwrap().as_str().unwrap(),
                r.get("seed").unwrap().as_f64().unwrap() as u64,
                r.get("params").unwrap().as_str().unwrap(),
            )
        })
        .collect();
    keys.sort();
    let tag = params_tag(&opts(out.clone()));
    let mut expect: Vec<String> = specs
        .iter()
        .map(|s| point_key(s, SIM_SIZE, 0, &tag))
        .collect();
    expect.sort();
    assert_eq!(keys, expect);

    // second run with resume: zero re-runs, file untouched in length
    let mut o = opts(out.clone());
    o.resume = true;
    let again = run_sweep(GRID, &o).unwrap();
    assert_eq!(again.planned, POINTS);
    assert_eq!(again.skipped, POINTS);
    assert_eq!(again.ran, 0, "--resume must re-run zero completed points");
    assert_eq!(again.failed, 0);
    assert_eq!(read_rows(&out).len(), POINTS);
}

#[test]
fn partial_file_resumes_only_the_remainder() {
    // simulate a killed sweep: run a sub-grid first, then resume the full
    // grid — only the missing points execute
    let out = std::env::temp_dir().join("owf_sweep_resume_partial.jsonl");
    let _ = std::fs::remove_file(&out);
    let sub = "{int,cbrt-t5}@{2..8}:block64-absmax"; // 14 of the 105
    let first = run_sweep(sub, &opts(out.clone())).unwrap();
    assert_eq!(first.ran, 14);

    let mut o = opts(out.clone());
    o.resume = true;
    let rest = run_sweep(GRID, &o).unwrap();
    assert_eq!(rest.planned, POINTS);
    assert_eq!(rest.skipped, 14);
    assert_eq!(rest.ran, POINTS - 14);
    assert_eq!(read_rows(&out).len(), POINTS);

    // idempotent third pass
    let done = run_sweep(GRID, &o).unwrap();
    assert_eq!(done.ran, 0);
    assert_eq!(done.skipped, POINTS);
}

#[test]
fn seeds_are_part_of_the_resume_key() {
    let out = std::env::temp_dir().join("owf_sweep_resume_seeds.jsonl");
    let _ = std::fs::remove_file(&out);
    let grid = "cbrt-t5@{3,4}:block64-absmax";
    let one_seed = opts(out.clone());
    run_sweep(grid, &one_seed).unwrap();

    // asking for 3 seeds with resume runs only the 2 new seeds per spec
    let mut o = opts(out.clone());
    o.resume = true;
    o.seeds = 3;
    let stats = run_sweep(grid, &o).unwrap();
    assert_eq!(stats.planned, 6);
    assert_eq!(stats.skipped, 2);
    assert_eq!(stats.ran, 4);
    assert_eq!(read_rows(&out).len(), 6);
}
