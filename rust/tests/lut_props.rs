//! Property tests for the LUT quantisation kernel: the uniform-bucket
//! lookup path must agree *bit-exactly* with the reference compare-count /
//! binary-search path for every format family and for adversarial inputs
//! (±inf, NaN, exact midpoints, subnormals) — the contract documented in
//! `rust/src/formats/` module docs.

use owf::dist::{Dist, Family};
use owf::formats::cbrt::{cbrt_absmax, cbrt_rms, CBRT_ALPHA};
use owf::formats::float::float_codebook_normalised;
use owf::formats::int::int_codebook;
use owf::formats::lloyd::{LloydInit, LloydMax};
use owf::formats::quantile::{af4, nf, nf4, sf};
use owf::formats::{Codebook, Variant};
use owf::util::rng::Rng;
use owf::util::testing::{check, Gen};

fn assert_paths_agree(cb: &Codebook, ys: &[f32], label: &str) {
    for &y in ys {
        let (fast, reference) = (cb.quantise(y), cb.quantise_ref(y));
        assert_eq!(
            fast, reference,
            "{label}: LUT {fast} != reference {reference} at y={y:?} (bits {:#010x})",
            y.to_bits()
        );
        // the index must be in range whatever the input
        assert!((fast as usize) < cb.len(), "{label}: index out of range");
    }
    // batch entry point takes the same path
    let (mut a, mut b) = (Vec::new(), Vec::new());
    cb.quantise_slice(ys, &mut a);
    let plain = cb.clone().with_lut_disabled();
    plain.quantise_slice(ys, &mut b);
    assert_eq!(a, b, "{label}: quantise_slice disagrees with reference");
}

#[test]
fn lut_matches_reference_for_every_format_family() {
    let mut rng = Rng::new(0x10f);
    let fit_data = Dist::standard(Family::StudentT, 5.0)
        .sample_vec(&mut rng, 4096);
    // (label, codebook, lut_expected): families with midpoint gaps finer
    // than the 2^16-bucket budget (high-exponent minifloats) legitimately
    // keep the reference path — the equality contract still holds.
    let mut books: Vec<(String, Codebook, bool)> = Vec::new();
    for b in 2..=6u32 {
        for v in [Variant::Symmetric, Variant::Asymmetric] {
            if b <= 8 {
                books.push((
                    format!("int{b}-{}", v.name()),
                    int_codebook(b, v),
                    true,
                ));
            }
        }
        books.push((format!("int{b}-signmax"), int_codebook(b, Variant::Signmax), true));
        books.push((format!("nf{b}"), nf(b), true));
        books.push((format!("sf{b}-t5"), sf(b, 5.0), true));
        books.push((
            format!("cbrt-normal-rms{b}"),
            cbrt_rms(Family::Normal, 0.0, b, Variant::Symmetric, CBRT_ALPHA),
            true,
        ));
        books.push((
            format!("cbrt-t5-absmax{b}"),
            cbrt_absmax(
                Family::StudentT,
                5.0,
                b,
                128,
                Variant::Symmetric,
                CBRT_ALPHA,
            ),
            true,
        ));
        books.push((
            format!("lloyd{b}"),
            LloydMax::new(b, LloydInit::KmeansPp).fit(&fit_data, &[]),
            false, // data-driven centroids may cluster arbitrarily close
        ));
    }
    books.push(("nf4-published".into(), nf4(), true));
    books.push(("af4-64".into(), af4(64), true));
    for (e, m, expect_lut) in [
        (2u32, 1u32, true),
        (3, 0, true),
        (3, 2, true),
        (4, 3, false), // subnormal gap ≈ 4e-6 of the span: over budget
        (5, 2, false),
    ] {
        books.push((
            format!("e{e}m{m}"),
            float_codebook_normalised(e, m),
            expect_lut,
        ));
    }

    let mut probe_rng = Rng::new(0x10f2);
    for (label, cb, expect_lut) in &books {
        if *expect_lut {
            assert!(cb.has_lut(), "{label}: expected the LUT fast path");
        }
        let mut ys = cb.adversarial_probes();
        for _ in 0..512 {
            ys.push(probe_rng.normal() as f32 * 1.5);
        }
        assert_paths_agree(cb, &ys, label);
    }
}

#[test]
fn lut_matches_reference_for_random_codebooks() {
    check("lut-random-codebooks", 200, |g: &mut Gen| {
        // sizes straddle the compare-count/binary-search switch at 32
        let n = 2 + g.rng.below(80);
        // occasional extreme scales exercise the LUT bail-out paths
        let scale = match g.case % 5 {
            0 => 1e-38,
            1 => 1e30,
            _ => 2.0,
        };
        let pts = g.f32_vec(n, scale);
        let cb = Codebook::new(pts);
        let mut ys = cb.adversarial_probes();
        ys.extend(g.f32_vec(128, scale * 1.5));
        ys.extend(g.f32_vec(32, 1.0));
        for &y in &ys {
            assert_eq!(
                cb.quantise(y),
                cb.quantise_ref(y),
                "n={n} scale={scale} y={y:?}"
            );
        }
    });
}

#[test]
fn lut_quantise_is_nearest_codepoint() {
    // beyond path agreement: the result must actually be a nearest
    // codepoint (ties allowed either side of the midpoint rule are pinned
    // by the reference equality above, so plain nearest-ness suffices)
    check("lut-nearest", 100, |g: &mut Gen| {
        let n = 2 + g.rng.below(40);
        let cb = Codebook::new(g.f32_vec(n, 2.0));
        for _ in 0..64 {
            let y = g.rng.normal() as f32 * 3.0;
            let idx = cb.quantise(y) as usize;
            let d = (cb.points()[idx] - y).abs();
            for &p in cb.points() {
                assert!(
                    d <= (p - y).abs() + 1e-5 * d.max(1.0),
                    "idx {idx} not nearest for y={y}"
                );
            }
        }
    });
}
