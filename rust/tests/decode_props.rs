//! Property tests for the serving-scale decode engine:
//!
//! * `decode_into` (fused, parallel) ≡ `decode_ref` (scalar oracle) ≡ the
//!   fused `qdq` across every format family — int / float / cbrt /
//!   quantile / lloyd — including adversarial data built from
//!   `Codebook::adversarial_probes` (±inf, NaN, subnormals, exact
//!   midpoints);
//! * the sparse-outlier overlay reconstructs identically through the fused
//!   scatter-back and the two-pass reference;
//! * K-lane interleaved Huffman / rANS roundtrips agree with the
//!   single-lane oracles for K ∈ {1, 2, 4, 8}, prefix ("short") decodes
//!   yield exactly the stream head, and torn containers panic instead of
//!   misreading.

use owf::compress::huffman::HuffmanCode;
use owf::compress::rans::{
    rans_decode_interleaved, rans_encode, rans_encode_interleaved, RansModel,
};
use owf::dist::Family;
use owf::formats::cbrt::{cbrt_absmax, cbrt_rms, CBRT_ALPHA};
use owf::formats::float::float_codebook_normalised;
use owf::formats::int::int_codebook;
use owf::formats::lloyd::{LloydInit, LloydMax};
use owf::formats::quantile::{af4, nf};
use owf::formats::{Codebook, Variant};
use owf::quant::outliers::{
    qdq_outliers_with_hist, qdq_with_outliers, OutlierCriterion,
    SparseOutliers,
};
use owf::quant::Quantiser;
use owf::scaling::{Granularity, ScaleFormat, Statistic, DEFAULT_SCALE};
use owf::util::testing::{check, Gen};

/// One codebook per format family (fit data for Lloyd drawn per call).
fn family_books(g: &mut Gen) -> Vec<(&'static str, Codebook, Statistic)> {
    let fit = g.heavy_tailed_vec(2048);
    vec![
        ("int4", int_codebook(4, Variant::Asymmetric), Statistic::Absmax),
        (
            "int4-signmax",
            int_codebook(4, Variant::Signmax),
            Statistic::Signmax,
        ),
        ("e2m1", float_codebook_normalised(2, 1), Statistic::Absmax),
        ("e5m2", float_codebook_normalised(5, 2), Statistic::Absmax),
        (
            "cbrt-t5",
            cbrt_absmax(
                Family::StudentT,
                5.0,
                4,
                64,
                Variant::Symmetric,
                CBRT_ALPHA,
            ),
            Statistic::Absmax,
        ),
        (
            "cbrt-normal-rms",
            cbrt_rms(Family::Normal, 0.0, 4, Variant::Symmetric, CBRT_ALPHA),
            Statistic::Rms,
        ),
        ("nf4", nf(4), Statistic::Absmax),
        ("af4", af4(64), Statistic::Absmax),
        (
            "lloyd4",
            LloydMax::new(4, LloydInit::KmeansPp).fit(&fit, &[]),
            Statistic::Rms,
        ),
    ]
}

#[test]
fn decode_into_matches_ref_and_qdq_across_families() {
    check("decode-parity-families", 30, |g: &mut Gen| {
        let n = 64 * (1 + g.rng.below(6));
        let base = g.heavy_tailed_vec(n);
        for (name, cb, stat) in family_books(g) {
            // adversarial data: the codebook's own probe set (specials,
            // exact midpoints, ULP neighbours) spliced over a random tail
            let mut data = base.clone();
            for (slot, probe) in
                data.iter_mut().zip(cb.adversarial_probes())
            {
                *slot = probe;
            }
            for granularity in
                [Granularity::Block(64), Granularity::Tensor]
            {
                let q = Quantiser::new(
                    granularity,
                    stat,
                    DEFAULT_SCALE,
                    cb.clone(),
                );
                let (enc, _) = q.encode_with_stats(&data, 0);
                let reference = q.decode_ref(&enc);
                let mut fused = vec![0f32; n];
                q.decode_into(&enc, &mut fused);
                let fused_bits: Vec<u32> =
                    fused.iter().map(|x| x.to_bits()).collect();
                let ref_bits: Vec<u32> =
                    reference.iter().map(|x| x.to_bits()).collect();
                assert_eq!(
                    fused_bits, ref_bits,
                    "{name} {granularity:?}: decode_into != decode_ref"
                );
                let qdq_bits: Vec<u32> = q
                    .qdq(&data, 0)
                    .iter()
                    .map(|x| x.to_bits())
                    .collect();
                assert_eq!(
                    fused_bits, qdq_bits,
                    "{name} {granularity:?}: decode_into != qdq"
                );
                // decode() is the same kernel behind an allocation
                let alloc_bits: Vec<u32> = q
                    .decode(&enc)
                    .iter()
                    .map(|x| x.to_bits())
                    .collect();
                assert_eq!(fused_bits, alloc_bits);
            }
        }
    });
}

#[test]
fn decode_parallel_path_is_bit_identical() {
    // big enough to fan out; the nested-parallelism guard forces the
    // serial path for the comparison run
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xDEC0DE),
        case: 0,
    };
    let data = g.heavy_tailed_vec(1 << 17);
    for granularity in [Granularity::Block(128), Granularity::Tensor] {
        let q = Quantiser::new(
            granularity,
            Statistic::Absmax,
            ScaleFormat::Bf16 { away: true },
            int_codebook(4, Variant::Asymmetric),
        );
        let enc = q.encode(&data, 0);
        let mut par = vec![0f32; data.len()];
        q.decode_into(&enc, &mut par);
        let serial = owf::util::pool::par_map(&[0, 1], |i, _| {
            (i == 0).then(|| {
                let mut out = vec![0f32; data.len()];
                q.decode_into(&enc, &mut out);
                out
            })
        })
        .swap_remove(0)
        .unwrap();
        assert_eq!(par, serial, "{granularity:?}");
        assert_eq!(par, q.decode_ref(&enc), "{granularity:?}");
    }
}

#[test]
fn sparse_overlay_fused_matches_two_pass() {
    check("sparse-decode-parity", 25, |g: &mut Gen| {
        let n = 256 * (1 + g.rng.below(8));
        let mut data = g.heavy_tailed_vec(n);
        // spike a few elements so selection is non-trivial
        for k in 0..4 {
            let at = g.rng.below(n);
            data[at] = 80.0 * if k % 2 == 0 { 1.0 } else { -1.0 };
        }
        let criterion = if g.rng.below(2) == 0 {
            OutlierCriterion::AbsValue
        } else {
            OutlierCriterion::FisherWeighted
        };
        let fisher: Vec<f32> = if criterion
            == OutlierCriterion::FisherWeighted
        {
            g.f32_vec(n, 1.0).iter().map(|x| x.abs()).collect()
        } else {
            Vec::new()
        };
        let sparse = SparseOutliers {
            fraction: [0.0, 1e-3, 0.01][g.rng.below(3)],
            criterion,
        };
        let q = Quantiser::new(
            Granularity::Block(64),
            Statistic::Absmax,
            DEFAULT_SCALE,
            int_codebook(4, Variant::Asymmetric),
        );
        let (fused, bits_f, counts) =
            qdq_outliers_with_hist(&q, &sparse, &data, &fisher, 0);
        let (two_pass, bits_t) =
            qdq_with_outliers(&q, &sparse, &data, &fisher, 0);
        assert_eq!(fused, two_pass);
        assert_eq!(bits_f, bits_t);
        assert_eq!(counts.iter().sum::<u64>() as usize, n);
        // every selected outlier is reconstructed exactly
        for &i in &sparse.select(&data, &fisher) {
            assert_eq!(fused[i as usize], data[i as usize]);
        }
    });
}

/// Random counts mixing zeros, singletons and heavy spikes.
fn random_counts(g: &mut Gen, n_symbols: usize) -> Vec<u64> {
    (0..n_symbols)
        .map(|_| match g.rng.below(4) {
            0 => 0,
            1 => 1,
            2 => g.rng.below(50) as u64 + 1,
            _ => g.rng.below(100_000) as u64 + 1,
        })
        .collect()
}

fn stream(counts: &[u64], len: usize, g: &mut Gen) -> Vec<u16> {
    let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    (0..len)
        .map(|_| g.rng.categorical(&weights) as u16)
        .collect()
}

#[test]
fn huffman_interleaved_equals_single_lane_for_all_k() {
    check("huffman-lanes", 40, |g: &mut Gen| {
        let n_symbols = 2 + g.rng.below(40);
        let mut counts = random_counts(g, n_symbols);
        if counts.iter().all(|&c| c == 0) {
            counts[0] = 1;
        }
        let code = HuffmanCode::from_counts(&counts);
        let len = g.rng.below(1500);
        let symbols = stream(&counts, len, g);
        let (bytes, _) = code.encode(&symbols);
        let oracle = code.decode(&bytes, len);
        assert_eq!(oracle, symbols);
        for lanes in [1usize, 2, 4, 8] {
            let container = code.encode_interleaved(&symbols, lanes);
            assert_eq!(
                code.decode_interleaved(&container, len),
                oracle,
                "K={lanes}"
            );
            // short stream: prefix decode returns exactly the head
            let short = len / 2;
            assert_eq!(
                code.decode_interleaved(&container, short),
                symbols[..short],
                "K={lanes} prefix"
            );
        }
    });
}

#[test]
fn rans_interleaved_equals_single_lane_for_all_k() {
    check("rans-lanes", 40, |g: &mut Gen| {
        let n_symbols = 2 + g.rng.below(40);
        let mut counts = random_counts(g, n_symbols);
        if counts.iter().all(|&c| c == 0) {
            counts[0] = 1;
        }
        let model = RansModel::from_counts(&counts);
        let len = g.rng.below(1500);
        let symbols = stream(&counts, len, g);
        let oracle_bytes = rans_encode(&model, &symbols);
        for lanes in [1usize, 2, 4, 8] {
            let container =
                rans_encode_interleaved(&model, &symbols, lanes);
            assert_eq!(
                rans_decode_interleaved(&model, &container, len),
                symbols,
                "K={lanes}"
            );
            let short = len / 2;
            assert_eq!(
                rans_decode_interleaved(&model, &container, short),
                symbols[..short],
                "K={lanes} prefix"
            );
        }
        // the K=1 container wraps the oracle payload byte for byte
        let one = rans_encode_interleaved(&model, &symbols, 1);
        assert_eq!(&one[1..], &oracle_bytes[..]);
    });
}

#[test]
fn torn_containers_panic_instead_of_misreading() {
    let counts = [500u64, 120, 40, 9, 2];
    let code = HuffmanCode::from_counts(&counts);
    let model = RansModel::from_counts(&counts);
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0x70A4),
        case: 0,
    };
    let symbols = stream(&counts, 400, &mut g);
    let hc = code.encode_interleaved(&symbols, 4);
    let rc = rans_encode_interleaved(&model, &symbols, 4);
    for cut in [0usize, 1, 3, 9, 16] {
        let h = hc[..cut.min(hc.len())].to_vec();
        let r = std::panic::catch_unwind(|| {
            code.decode_interleaved(&h, symbols.len())
        });
        assert!(r.is_err(), "huffman cut {cut} must panic");
        let rr = rc[..cut.min(rc.len())].to_vec();
        let r = std::panic::catch_unwind(|| {
            rans_decode_interleaved(&model, &rr, symbols.len())
        });
        assert!(r.is_err(), "rans cut {cut} must panic");
    }
    // cutting payload bytes (header intact) must also be detected
    let h_torn = hc[..hc.len() - 3].to_vec();
    let r = std::panic::catch_unwind(|| {
        code.decode_interleaved(&h_torn, symbols.len())
    });
    assert!(r.is_err(), "huffman payload tear must panic");
}

#[test]
fn end_to_end_quantise_entropy_code_decode_reconstruct() {
    // the full serving loop: fused encode → interleaved entropy coding →
    // interleaved decode → fused dequantise must reproduce the direct qdq
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xE2E),
        case: 0,
    };
    let data = g.heavy_tailed_vec(20_000);
    let q = Quantiser::new(
        Granularity::Block(128),
        Statistic::Absmax,
        DEFAULT_SCALE,
        int_codebook(4, Variant::Asymmetric),
    );
    let (enc, stats) = q.encode_with_stats(&data, 0);
    let code = HuffmanCode::from_counts(&stats.counts);
    let container = code.encode_interleaved(&enc.indices, 8);
    let decoded = code.decode_interleaved(&container, enc.indices.len());
    assert_eq!(decoded, enc.indices);
    let wire = Quantiser::new(
        q.granularity,
        q.statistic,
        q.scale_format,
        q.codebook.clone(),
    );
    let mut recon = vec![0f32; data.len()];
    wire.decode_into(
        &owf::quant::Encoded {
            scales: enc.scales.clone(),
            indices: decoded,
            groups: enc.groups.clone(),
        },
        &mut recon,
    );
    assert_eq!(recon, q.qdq(&data, 0));
}
