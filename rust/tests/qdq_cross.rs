//! Three-way cross-validation of the fused block qdq:
//!
//!     Rust quant::Quantiser  ==  Pallas kernel (lowered HLO via PJRT)
//!
//! (the Python side already asserts pallas == pure-jnp oracle), closing the
//! loop across all three layers. Skips gracefully when artifacts are absent.

use owf::formats::cbrt::{cbrt_absmax, CBRT_ALPHA};
use owf::formats::int::int_codebook;
use owf::formats::Variant;
use owf::quant::Quantiser;
use owf::runtime::{Runtime, Value};
use owf::scaling::{Granularity, ScaleFormat, Statistic};
use owf::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    Runtime::open_default().ok()
}

fn cross_check(mode: &str, codebook: owf::formats::Codebook, seed: u64) {
    let Some(rt) = runtime() else { return };
    let artifact = format!("qdq_block_{mode}");
    let info = rt.artifact(&artifact).unwrap().clone();
    let n_blocks = info.inputs[0].shape[0];
    let block = info.inputs[0].shape[1];
    let k = info.inputs[1].numel();

    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n_blocks * block)
        .map(|_| rng.student_t(5.0) as f32)
        .collect();
    // pad the codebook to the artifact's LUT width by duplication
    let mut cb_points = codebook.points().to_vec();
    while cb_points.len() < k {
        cb_points.push(*cb_points.last().unwrap());
    }
    cb_points.sort_by(|a, b| a.total_cmp(b));

    // L1 via PJRT
    let out = rt
        .execute_f32(&artifact, &[Value::F32(&x), Value::F32(&cb_points)])
        .unwrap();
    let pallas = &out[0];

    // L3 native
    let statistic = if mode == "absmax" {
        Statistic::Absmax
    } else {
        Statistic::Rms
    };
    let quantiser = Quantiser::new(
        Granularity::Block(block),
        statistic,
        ScaleFormat::Bf16 { away: true },
        codebook,
    );
    let native = quantiser.qdq(&x, 0);

    let mut mismatches = 0usize;
    for (i, (a, b)) in pallas.iter().zip(&native).enumerate() {
        // reductions may differ by 1 ulp; a midpoint tie could flip a
        // codepoint (bounded by the local gap) — count real mismatches
        if (a - b).abs() > 1e-5 * a.abs().max(1.0) {
            mismatches += 1;
            assert!(
                mismatches < 5,
                "too many mismatches; first at {i}: pallas {a} vs rust {b}"
            );
        }
    }
    assert!(
        (mismatches as f64) < 1e-4 * native.len() as f64,
        "{mismatches} mismatches"
    );
}

#[test]
fn rust_matches_pallas_absmax_int4() {
    cross_check("absmax", int_codebook(4, Variant::Asymmetric), 1);
}

#[test]
fn rust_matches_pallas_absmax_cbrt() {
    cross_check(
        "absmax",
        cbrt_absmax(
            owf::dist::Family::StudentT,
            5.0,
            4,
            128,
            Variant::Symmetric,
            CBRT_ALPHA,
        ),
        2,
    );
}

#[test]
fn rust_matches_pallas_rms_int4() {
    cross_check("rms", int_codebook(4, Variant::Symmetric), 3);
}
