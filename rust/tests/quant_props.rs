//! Property tests for the quantiser invariants (via the crate's own
//! `util::testing::check` harness):
//!
//! * qdq idempotence — `qdq(qdq(x)) == qdq(x)` for absmax/signmax schemes
//!   with exact (f32) scales and ±1-endpoint codebooks;
//! * bits accounting — `bits_per_element` agrees with the sizes of the
//!   materialised [`owf::quant::Encoded`];
//! * scale-multiplier 1.0 is moment matching — `:mult1` is a no-op.

use owf::coordinator::config::Scheme;
use owf::eval::pipeline::qdq_tensor;
use owf::formats::cbrt::{cbrt_absmax, CBRT_ALPHA};
use owf::formats::int::int_codebook;
use owf::formats::quantile::nf;
use owf::formats::{Codebook, Variant};
use owf::quant::Quantiser;
use owf::scaling::{scale_overhead_bits, Granularity, ScaleFormat, Statistic};
use owf::util::testing::{check, Gen};

fn idempotence_codebooks() -> Vec<(&'static str, Codebook, Statistic)> {
    use owf::dist::Family;
    vec![
        (
            "int4-sym",
            int_codebook(4, Variant::Symmetric),
            Statistic::Absmax,
        ),
        (
            "int3-sym",
            int_codebook(3, Variant::Symmetric),
            Statistic::Absmax,
        ),
        (
            "int4-signmax",
            int_codebook(4, Variant::Signmax),
            Statistic::Signmax,
        ),
        ("nf4", nf(4), Statistic::Absmax),
        (
            "cbrt-normal-absmax",
            cbrt_absmax(Family::Normal, 0.0, 4, 64, Variant::Symmetric, CBRT_ALPHA),
            Statistic::Absmax,
        ),
        (
            "cbrt-t5-absmax",
            cbrt_absmax(Family::StudentT, 5.0, 4, 64, Variant::Symmetric, CBRT_ALPHA),
            Statistic::Absmax,
        ),
    ]
}

#[test]
fn qdq_is_idempotent_for_exact_absmax_scales() {
    // with an f32 scale and a ±1-endpoint codebook, the block maximum is
    // reconstructed exactly, so re-quantising the reconstruction recomputes
    // the same scale and maps every codepoint back onto itself
    check("qdq-idempotent", 80, |g: &mut Gen| {
        let n = 64 * (1 + g.rng.below(6));
        let data = g.heavy_tailed_vec(n);
        for (name, cb, stat) in idempotence_codebooks() {
            for granularity in
                [Granularity::Block(64), Granularity::Tensor]
            {
                let q = Quantiser::new(
                    granularity,
                    stat,
                    ScaleFormat::F32,
                    cb.clone(),
                );
                let once = q.qdq(&data, 0);
                let twice = q.qdq(&once, 0);
                assert_eq!(
                    once, twice,
                    "{name} {granularity:?} not idempotent"
                );
            }
        }
    });
}

#[test]
fn bits_accounting_matches_encoded_sizes() {
    // bits_per_element must equal (index bits + scale bits · #scales / n)
    // computed from the actual Encoded representation
    check("bits-accounting", 60, |g: &mut Gen| {
        let block = 16 << g.rng.below(4); // 16..128
        let n_blocks = 1 + g.rng.below(20);
        let n = block * n_blocks;
        let data = g.f32_vec(n, 1.0);
        let bits = g.bits(2, 6);
        let (stat, variant) = if g.rng.below(2) == 0 {
            (Statistic::Absmax, Variant::Symmetric)
        } else {
            (Statistic::Signmax, Variant::Signmax)
        };
        for scale_format in [
            ScaleFormat::F32,
            ScaleFormat::Bf16 { away: true },
            ScaleFormat::E8M0 { away: true },
        ] {
            let q = Quantiser::new(
                Granularity::Block(block),
                stat,
                scale_format,
                int_codebook(bits, variant),
            );
            let enc = q.encode(&data, 0);
            assert_eq!(enc.indices.len(), n);
            assert_eq!(enc.scales.len(), n_blocks);
            assert_eq!(enc.groups.len(), n_blocks);
            let sign = if stat == Statistic::Signmax { 1.0 } else { 0.0 };
            let expect = bits as f64
                + enc.scales.len() as f64 * (scale_format.bits() + sign)
                    / n as f64;
            let got = q.bits_per_element(n, 0);
            assert!(
                (got - expect).abs() < 1e-12,
                "bits {got} vs encoded-derived {expect}"
            );
            // and the helper the accounting is built on agrees
            let overhead = scale_overhead_bits(
                n,
                Granularity::Block(block),
                0,
                scale_format,
                stat,
            );
            assert!((got - bits as f64 - overhead).abs() < 1e-12);
        }
    });
}

#[test]
fn multiplier_one_is_moment_matching() {
    // `:mult1` must be byte-identical to the bare scheme through the whole
    // tensor pipeline (multiplier 1.0 is the moment-matching default)
    check("mult1-noop", 40, |g: &mut Gen| {
        let n = 128 * (1 + g.rng.below(4));
        let data = g.heavy_tailed_vec(n);
        for base in [
            "int@4:block64-absmax",
            "cbrt-t5@4:block128-absmax",
            "cbrt-normal@3:tensor-rms",
            "nf@4:block64-absmax",
        ] {
            let plain = Scheme::parse(base).unwrap();
            let mult1 =
                Scheme::parse(&format!("{base}:mult1")).unwrap();
            let a =
                qdq_tensor(&plain, &data, &[n], None, &[], 5).unwrap();
            let b =
                qdq_tensor(&mult1, &data, &[n], None, &[], 5).unwrap();
            assert_eq!(a.recon, b.recon, "{base}");
            assert_eq!(a.bits, b.bits, "{base}");
        }
    });
}

#[test]
fn decode_inverts_encode() {
    // decode(encode(x)) must equal the fused qdq for every granularity
    check("encode-decode-qdq", 50, |g: &mut Gen| {
        let n = 64 * (1 + g.rng.below(8));
        let data = g.heavy_tailed_vec(n);
        for granularity in [
            Granularity::Tensor,
            Granularity::Block(64),
            Granularity::Block(32),
        ] {
            let q = Quantiser::new(
                granularity,
                Statistic::Absmax,
                ScaleFormat::Bf16 { away: true },
                int_codebook(4, Variant::Asymmetric),
            );
            let enc = q.encode(&data, 0);
            assert_eq!(q.decode(&enc), q.qdq(&data, 0), "{granularity:?}");
        }
    });
}
