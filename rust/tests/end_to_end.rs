//! End-to-end integration over the whole stack: checkpoint → quantise →
//! PJRT forward → top-k KL, verifying the *monotone structure* the paper's
//! evaluation depends on. Skips gracefully when artifacts are absent.

use owf::coordinator::config::Scheme;
use owf::eval::llm::Env;
use owf::eval::RunOpts;

fn env() -> Option<Env> {
    let opts = RunOpts {
        eval_seqs: 8,
        ..Default::default()
    };
    Env::open(opts).ok()
}

#[test]
fn kl_decreases_with_bits() {
    let Some(mut env) = env() else { return };
    let mut prev = f64::INFINITY;
    for b in [2u32, 4, 6] {
        let scheme =
            Scheme::parse(&format!("cbrt-t7@{b}:block128-absmax")).unwrap();
        let p = env.direct_cast("s", &scheme, None, false).unwrap();
        assert!(
            p.kl.mean < prev,
            "KL must fall with bits: b={b} kl={} prev={prev}",
            p.kl.mean
        );
        assert!(p.kl.mean >= 0.0);
        prev = p.kl.mean;
    }
    // 8-bit quantisation should be near-lossless
    let scheme = Scheme::parse("int@8:block64-absmax").unwrap();
    let p = env.direct_cast("s", &scheme, None, false).unwrap();
    assert!(p.kl.mean < 1e-3, "8-bit KL {}", p.kl.mean);
}

#[test]
fn variable_length_beats_fixed_length_on_llm() {
    // the paper's headline claim, end to end on a real (micro) checkpoint
    let Some(mut env) = env() else { return };
    let fixed = env
        .direct_cast(
            "s",
            &Scheme::parse("cbrt-t7@4:tensor-rms").unwrap(),
            None,
            false,
        )
        .unwrap();
    let block = env
        .direct_cast(
            "s",
            &Scheme::parse("cbrt-t7@4:block128-absmax").unwrap(),
            None,
            false,
        )
        .unwrap();
    let compress = env
        .direct_cast(
            "s",
            &Scheme::parse("grid@4:tensor-rms:compress").unwrap(),
            None,
            false,
        )
        .unwrap();
    assert!(
        block.kl.mean < fixed.kl.mean,
        "block absmax {} should beat tensor RMS {}",
        block.kl.mean,
        fixed.kl.mean
    );
    assert!(
        compress.kl.mean < fixed.kl.mean,
        "compression {} should beat fixed-length {}",
        compress.kl.mean,
        fixed.kl.mean
    );
}

#[test]
fn quantise_params_bits_accounting() {
    let Some(mut env) = env() else { return };
    let scheme = Scheme::parse("int@4:block128-absmax").unwrap();
    let (params, bits, r) = env.quantise("s", &scheme, None, false).unwrap();
    // 4 bits + 16/128 scale (small 1-D tensors have partial blocks, so a
    // hair above the ideal 4.125)
    assert!((bits - 4.125).abs() < 0.01, "bits {bits}");
    assert!(r > 0.0 && r < 1.0, "R {r}");
    // every tensor reconstructed with the right length
    let ck = env.checkpoint("s").unwrap();
    for t in &ck.store.tensors {
        assert_eq!(params[&t.name].len(), t.numel());
    }
}

#[test]
fn fisher_weighted_outliers_run() {
    let Some(mut env) = env() else { return };
    let scheme =
        Scheme::parse("cbrt-t7@3:tensor-rms:sparse0.001").unwrap();
    let plain = env.direct_cast("s", &scheme, None, false).unwrap();
    let fisher = env.direct_cast("s", &scheme, None, true).unwrap();
    // both valid; Fisher-weighted selection must at least produce a
    // finite, comparable result (the paper finds it helps on average)
    assert!(plain.kl.mean.is_finite() && fisher.kl.mean.is_finite());
}

#[test]
fn allocation_end_to_end() {
    let Some(mut env) = env() else { return };
    let infos = env.tensor_infos("s").unwrap();
    let alloc = owf::alloc::variable_allocation(&infos, 4.0);
    let rounded = owf::alloc::round_allocation(&infos, &alloc, 4.0);
    assert!(rounded.average <= 4.0 + 1e-9);
    let map: std::collections::HashMap<String, f64> = infos
        .iter()
        .zip(&rounded.bits)
        .map(|(t, &b)| (t.name.clone(), b))
        .collect();
    let scheme = Scheme::parse("cbrt-t7@4:block128-absmax").unwrap();
    let p = env.direct_cast("s", &scheme, Some(&map), false).unwrap();
    assert!(p.kl.mean.is_finite());
    // the realised average must respect the budget (+ scale overhead)
    assert!(p.bits <= 4.0 + 0.125 + 0.05, "bits {}", p.bits);
}
