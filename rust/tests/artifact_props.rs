//! Property tests for the `OWQ1`/`OWQ2` quantised-artifact store:
//!
//! * `encode_tensor` (the pack path) produces reconstructions, bits and
//!   sq-err **bit-identical** to `qdq_tensor` (the in-memory pipeline)
//!   across format families, granularities, sparse overlays, rotation,
//!   grid schemes and the multiplier search;
//! * pack → open → decode round-trips bit-exactly for every codec
//!   (raw / interleaved Huffman / interleaved rANS) and lane count, and
//!   the stored sq-err/bits fields match the pipeline's to the last bit;
//! * `:rot` and `grid` specs — rejected by the v1 writer — pack into
//!   OWQ2 containers whose decode matches the in-memory pipeline to the
//!   last f64 bit (seed re-derivation, inverse rotation, dense-index
//!   gather);
//! * a byte-level version-1 manifest still opens and decodes (the v2
//!   reader is backward compatible), and unknown future revs are
//!   rejected;
//! * non-packable tensors are recorded as skipped in the summary and
//!   the manifest instead of vanishing silently;
//! * the variable (eq. 5) allocation is recorded in the manifest and
//!   applied per tensor;
//! * truncated, torn and checksum-corrupted containers are rejected
//!   instead of misread (the `decode_props.rs` adversarial style);
//! * `ArtifactServer` serves concurrent readers bit-identically with
//!   coherent cache-hit statistics and strict-LRU eviction.

use std::collections::HashMap;

use owf::artifact::server::ArtifactServer;
use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
use owf::artifact::{fnv1a64, Artifact, Codec};
use owf::coordinator::config::{Element, Scheme};
use owf::eval::pipeline::{encode_tensor, qdq_tensor, qdq_tensor_mixed};
use owf::tensorstore::{Store, Tensor};
use owf::util::json::Json;
use owf::util::testing::{check, Gen};

/// A store mixing the shapes the pipeline cares about: a 2-D column-scaled
/// tensor with spikes (outlier + transpose coverage), a small-RMS 1-D
/// tensor, a large-RMS 2-D tensor and an all-zero tensor (degenerate
/// scales, single-symbol histograms).
fn test_store(g: &mut Gen) -> Store {
    let mut store = Store::new(Json::obj().push("kind", "test-source"));
    let mut a = g.heavy_tailed_vec(64 * 96);
    for k in 0..6 {
        let at = g.rng.below(a.len());
        a[at] = 60.0 * if k % 2 == 0 { 1.0 } else { -1.0 };
    }
    let mut t = Tensor::from_f32("a", vec![64, 96], &a);
    t.channel_axis = Some(1);
    store.push(t);
    let b: Vec<f32> =
        g.heavy_tailed_vec(4096).iter().map(|x| x * 0.01).collect();
    store.push(Tensor::from_f32("b", vec![4096], &b));
    let c: Vec<f32> =
        g.heavy_tailed_vec(32 * 128).iter().map(|x| x * 75.0).collect();
    let mut t = Tensor::from_f32("c", vec![32, 128], &c);
    t.channel_axis = Some(1);
    store.push(t);
    store.push(Tensor::from_f32("z", vec![256], &vec![0f32; 256]));
    store
}

fn assert_f32_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i}: {x:?} vs {y:?}"
        );
    }
}

/// The schemes the pack path must reproduce bit-exactly.
const SCHEMES: &[&str] = &[
    "int@4:block64-absmax",
    "int@3:tensor-absmax:compress",
    "cbrt-t5@4:block64-absmax:compress",
    "cbrt-t5@4:block64-absmax:sparse0.01,compress",
    "nf@4:block64-absmax:sparse0.01",
    "e2m1@4:channel-absmax",
    "int@4:block64-signmax",
    "lloyd@4:tensor-rms",
    "cbrt-normal@4:tensor-rms:search",
    "cbrt-normal@4:tensor-rms:rot",
    "int@4:block64-absmax:compress,rot",
    "grid@4:tensor-rms:compress",
    "grid@3:tensor-rms:search",
];

#[test]
fn encode_tensor_matches_qdq_tensor_bit_for_bit() {
    check("encode-tensor-parity", 8, |g: &mut Gen| {
        let store = test_store(g);
        for spec in SCHEMES {
            let scheme = Scheme::parse(spec).unwrap();
            for t in &store.tensors {
                let data = t.as_f32();
                let reference = qdq_tensor(
                    &scheme,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    0,
                )
                .unwrap();
                let et = encode_tensor(
                    &scheme,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    0,
                )
                .unwrap();
                assert_f32_bits_eq(
                    &et.recon,
                    &reference.recon,
                    &format!("{spec} on {}", t.name),
                );
                assert_eq!(
                    et.bits.to_bits(),
                    reference.bits.to_bits(),
                    "{spec} on {}: bits {} vs {}",
                    t.name,
                    et.bits,
                    reference.bits
                );
                assert_eq!(
                    et.sq_err.to_bits(),
                    reference.sq_err.to_bits(),
                    "{spec} on {}: sq_err {} vs {}",
                    t.name,
                    et.sq_err,
                    reference.sq_err
                );
            }
        }
    });
}

fn pack_opts(spec: &str, codec: Codec, lanes: usize) -> PackOptions {
    PackOptions {
        spec: spec.to_string(),
        alloc: AllocMode::Flat,
        codec,
        lanes,
        target_bits: None,
        meta: Json::obj().push("source", "test"),
    }
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("owf_artifact_props");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.owq", std::process::id()))
}

#[test]
fn pack_unpack_roundtrips_bit_exactly_for_every_codec() {
    check("pack-roundtrip", 5, |g: &mut Gen| {
        let store = test_store(g);
        let spec = "cbrt-t5@4:block64-absmax:sparse0.01,compress";
        for (codec, lanes) in [
            (Codec::Raw, 1),
            (Codec::Huffman, 1),
            (Codec::Huffman, 4),
            (Codec::Rans, 1),
            (Codec::Rans, 8),
        ] {
            let path = tmp_path(&format!(
                "rt_{}_{lanes}",
                codec.name()
            ));
            let summary = pack_store(
                &store,
                &HashMap::new(),
                &pack_opts(spec, codec, lanes),
                &path,
            )
            .unwrap();
            assert_eq!(summary.tensors, store.tensors.len());
            let art = Artifact::open(&path).unwrap();
            assert_eq!(art.codec, codec);
            assert_eq!(art.lanes, lanes);
            art.verify_all().unwrap();
            let scheme = Scheme::parse(spec).unwrap();
            for (i, rec) in art.tensors.iter().enumerate() {
                let t = store.require(&rec.name).unwrap();
                let data = t.as_f32();
                let reference = qdq_tensor(
                    &scheme,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    0,
                )
                .unwrap();
                let decoded = art.decode_tensor(i).unwrap();
                assert_f32_bits_eq(
                    &decoded,
                    &reference.recon,
                    &format!("{} x{lanes} on {}", codec.name(), rec.name),
                );
                assert_eq!(
                    rec.sq_err.to_bits(),
                    reference.sq_err.to_bits(),
                    "{}: stored sq_err",
                    rec.name
                );
                assert_eq!(
                    rec.bits.to_bits(),
                    reference.bits.to_bits(),
                    "{}: stored bits",
                    rec.name
                );
                // decode into a caller-owned buffer is the same kernel
                let mut buf = vec![0f32; rec.n];
                art.decode_tensor_into(i, &mut buf).unwrap();
                assert_f32_bits_eq(&buf, &decoded, "decode_into");
            }
            std::fs::remove_file(&path).unwrap();
        }
    });
}

#[test]
fn variable_allocation_is_recorded_and_applied() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xA110C),
        case: 0,
    };
    // three tensors with 10^4-spread RMS so eq. (5) must differentiate
    let mut store = Store::new(Json::obj());
    for (name, scale) in [("lo", 0.01f32), ("mid", 1.0), ("hi", 100.0)] {
        let data: Vec<f32> = g
            .heavy_tailed_vec(64 * 64)
            .iter()
            .map(|x| x * scale)
            .collect();
        let mut t = Tensor::from_f32(name, vec![64, 64], &data);
        t.channel_axis = Some(1);
        store.push(t);
    }
    let path = tmp_path("alloc");
    let opts = PackOptions {
        spec: "int@4:block64-absmax:compress".to_string(),
        alloc: AllocMode::Variable,
        codec: Codec::Huffman,
        lanes: 4,
        target_bits: None,
        meta: Json::obj().push("source", "test"),
    };
    pack_store(&store, &HashMap::new(), &opts, &path).unwrap();
    let art = Artifact::open(&path).unwrap();
    let alloc = art.alloc.as_ref().expect("alloc record missing");
    assert_eq!(alloc.scheme, "variable");
    assert_eq!(alloc.bits.len(), 3);
    let max = alloc.bits.iter().fold(f64::MIN, |m, &b| m.max(b));
    let min = alloc.bits.iter().fold(f64::MAX, |m, &b| m.min(b));
    assert!(
        max > min,
        "RMS spread must induce unequal bits: {:?}",
        alloc.bits
    );
    // integral bits (round_allocation), average within the budget
    let total: f64 = art.tensors.iter().map(|r| r.n as f64).sum();
    let avg: f64 = art
        .tensors
        .iter()
        .zip(&alloc.bits)
        .map(|(r, &b)| b * r.n as f64)
        .sum::<f64>()
        / total;
    assert!(avg <= 4.0 + 1e-9, "avg {avg}");
    for (rec, &b) in art.tensors.iter().zip(&alloc.bits) {
        assert_eq!(b.fract(), 0.0, "{}: non-integral bits", rec.name);
        let s = Scheme::parse(&rec.spec).unwrap();
        assert_eq!(s.bits, b, "{}: spec bits != alloc bits", rec.name);
        // the per-tensor spec reproduces the packed reconstruction
        let t = store.require(&rec.name).unwrap();
        let reference = qdq_tensor(
            &s,
            &t.as_f32(),
            &t.shape,
            t.channel_axis,
            &[],
            0,
        )
        .unwrap();
        let i = art.position(&rec.name).unwrap();
        assert_f32_bits_eq(
            &art.decode_tensor(i).unwrap(),
            &reference.recon,
            &rec.name,
        );
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn truncated_torn_and_corrupted_containers_are_rejected() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0x70A2),
        case: 0,
    };
    let store = test_store(&mut g);
    let path = tmp_path("adversarial");
    pack_store(
        &store,
        &HashMap::new(),
        &pack_opts("cbrt-t5@4:block64-absmax:sparse0.01,compress", Codec::Huffman, 4),
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let full = Artifact::from_bytes(raw.clone()).unwrap();
    full.verify_all().unwrap();

    // every strict prefix must fail to open (bounds or checksums), never
    // silently decode
    let mlen = u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]) as usize;
    for cut in [
        0usize,
        3,
        7,
        8 + mlen / 2,     // mid-manifest
        8 + mlen + 4,     // mid manifest-checksum
        8 + mlen + 8 + 1, // one payload byte
        raw.len() * 2 / 3,
        raw.len() - 1,
    ] {
        let torn = raw[..cut].to_vec();
        assert!(
            Artifact::from_bytes(torn).is_err(),
            "cut at {cut} must be rejected"
        );
    }

    // a flipped manifest byte fails the header checksum at open
    let mut bad = raw.clone();
    bad[10] ^= 0x40;
    assert!(
        Artifact::from_bytes(bad).is_err(),
        "manifest corruption must fail at open"
    );

    // a flipped payload byte inside a section opens fine (bounds intact)
    // but fails that tensor's checksum at decode / verify
    let base = 8 + mlen + 8;
    let first_payload = &full.tensors[0].payload;
    let mut bad = raw.clone();
    bad[base + first_payload.off + first_payload.len / 2] ^= 0x01;
    let art = Artifact::from_bytes(bad).unwrap();
    assert!(art.verify_all().is_err(), "verify_all must catch bit rot");
    assert!(
        art.decode_tensor(0).is_err(),
        "decoding the corrupted tensor must fail"
    );
    // untouched tensors still decode
    assert!(art.decode_tensor(1).is_ok());

    // not-our-magic
    assert!(Artifact::from_bytes(b"OWT1....rest".to_vec()).is_err());
}

/// The v1 writer rejected `:rot` and `grid` outright; the v2 container
/// must pack both and decode them bit-identically to the in-memory
/// pipeline, with the rotation seed re-derived from the manifest and
/// grid indices gathered through the dense codepoint table.
#[test]
fn pack_roundtrips_rot_and_grid_schemes() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xBAD),
        case: 0,
    };
    let store = test_store(&mut g);
    for (k, (spec, codec, lanes)) in [
        ("cbrt-normal@4:tensor-rms:rot", Codec::Huffman, 4),
        ("cbrt-t5@4:block64-absmax:sparse0.01,compress,rot", Codec::Rans, 2),
        ("int@4:block64-signmax:rot", Codec::Raw, 1),
        ("grid@4:tensor-rms:compress", Codec::Huffman, 4),
        ("grid@4:tensor-rms:compress", Codec::Rans, 1),
        ("grid@3:tensor-rms:search", Codec::Raw, 1),
    ]
    .into_iter()
    .enumerate()
    {
        let path = tmp_path(&format!("rotgrid_{k}"));
        let summary = pack_store(
            &store,
            &HashMap::new(),
            &pack_opts(spec, codec, lanes),
            &path,
        )
        .unwrap_or_else(|e| panic!("{spec} must pack: {e}"));
        assert_eq!(summary.tensors, store.tensors.len());
        assert!(summary.skipped.is_empty());
        let art = Artifact::open(&path).unwrap();
        assert_eq!(art.version, owf::artifact::VERSION);
        art.verify_all().unwrap();
        for (i, rec) in art.tensors.iter().enumerate() {
            let t = store.require(&rec.name).unwrap();
            let scheme = Scheme::parse(&rec.spec).unwrap();
            // the writer derives the seed from the tensor name; only
            // tensors that were actually rotated (2-D under `:rot`)
            // carry it — everything else is a recorded identity
            if scheme.rotate && t.shape.len() == 2 {
                assert_eq!(
                    rec.rot_seed,
                    Some(fnv1a64(rec.name.as_bytes())),
                    "{spec} on {}: rot seed",
                    rec.name
                );
            } else {
                assert!(
                    rec.rot_seed.is_none(),
                    "{spec} on {}: spurious rot seed",
                    rec.name
                );
            }
            assert_eq!(
                scheme.element == Element::Grid,
                rec.grid.is_some(),
                "{spec} on {}: grid record presence",
                rec.name
            );
            let reference = qdq_tensor(
                &scheme,
                &t.as_f32(),
                &t.shape,
                t.channel_axis,
                &[],
                rec.rot_seed.unwrap_or(0),
            )
            .unwrap();
            let decoded = art.decode_tensor(i).unwrap();
            assert_f32_bits_eq(
                &decoded,
                &reference.recon,
                &format!("{spec} {} x{lanes} on {}", codec.name(), rec.name),
            );
            assert_eq!(
                rec.sq_err.to_bits(),
                reference.sq_err.to_bits(),
                "{spec} on {}: stored sq_err",
                rec.name
            );
            assert_eq!(
                rec.bits.to_bits(),
                reference.bits.to_bits(),
                "{spec} on {}: stored bits",
                rec.name
            );
        }
        std::fs::remove_file(&path).unwrap();
    }
}

/// The v3 reader stays byte-level compatible with version-1 and
/// version-2 manifests (v1 never carried `rot_seed`/`grid`/`skipped`;
/// v2 never carried `mix`/`block_schemes` — a non-mixed v3 container is
/// byte-identical to a v2 one apart from the version field), and
/// refuses revs it does not know how to read.
#[test]
fn version_1_containers_still_read_and_future_revs_are_rejected() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0x1111),
        case: 0,
    };
    let store = test_store(&mut g);
    let path = tmp_path("v1compat");
    pack_store(
        &store,
        &HashMap::new(),
        &pack_opts("cbrt-t5@4:block64-absmax:sparse0.01,compress", Codec::Huffman, 4),
        &path,
    )
    .unwrap();
    let raw = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let expected: Vec<Vec<f32>> = {
        let art = Artifact::from_bytes(raw.clone()).unwrap();
        (0..art.tensors.len())
            .map(|i| art.decode_tensor(i).unwrap())
            .collect()
    };

    // patch the version field in place (same byte length) and restore
    // the manifest checksum — a byte-faithful older-rev container
    let reversion = |to: &str| -> Vec<u8> {
        let mlen =
            u32::from_le_bytes(raw[4..8].try_into().unwrap()) as usize;
        let manifest =
            std::str::from_utf8(&raw[8..8 + mlen]).unwrap().to_string();
        let patched = manifest.replace("\"version\":3", to);
        assert_ne!(patched, manifest, "manifest must carry version 3");
        assert_eq!(patched.len(), manifest.len());
        let mut out = raw.clone();
        out[8..8 + mlen].copy_from_slice(patched.as_bytes());
        out[8 + mlen..8 + mlen + 8]
            .copy_from_slice(&fnv1a64(patched.as_bytes()).to_le_bytes());
        out
    };

    for (label, want_version) in
        [("\"version\":1", 1u32), ("\"version\":2", 2)]
    {
        let art = Artifact::from_bytes(reversion(label)).unwrap();
        assert_eq!(art.version, want_version);
        assert!(art.skipped.is_empty());
        for (i, want) in expected.iter().enumerate() {
            assert_eq!(art.tensors[i].rot_seed, None);
            assert!(art.tensors[i].grid.is_none());
            assert!(art.tensors[i].mix.is_none());
            assert!(art.tensors[i].block_schemes.is_none());
            assert_f32_bits_eq(
                &art.decode_tensor(i).unwrap(),
                want,
                &format!("v{want_version} decode"),
            );
        }
    }

    let future = Artifact::from_bytes(reversion("\"version\":4"));
    assert!(future.is_err(), "future rev must be rejected");
    let msg = format!("{:?}", future.err().unwrap());
    assert!(
        msg.contains("unsupported OWQ version"),
        "wrong error: {msg}"
    );
}

/// Tensors the packer cannot carry (non-f32, empty) are recorded by
/// name in both the pack summary and the manifest, not silently
/// dropped.
#[test]
fn skipped_tensors_are_recorded_in_summary_and_manifest() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0x55),
        case: 0,
    };
    let mut store = test_store(&mut g);
    store.push(Tensor::from_i32("steps", vec![3], &[1, 2, 3]));
    store.push(Tensor::from_f32("hollow", vec![0], &[]));
    let path = tmp_path("skipped");
    let summary = pack_store(
        &store,
        &HashMap::new(),
        &pack_opts("int@4:block64-absmax:compress", Codec::Huffman, 4),
        &path,
    )
    .unwrap();
    assert_eq!(summary.tensors, store.tensors.len() - 2);
    assert_eq!(
        summary.skipped,
        vec!["steps".to_string(), "hollow".to_string()]
    );
    let art = Artifact::open(&path).unwrap();
    assert_eq!(art.skipped, summary.skipped);
    assert!(art.position("steps").is_none());
    assert!(art.position("hollow").is_none());
    std::fs::remove_file(&path).unwrap();
}

/// The fractional tier-1 acceptance gate: for every target budget the
/// issue names, `--alloc fractional` must (a) record an average within
/// 0.05 of the target in the manifest, (b) realise an element-weighted
/// per-tensor bits average within 0.05 of the target, and (c) decode
/// every tensor — pure or mixed — bit-identically to the in-memory
/// pipeline replayed from the manifest (specs + block assignment).
#[test]
fn fractional_pack_hits_budgets_and_decodes_bit_identically() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xF2AC),
        case: 0,
    };
    let store = test_store(&mut g);
    let mut mixed_seen = 0usize;
    for (k, target) in [2.5f64, 3.3, 4.7, 6.1].into_iter().enumerate() {
        let path = tmp_path(&format!("frac_{k}"));
        let opts = PackOptions {
            // non-compress int base: candidate bits are exactly k + 0.25
            // (16-bit block64 scales), so the budget arithmetic is exact
            spec: "int@4:block64-absmax".to_string(),
            alloc: AllocMode::Fractional,
            codec: Codec::Huffman,
            lanes: 4,
            target_bits: Some(target),
            meta: Json::obj().push("source", "test"),
        };
        pack_store(&store, &HashMap::new(), &opts, &path).unwrap();
        let art = Artifact::open(&path).unwrap();
        assert_eq!(art.version, owf::artifact::VERSION);
        art.verify_all().unwrap();

        let alloc = art.alloc.as_ref().expect("alloc record missing");
        assert_eq!(alloc.scheme, "fractional");
        assert!(
            (alloc.target - target).abs() < 1e-12,
            "recorded target {} vs {target}",
            alloc.target
        );
        assert!(
            (alloc.average - target).abs() < 0.05,
            "budget {target}: manifest average {} off target",
            alloc.average
        );
        // realised (honest, id-overhead-inclusive) average also lands
        let total: f64 =
            art.tensors.iter().map(|r| r.n as f64).sum();
        let realised: f64 = art
            .tensors
            .iter()
            .map(|r| r.bits * r.n as f64)
            .sum::<f64>()
            / total;
        assert!(
            (realised - target).abs() < 0.05,
            "budget {target}: realised average {realised} off target"
        );

        for (i, rec) in art.tensors.iter().enumerate() {
            let t = store.require(&rec.name).unwrap();
            let data = t.as_f32();
            let seed = rec.rot_seed.unwrap_or(0);
            let reference = if let Some(mix) = &rec.mix {
                mixed_seen += 1;
                let specs: Vec<Scheme> = mix
                    .specs
                    .iter()
                    .map(|s| Scheme::parse(s).unwrap())
                    .collect();
                let assign = art
                    .block_assignment(i)
                    .unwrap()
                    .expect("mixed tensor without block_schemes");
                qdq_tensor_mixed(
                    &specs,
                    &assign,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    seed,
                )
                .unwrap()
            } else {
                let s = Scheme::parse(&rec.spec).unwrap();
                assert_eq!(
                    s.bits.fract(),
                    0.0,
                    "{}: pure fractional tensors sit on the lattice",
                    rec.name
                );
                qdq_tensor(
                    &s,
                    &data,
                    &t.shape,
                    t.channel_axis,
                    &[],
                    seed,
                )
                .unwrap()
            };
            let decoded = art.decode_tensor(i).unwrap();
            assert_f32_bits_eq(
                &decoded,
                &reference.recon,
                &format!("budget {target} on {}", rec.name),
            );
            assert_eq!(
                rec.bits.to_bits(),
                reference.bits.to_bits(),
                "budget {target} on {}: stored bits",
                rec.name
            );
            assert_eq!(
                rec.sq_err.to_bits(),
                reference.sq_err.to_bits(),
                "budget {target} on {}: stored sq_err",
                rec.name
            );
        }
        std::fs::remove_file(&path).unwrap();
    }
    assert!(
        mixed_seen > 0,
        "at least one budget must realise a genuine block-level mix"
    );
}

/// Packing the same store at the same fractional budget twice produces
/// byte-identical containers — the block→scheme assignment is seeded by
/// the tensor name, not by any run state.
#[test]
fn fractional_pack_is_deterministic_across_runs() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xDE7),
        case: 0,
    };
    let store = test_store(&mut g);
    let opts = PackOptions {
        spec: "int@4:block64-absmax".to_string(),
        alloc: AllocMode::Fractional,
        codec: Codec::Rans,
        lanes: 4,
        target_bits: Some(3.3),
        meta: Json::obj().push("source", "test"),
    };
    let pa = tmp_path("det_a");
    let pb = tmp_path("det_b");
    pack_store(&store, &HashMap::new(), &opts, &pa).unwrap();
    pack_store(&store, &HashMap::new(), &opts, &pb).unwrap();
    let a = std::fs::read(&pa).unwrap();
    let b = std::fs::read(&pb).unwrap();
    assert_eq!(a, b, "re-pack must be byte-identical");
    // and the container genuinely contains a mixed tensor, so the
    // determinism claim covers the block_schemes stream too
    let art = Artifact::from_bytes(a).unwrap();
    assert!(
        art.tensors.iter().any(|r| r.mix.is_some()),
        "3.3-bit pack must mix at least one tensor"
    );
    std::fs::remove_file(&pa).unwrap();
    std::fs::remove_file(&pb).unwrap();
}

/// Fractional targets outside the measured hull range clamp to the
/// nearest endpoint and pack pure-lattice containers whose manifests
/// record the residual through `average` (≠ target).
#[test]
fn fractional_pack_clamps_out_of_range_budgets() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0xC1A),
        case: 0,
    };
    let store = test_store(&mut g);
    for (target, expect_le) in [(1.0f64, 3.0), (16.0, f64::MAX)] {
        let path = tmp_path(&format!("clamp_{target}"));
        let opts = PackOptions {
            spec: "int@4:block64-absmax".to_string(),
            alloc: AllocMode::Fractional,
            codec: Codec::Huffman,
            lanes: 4,
            target_bits: Some(target),
            meta: Json::obj().push("source", "test"),
        };
        pack_store(&store, &HashMap::new(), &opts, &path).unwrap();
        let art = Artifact::open(&path).unwrap();
        art.verify_all().unwrap();
        let alloc = art.alloc.as_ref().unwrap();
        assert!(
            (alloc.average - target).abs() > 0.05,
            "target {target}: clamping must leave a visible residual \
             (average {})",
            alloc.average
        );
        if expect_le.is_finite() {
            assert!(alloc.average <= expect_le);
        }
        // clamped packs are pure: every tensor pinned to a hull endpoint
        for rec in &art.tensors {
            assert!(rec.mix.is_none(), "{}: spurious mix", rec.name);
        }
        for i in 0..art.tensors.len() {
            art.decode_tensor(i).unwrap();
        }
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn server_concurrent_reads_are_bit_identical_with_coherent_stats() {
    let mut g = Gen {
        rng: owf::util::rng::Rng::new(0x5E17E),
        case: 0,
    };
    let store = test_store(&mut g);
    let path = tmp_path("server");
    pack_store(
        &store,
        &HashMap::new(),
        &pack_opts("cbrt-t5@4:block64-absmax:compress", Codec::Huffman, 4),
        &path,
    )
    .unwrap();
    let art = Artifact::open(&path).unwrap();
    let expected: Vec<(String, Vec<f32>)> = art
        .tensors
        .iter()
        .enumerate()
        .map(|(i, r)| (r.name.clone(), art.decode_tensor(i).unwrap()))
        .collect();
    let n_tensors = expected.len();

    let server = ArtifactServer::new(Artifact::open(&path).unwrap(), 1 << 30);
    let threads = 4;
    let per_thread = 16;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let server = &server;
            let expected = &expected;
            scope.spawn(move || {
                for i in 0..per_thread {
                    let (name, want) = &expected[(t + i) % expected.len()];
                    let got = server.get(name).unwrap();
                    assert_f32_bits_eq(&got, want, name);
                }
            });
        }
    });
    let s = server.stats();
    let total = (threads * per_thread) as u64;
    assert_eq!(s.requests, total);
    assert_eq!(s.hits + s.misses, total);
    // worst racing case: every thread misses each tensor once
    assert!(
        s.misses >= n_tensors as u64
            && s.misses <= (threads * n_tensors) as u64,
        "misses {} outside [{n_tensors}, {}]",
        s.misses,
        threads * n_tensors
    );
    assert!(s.hits > 0, "a warm cache must produce hits");
    assert_eq!(s.cached_tensors, n_tensors);
    assert_eq!(s.evictions, 0);
    assert_eq!(
        s.decoded_bytes % 4,
        0,
        "decoded bytes are whole f32s"
    );

    // cap 0 disables the cache: all misses
    let cold = ArtifactServer::new(Artifact::open(&path).unwrap(), 0);
    for _ in 0..3 {
        cold.get(&expected[0].0).unwrap();
    }
    let s = cold.stats();
    assert_eq!((s.requests, s.hits, s.misses), (3, 0, 3));
    assert_eq!(s.cached_tensors, 0);

    // a 1-byte cap holds exactly the most recent tensor and evicts the
    // rest in strict LRU order
    let tiny = ArtifactServer::new(Artifact::open(&path).unwrap(), 1);
    for (name, want) in &expected {
        let got = tiny.get(name).unwrap();
        assert_f32_bits_eq(&got, want, name);
    }
    let s = tiny.stats();
    assert_eq!(s.cached_tensors, 1);
    assert_eq!(s.evictions, n_tensors as u64 - 1);
    assert_eq!(s.hits, 0);

    // unknown tensors error cleanly
    assert!(server.get("nope").is_err());
    // params() hands the whole artifact to the eval harness
    let params = server.params().unwrap();
    assert_eq!(params.len(), n_tensors);
    for (name, want) in &expected {
        assert_f32_bits_eq(&params[name], want, name);
    }
    std::fs::remove_file(&path).unwrap();
}
