//! Shared timing harness for the benches (criterion is unavailable in the
//! offline registry, so this is a minimal warmup + repeated-measurement
//! harness printing criterion-style lines) plus the machine-readable
//! trajectory writer behind `scripts/bench.sh` (`BENCH_*.json`).

use std::time::Instant;

/// Shared element count for size-scalable benches: `OWF_BENCH_N` (must be
/// a multiple of 1024, as `scripts/check.sh`'s tiny-n gate and
/// `scripts/bench.sh quick` rely on), default 2^22.
#[allow(dead_code)]
pub fn bench_n() -> usize {
    let n: usize = std::env::var("OWF_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 22);
    assert!(n >= 1024 && n % 1024 == 0, "OWF_BENCH_N must be k·1024");
    n
}

/// Run `f` with warmup and `reps` timed repetitions; prints
/// `name  median  min..max  [throughput]` and returns the median seconds.
pub fn bench(name: &str, items_per_rep: Option<f64>, mut f: impl FnMut()) -> f64 {
    // warmup
    for _ in 0..2 {
        f();
    }
    let reps = 7;
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let median = times[reps / 2];
    let throughput = items_per_rep
        .map(|n| format!("  {:>10.1} Melem/s", n / median / 1e6))
        .unwrap_or_default();
    println!(
        "{name:<52} {:>9.3} ms  ({:.3}..{:.3} ms){throughput}",
        median * 1e3,
        times[0] * 1e3,
        times[reps - 1] * 1e3,
    );
    median
}

/// One recorded bench row: name, median seconds, optional items/rep for
/// the Melem/s figure.
#[allow(dead_code)]
pub struct Row {
    pub name: String,
    pub median_s: f64,
    pub items: Option<f64>,
}

/// [`bench`] that also appends to a trajectory row list.
#[allow(dead_code)]
pub fn bench_rec(
    rows: &mut Vec<Row>,
    name: &str,
    items_per_rep: Option<f64>,
    f: impl FnMut(),
) -> f64 {
    let median = bench(name, items_per_rep, f);
    rows.push(Row {
        name: name.to_string(),
        median_s: median,
        items: items_per_rep,
    });
    median
}

/// Write the machine-readable perf trajectory when `OWF_BENCH_JSON` names
/// a path: `{"bench": ..., ["n": ...,] "rows": [{"name", "median_ms",
/// "items", "melem_per_s"}, ...]}` — `scripts/bench.sh` points this at the
/// repo-root `BENCH_<bench>.json` files future PRs diff against.  Pass
/// `n: Some(..)` only when every row shares one element count; per-row
/// counts are always recorded as `items`.
#[allow(dead_code)]
pub fn write_bench_json(bench_name: &str, n: Option<usize>, rows: &[Row]) {
    let Ok(path) = std::env::var("OWF_BENCH_JSON") else {
        return;
    };
    use owf::util::json::Json;
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut obj = Json::obj()
                .push("name", r.name.as_str())
                .push("median_ms", r.median_s * 1e3);
            if let Some(items) = r.items {
                obj = obj
                    .push("items", items)
                    .push("melem_per_s", items / r.median_s / 1e6);
            }
            obj
        })
        .collect();
    let mut doc = Json::obj().push("bench", bench_name);
    if let Some(n) = n {
        doc = doc.push("n", n);
    }
    let doc = doc.push("rows", Json::Arr(rows_json));
    if let Err(e) = std::fs::write(&path, format!("{doc}\n")) {
        eprintln!("write_bench_json: cannot write {path}: {e}");
    } else {
        println!("bench trajectory written to {path}");
    }
}
