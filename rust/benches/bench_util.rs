//! Shared timing harness for the benches (criterion is unavailable in the
//! offline registry, so this is a minimal warmup + repeated-measurement
//! harness printing criterion-style lines and recording JSONL).

use std::time::Instant;

/// Run `f` with warmup and `reps` timed repetitions; prints
/// `name  median  min..max  [throughput]` and returns the median seconds.
pub fn bench(name: &str, items_per_rep: Option<f64>, mut f: impl FnMut()) -> f64 {
    // warmup
    for _ in 0..2 {
        f();
    }
    let reps = 7;
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let median = times[reps / 2];
    let throughput = items_per_rep
        .map(|n| format!("  {:>10.1} Melem/s", n / median / 1e6))
        .unwrap_or_default();
    println!(
        "{name:<52} {:>9.3} ms  ({:.3}..{:.3} ms){throughput}",
        median * 1e3,
        times[0] * 1e3,
        times[reps - 1] * 1e3,
    );
    median
}
