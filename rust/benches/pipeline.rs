//! Bench: end-to-end direct-cast of a full checkpoint (quantise every
//! tensor + PJRT forward + top-k KL) — the fig.-1 inner loop, and the
//! number EXPERIMENTS.md §Perf tracks for the whole stack — plus the
//! `owf sweep` engine over a simulated grid (pure CPU, always runs).
//!
//! The checkpoint benches require `make artifacts`; they exit quietly
//! otherwise.  Set `OWF_BENCH_JSON=<path>` (as `scripts/bench.sh` does)
//! to record the rows machine-readably.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench_rec, write_bench_json, Row};

use owf::coordinator::config::Scheme;
use owf::coordinator::{run_sweep, SweepOpts};
use owf::eval::llm::Env;
use owf::eval::RunOpts;

fn bench_sweep(rows: &mut Vec<Row>) {
    // 24 points × 2^16 samples through the full sweep engine (expansion,
    // scheduling over OWF_THREADS, JSONL streaming)
    let out = std::env::temp_dir().join("owf_bench_sweep.jsonl");
    let grid = "{int,cbrt-t5,nf}@{3..6}:block{64,128}-absmax";
    let opts = SweepOpts {
        out: out.clone(),
        samples: 1 << 16,
        ..Default::default()
    };
    let points = 3 * 4 * 2;
    bench_rec(
        rows,
        &format!("sweep sim {points}pt x 2^16"),
        Some((points * (1 << 16)) as f64),
        || {
            let stats = run_sweep(grid, &opts).unwrap();
            assert_eq!(stats.ran, points);
            std::hint::black_box(stats.ran);
        },
    );
    let _ = std::fs::remove_file(&out);
}

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Row> = Vec::new();
    bench_sweep(&mut rows);
    let opts = RunOpts {
        eval_seqs: 16,
        ..Default::default()
    };
    let Ok(mut env) = Env::open(opts) else {
        println!("artifacts missing; run `make artifacts` first");
        write_bench_json("pipeline", None, &rows);
        return Ok(());
    };
    for size in ["s", "m"] {
        let n_params = env.checkpoint(size)?.config.n_params;
        // warm the ref-logits cache so the bench isolates the test path
        env.ref_logits(size)?;
        for spec in [
            "cbrt-t7@4:block128-absmax",
            "grid@4:tensor-rms:compress",
        ] {
            let scheme = Scheme::parse(spec)?;
            bench_rec(
                &mut rows,
                &format!("direct-cast {size} {spec}"),
                Some(n_params as f64),
                || {
                    let p =
                        env.direct_cast(size, &scheme, None, false).unwrap();
                    std::hint::black_box(p.kl.mean);
                },
            );
        }
        // quantise-only (no PJRT) to split the cost
        let scheme = Scheme::parse("cbrt-t7@4:block128-absmax")?;
        bench_rec(
            &mut rows,
            &format!("quantise-only {size}"),
            Some(n_params as f64),
            || {
                let (p, _, _) =
                    env.quantise(size, &scheme, None, false).unwrap();
                std::hint::black_box(p.len());
            },
        );
    }
    write_bench_json("pipeline", None, &rows);
    Ok(())
}
