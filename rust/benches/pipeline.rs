//! Bench: end-to-end direct-cast of a full checkpoint (quantise every
//! tensor + PJRT forward + top-k KL) — the fig.-1 inner loop, and the
//! number EXPERIMENTS.md §Perf tracks for the whole stack — plus the
//! `owf sweep` engine over a simulated grid, the serving-scale tensor
//! decode rows (`[dec]` vs `[dec-ref]`) and the OWQ1 artifact round trip
//! (`[pack]` / `[unpack]`), the OWQ3 mixed-tensor decode (`[frac]`,
//! parity-gated against the in-memory mixed pipeline) plus the
//! contended serving path through the single-flight server
//! (`[get-coalesced]`; all pure CPU, always run).
//!
//! The checkpoint benches require `make artifacts`; they exit quietly
//! otherwise.  Set `OWF_BENCH_JSON=<path>` (as `scripts/bench.sh` does)
//! to record the rows machine-readably.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench_n, bench_rec, write_bench_json, Row};

use std::collections::HashMap;

use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
use owf::artifact::{Artifact, Codec};
use owf::coordinator::config::Scheme;
use owf::coordinator::{run_sweep, SweepOpts};
use owf::dist::{Dist, Family};
use owf::eval::llm::Env;
use owf::eval::RunOpts;
use owf::quant::Quantiser;
use owf::tensorstore::{Store, Tensor};
use owf::util::json::Json;
use owf::util::rng::Rng;

fn bench_sweep(rows: &mut Vec<Row>) {
    // 24 points × 2^16 samples through the full sweep engine (expansion,
    // scheduling over OWF_THREADS, JSONL streaming)
    let out = std::env::temp_dir().join("owf_bench_sweep.jsonl");
    let grid = "{int,cbrt-t5,nf}@{3..6}:block{64,128}-absmax";
    let opts = SweepOpts {
        out: out.clone(),
        samples: 1 << 16,
        ..Default::default()
    };
    let points = 3 * 4 * 2;
    bench_rec(
        rows,
        &format!("sweep sim {points}pt x 2^16"),
        Some((points * (1 << 16)) as f64),
        || {
            let stats = run_sweep(grid, &opts).unwrap();
            assert_eq!(stats.ran, points);
            std::hint::black_box(stats.ran);
        },
    );
    let _ = std::fs::remove_file(&out);
}

fn bench_decode(rows: &mut Vec<Row>) -> anyhow::Result<()> {
    // serving-scale reconstruction of one checkpoint-sized tensor from its
    // Encoded form — fused parallel kernel vs scalar oracle.  Element count
    // follows OWF_BENCH_N (as in benches/formats.rs) so `bench.sh quick`
    // smoke runs stay quick.
    let n = bench_n();
    let mut rng = Rng::new(7);
    let data =
        Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let scheme = Scheme::parse("cbrt-t5@4:block128-absmax")?;
    let cb = scheme.build_codebook(128, Some(&data), &[])?;
    let q = Quantiser::new(
        scheme.granularity,
        scheme.statistic,
        scheme.scale_format,
        cb,
    );
    let enc = q.encode(&data, 0);
    let mut out = vec![0f32; n];
    q.decode_into(&enc, &mut out);
    assert_eq!(out, q.decode_ref(&enc), "decode kernels disagree");
    bench_rec(
        rows,
        "decode cbrt-t5@4:block128-absmax [dec]",
        Some(n as f64),
        || {
            q.decode_into(&enc, &mut out);
            std::hint::black_box(out[n / 2]);
        },
    );
    bench_rec(
        rows,
        "decode cbrt-t5@4:block128-absmax [dec-ref]",
        Some(n as f64),
        || {
            let r = q.decode_ref(&enc);
            std::hint::black_box(r[n / 2]);
        },
    );
    Ok(())
}

fn bench_fnv(rows: &mut Vec<Row>) {
    // the container checksum kernel: word-at-a-time loads vs the pinned
    // byte-serial oracle, parity-gated before timing — every OWQ1 section
    // checksum flows through this hash, so the two paths must agree
    // bit-for-bit on the bench buffer before either row is priced.
    use owf::util::simd::{fnv1a64_ref, fnv1a64_words};
    let n = bench_n();
    let mut rng = Rng::new(41);
    let buf: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
    assert_eq!(
        fnv1a64_words(&buf),
        fnv1a64_ref(&buf),
        "fnv1a64 word/byte paths diverge"
    );
    bench_rec(rows, "fnv1a64 [simd]", Some(n as f64), || {
        std::hint::black_box(fnv1a64_words(&buf));
    });
    bench_rec(rows, "fnv1a64 [scalar]", Some(n as f64), || {
        std::hint::black_box(fnv1a64_ref(&buf));
    });
}

fn bench_artifact(rows: &mut Vec<Row>) -> anyhow::Result<()> {
    // the OWQ1 round trip at checkpoint-tensor scale: [pack] = fused
    // encode + Fisher-free flat alloc + interleaved Huffman coding +
    // checksummed atomic write; [unpack] = checksum-verified sections +
    // table-driven interleaved entropy decode + fused dequantise.  The
    // packed decode is gated bit-exact against the in-memory pipeline
    // before any timing (EXPERIMENTS.md §Artifact).
    let n = bench_n();
    let (rows_n, cols) = (n / 1024, 1024);
    let mut rng = Rng::new(23);
    let data =
        Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let mut store = Store::new(Json::obj().push("kind", "bench-source"));
    let mut t = Tensor::from_f32("bench.w", vec![rows_n, cols], &data);
    t.channel_axis = Some(1);
    store.push(t);
    let spec = "cbrt-t5@4:block64-absmax:compress";
    let opts = PackOptions {
        spec: spec.to_string(),
        alloc: AllocMode::Flat,
        codec: Codec::Huffman,
        lanes: 4,
        target_bits: None,
        meta: Json::obj().push("source", "bench"),
    };
    let path = std::env::temp_dir().join(format!(
        "owf_bench_pack_{}.owq",
        std::process::id()
    ));
    let empty: HashMap<String, f64> = HashMap::new();
    pack_store(&store, &empty, &opts, &path)?;
    let art = Artifact::open(&path)?;
    let scheme = Scheme::parse(&art.tensors[0].spec)?;
    let reference = owf::eval::pipeline::qdq_tensor(
        &scheme,
        &data,
        &[rows_n, cols],
        Some(1),
        &[],
        0,
    )?;
    let decoded = art.decode_tensor(0)?;
    assert!(
        decoded
            .iter()
            .zip(&reference.recon)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "packed decode is not bit-identical to the in-memory pipeline"
    );
    bench_rec(
        rows,
        &format!("artifact {spec} [pack]"),
        Some(n as f64),
        || {
            pack_store(&store, &empty, &opts, &path).unwrap();
        },
    );
    let mut out = vec![0f32; n];
    bench_rec(
        rows,
        &format!("artifact {spec} [unpack]"),
        Some(n as f64),
        || {
            art.decode_tensor_into(0, &mut out).unwrap();
            std::hint::black_box(out[n / 2]);
        },
    );
    // the fault-tolerant serving path under contention: 4 threads
    // cold-miss the single tensor each round (clear_cache forces it);
    // single-flight coalescing means exactly one decode per iteration,
    // so the row prices the coalescing + cache machinery on top of
    // [unpack] rather than 4 decodes.
    let server = owf::artifact::server::ArtifactServer::new(
        Artifact::open(&path)?,
        1 << 30,
    );
    bench_rec(
        rows,
        &format!("artifact {spec} [get-coalesced]"),
        Some(n as f64),
        || {
            server.clear_cache();
            std::thread::scope(|scope| {
                for _ in 0..4 {
                    let server = &server;
                    scope.spawn(move || {
                        let t = server.get("bench.w").unwrap();
                        std::hint::black_box(t[n / 2]);
                    });
                }
            });
        },
    );
    let s = server.stats();
    assert_eq!(
        s.decode_errors + s.coalesced_errors + s.quarantined as u64,
        0,
        "serving bench must stay fault-free"
    );
    // the queued admission path: one decode permit, the other three
    // lanes wait FIFO in the bounded queue (nobody coalesces — each
    // round clears the cache and the lanes pile onto the same tensor,
    // so three ride the single-flight slot and the row prices permit
    // acquisition + deadline-bounded waiting on top of [get-coalesced].
    let queued = owf::artifact::server::ArtifactServer::new(
        Artifact::open(&path)?,
        1 << 30,
    )
    .with_max_decodes(1)
    .with_queue_depth(8);
    bench_rec(
        rows,
        &format!("artifact {spec} [get-queued]"),
        Some(n as f64),
        || {
            queued.clear_cache();
            std::thread::scope(|scope| {
                for _ in 0..4 {
                    let queued = &queued;
                    scope.spawn(move || {
                        let t = queued.get("bench.w").unwrap();
                        std::hint::black_box(t[n / 2]);
                    });
                }
            });
        },
    );
    let qs = queued.stats();
    assert!(
        qs.partition_closed(),
        "queued serving bench must close its stats partition"
    );
    assert_eq!(
        qs.queue_full + qs.deadline_exceeded_queued + qs.overloads,
        0,
        "depth-8 queue must absorb 4 lanes without shedding"
    );
    let _ = std::fs::remove_file(&path);
    Ok(())
}

fn bench_fractional(rows: &mut Vec<Row>) -> anyhow::Result<()> {
    // the OWQ3 mixed-decode path at serving scale: pack one tensor at a
    // non-lattice 3.3-bit budget (fractional water-filling mixes two
    // int schemes at block granularity), gate the packed decode
    // bit-exact against the in-memory mixed pipeline, then price the
    // partition-reassembling decode as the `[frac]` row.
    let n = bench_n();
    let (rows_n, cols) = (n / 1024, 1024);
    let mut rng = Rng::new(29);
    let data =
        Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let mut store = Store::new(Json::obj().push("kind", "bench-source"));
    let mut t = Tensor::from_f32("bench.w", vec![rows_n, cols], &data);
    t.channel_axis = Some(1);
    store.push(t);
    let opts = PackOptions {
        spec: "int@4:block64-absmax".to_string(),
        alloc: AllocMode::Fractional,
        codec: Codec::Huffman,
        lanes: 4,
        target_bits: Some(3.3),
        meta: Json::obj().push("source", "bench"),
    };
    let path = std::env::temp_dir().join(format!(
        "owf_bench_frac_{}.owq",
        std::process::id()
    ));
    let empty: HashMap<String, f64> = HashMap::new();
    pack_store(&store, &empty, &opts, &path)?;
    let art = Artifact::open(&path)?;
    let rec = &art.tensors[0];
    let mix = rec
        .mix
        .as_ref()
        .expect("a 3.3-bit fractional pack must mix its one tensor");
    let specs: Vec<Scheme> = mix
        .specs
        .iter()
        .map(|s| Scheme::parse(s))
        .collect::<anyhow::Result<_>>()?;
    let assign = art
        .block_assignment(0)?
        .expect("mixed tensor without block_schemes");
    let reference = owf::eval::pipeline::qdq_tensor_mixed(
        &specs,
        &assign,
        &data,
        &[rows_n, cols],
        Some(1),
        &[],
        rec.rot_seed.unwrap_or(0),
    )?;
    let decoded = art.decode_tensor(0)?;
    assert!(
        decoded
            .iter()
            .zip(&reference.recon)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "packed mixed decode is not bit-identical to the in-memory \
         mixed pipeline"
    );
    let mut out = vec![0f32; n];
    bench_rec(
        rows,
        "artifact int@3.3(frac):block64-absmax [frac]",
        Some(n as f64),
        || {
            art.decode_tensor_into(0, &mut out).unwrap();
            std::hint::black_box(out[n / 2]);
        },
    );
    let _ = std::fs::remove_file(&path);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Row> = Vec::new();
    bench_sweep(&mut rows);
    bench_decode(&mut rows)?;
    bench_fnv(&mut rows);
    bench_artifact(&mut rows)?;
    bench_fractional(&mut rows)?;
    let opts = RunOpts {
        eval_seqs: 16,
        ..Default::default()
    };
    let Ok(mut env) = Env::open(opts) else {
        println!("artifacts missing; run `make artifacts` first");
        write_bench_json("pipeline", None, &rows);
        return Ok(());
    };
    for size in ["s", "m"] {
        let n_params = env.checkpoint(size)?.config.n_params;
        // warm the ref-logits cache so the bench isolates the test path
        env.ref_logits(size)?;
        for spec in [
            "cbrt-t7@4:block128-absmax",
            "grid@4:tensor-rms:compress",
        ] {
            let scheme = Scheme::parse(spec)?;
            bench_rec(
                &mut rows,
                &format!("direct-cast {size} {spec}"),
                Some(n_params as f64),
                || {
                    let p =
                        env.direct_cast(size, &scheme, None, false).unwrap();
                    std::hint::black_box(p.kl.mean);
                },
            );
        }
        // quantise-only (no PJRT) to split the cost
        let scheme = Scheme::parse("cbrt-t7@4:block128-absmax")?;
        bench_rec(
            &mut rows,
            &format!("quantise-only {size}"),
            Some(n_params as f64),
            || {
                let (p, _, _) =
                    env.quantise(size, &scheme, None, false).unwrap();
                std::hint::black_box(p.len());
            },
        );
    }
    write_bench_json("pipeline", None, &rows);
    Ok(())
}
