//! Bench: end-to-end direct-cast of a full checkpoint (quantise every
//! tensor + PJRT forward + top-k KL) — the fig.-1 inner loop, and the
//! number EXPERIMENTS.md §Perf tracks for the whole stack.
//!
//! Requires `make artifacts`; exits quietly otherwise.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::bench;

use owf::coordinator::config::Scheme;
use owf::eval::llm::Env;
use owf::eval::RunOpts;

fn main() -> anyhow::Result<()> {
    let opts = RunOpts {
        eval_seqs: 16,
        ..Default::default()
    };
    let Ok(mut env) = Env::open(opts) else {
        println!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    for size in ["s", "m"] {
        let n_params = env.checkpoint(size)?.config.n_params;
        // warm the ref-logits cache so the bench isolates the test path
        env.ref_logits(size)?;
        for spec in [
            "cbrt-t7@4:block128-absmax",
            "grid@4:tensor-rms:compress",
        ] {
            let scheme = Scheme::parse(spec)?;
            bench(
                &format!("direct-cast {size} {spec}"),
                Some(n_params as f64),
                || {
                    let p =
                        env.direct_cast(size, &scheme, None, false).unwrap();
                    std::hint::black_box(p.kl.mean);
                },
            );
        }
        // quantise-only (no PJRT) to split the cost
        let scheme = Scheme::parse("cbrt-t7@4:block128-absmax")?;
        bench(
            &format!("quantise-only {size}"),
            Some(n_params as f64),
            || {
                let (p, _, _) =
                    env.quantise(size, &scheme, None, false).unwrap();
                std::hint::black_box(p.len());
            },
        );
    }
    Ok(())
}
