//! Bench: the quantise→dequantise hot path per element format — the L3
//! side of the paper's efficiency story (EXPERIMENTS.md §Perf).
//!
//! One row per format family at b=4, block absmax B=128 where applicable;
//! throughput in Melem/s over a 4M-element Student-t tensor.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::bench;

use owf::coordinator::config::Scheme;
use owf::dist::{Dist, Family};
use owf::eval::pipeline::qdq_tensor;
use owf::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 1 << 22;
    let mut rng = Rng::new(1);
    let data = Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    println!("qdq hot path, {n} elements:");
    for spec in [
        "int@4:block128-absmax",
        "int@8:block128-absmax",
        "cbrt-t5@4:block128-absmax",
        "cbrt-t5@4:block128-signmax",
        "nf@4:block128-absmax",
        "e2m1@4:block128-absmax",
        "cbrt-t5@4:tensor-rms",
        "cbrt-t5@4:channel-absmax",
        "int@4:block128-absmax:sparse0.001",
        "grid@4:tensor-rms:compress",
    ] {
        let scheme = Scheme::parse(spec)?;
        bench(spec, Some(n as f64), || {
            let out =
                qdq_tensor(&scheme, &data, &[n / 1024, 1024], Some(1), &[], 1)
                    .unwrap();
            std::hint::black_box(out.sq_err);
        });
    }
    Ok(())
}
