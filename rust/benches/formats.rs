//! Bench: the quantise→dequantise hot path per element format — the L3
//! side of the paper's efficiency story (EXPERIMENTS.md §Perf).
//!
//! One row per format family at b=4, block absmax B=128 where applicable;
//! throughput in Melem/s over a Student-t tensor (4M elements by default,
//! `OWF_BENCH_N` overrides — must be a multiple of 1024).  Also benches the
//! raw LUT kernel against the reference compare-count/binary-search path
//! (the ≥3× encode trajectory rows) and the fused parallel decode kernel
//! against the scalar oracle (the `[dec]` vs `[dec-ref]` rows, same ≥3×
//! target), and *gates* every benched codebook on bit-exact LUT/reference
//! and decode_into/decode_ref agreement first, so `scripts/check.sh` can
//! run this at tiny n as an offline equivalence smoke test.
//!
//! Set `OWF_BENCH_JSON=<path>` (as `scripts/bench.sh` does) to record the
//! rows machine-readably.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench_n, bench_rec, write_bench_json, Row};

use owf::coordinator::config::Scheme;
use owf::dist::{Dist, Family};
use owf::eval::pipeline::qdq_tensor;
use owf::formats::Codebook;
use owf::util::rng::Rng;

/// Bit-exact LUT/reference agreement on data probes plus the shared
/// adversarial set (`Codebook::adversarial_probes`: ±inf, NaN, subnormals,
/// exact midpoints, ULP neighbours). Panics on mismatch — the equivalence
/// contract enforced before any timing runs.
fn equivalence_gate(cb: &Codebook, data: &[f32], label: &str) {
    let mut probes: Vec<f32> = data.iter().step_by(7).copied().collect();
    probes.extend(cb.adversarial_probes());
    for &y in &probes {
        let (lut, reference) = (cb.quantise(y), cb.quantise_ref(y));
        assert_eq!(
            lut, reference,
            "LUT/reference disagree for {label} at y={y:?}"
        );
    }
}

fn main() -> anyhow::Result<()> {
    let n = bench_n();
    let mut rng = Rng::new(1);
    let data = Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let mut rows: Vec<Row> = Vec::new();

    // --- raw kernel: LUT vs reference nearest-neighbour (fused qdq) -------
    println!("codebook kernel (qdq_scaled_slice), {n} elements:");
    let mut buf = vec![0f32; n];
    for spec in [
        "cbrt-t5@4:block128-absmax",
        "nf@4:block128-absmax",
        "int@8:block128-absmax",
    ] {
        let scheme = Scheme::parse(spec)?;
        let cb = scheme.build_codebook(128, Some(&data), &[])?;
        equivalence_gate(&cb, &data, spec);
        assert!(cb.has_lut(), "{spec}: expected the LUT fast path");
        let reference = cb.clone().with_lut_disabled();
        for (tag, book) in [("lut", &cb), ("ref", &reference)] {
            // seed the buffer outside the timed closure; re-quantising the
            // (already snapped) buffer costs the same per element as fresh
            // data — the kernel is branchless — so no memcpy dilutes the
            // lut/ref throughput ratio
            buf.copy_from_slice(&data);
            bench_rec(
                &mut rows,
                &format!("kernel {spec} [{tag}]"),
                Some(n as f64),
                || {
                    book.qdq_scaled_slice(&mut buf, 0.8, 1.25);
                    std::hint::black_box(buf[n / 2]);
                },
            );
        }
    }

    // --- explicit SIMD kernels vs the pinned scalar oracles ----------------
    // parity gate first (bit compare, adversarial probes included), then
    // the `[simd]` vs `[scalar]` trajectory rows (EXPERIMENTS.md §SIMD).
    // On a host with neither AVX2 nor NEON the active ISA *is* scalar and
    // the [simd] rows time the fallback — the gate still passes.
    {
        use owf::util::simd::{self, Isa};
        let active = simd::active();
        println!("simd kernels (active ISA: {}), {n} elements:", active.name());
        let scheme = Scheme::parse("cbrt-t5@4:block128-absmax")?;
        let cb = scheme.build_codebook(128, Some(&data), &[])?;
        let (lo, inv_step, top) =
            cb.lut_params().expect("cbrt-t5@4 builds a LUT");
        let mut probes = data.clone();
        probes.extend(cb.adversarial_probes());
        let mut want = vec![0u32; probes.len()];
        simd::lut_slots(Isa::Scalar, &probes, lo, inv_step, top, &mut want);
        let mut slots = vec![0u32; probes.len()];
        simd::lut_slots(active, &probes, lo, inv_step, top, &mut slots);
        assert_eq!(slots, want, "lut_slots: {} != scalar", active.name());
        for (tag, isa) in [("simd", active), ("scalar", Isa::Scalar)] {
            bench_rec(
                &mut rows,
                &format!("kernel lut-slots [{tag}]"),
                Some(probes.len() as f64),
                || {
                    simd::lut_slots(
                        isa, &probes, lo, inv_step, top, &mut slots,
                    );
                    std::hint::black_box(slots[0]);
                },
            );
        }
        // the scaled-codepoint gather (decode_block's inner loop)
        let mut indices: Vec<u16> = Vec::new();
        cb.quantise_slice(&data, &mut indices);
        let table: Vec<f32> =
            (0..cb.len()).map(|i| cb.dequantise(i as u16) * 0.8).collect();
        let mut got = vec![0f32; indices.len()];
        let mut reference = vec![0f32; indices.len()];
        simd::gather_u16_f32(Isa::Scalar, &table, &indices, &mut reference);
        simd::gather_u16_f32(active, &table, &indices, &mut got);
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            reference.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "gather: {} != scalar",
            active.name()
        );
        for (tag, isa) in [("simd", active), ("scalar", Isa::Scalar)] {
            bench_rec(
                &mut rows,
                &format!("kernel gather [{tag}]"),
                Some(indices.len() as f64),
                || {
                    simd::gather_u16_f32(isa, &table, &indices, &mut got);
                    std::hint::black_box(got[0]);
                },
            );
        }
    }

    // --- decode kernel: fused parallel decode_into vs scalar oracle --------
    println!("decode kernel (decode_into vs decode_ref), {n} elements:");
    let mut dec_out = vec![0f32; n];
    for spec in [
        "int@4:block128-absmax",
        "cbrt-t5@4:block128-absmax",
        "nf@4:block128-absmax",
        "int@8:block128-absmax",
    ] {
        let scheme = Scheme::parse(spec)?;
        let cb = scheme.build_codebook(128, Some(&data), &[])?;
        let quantiser = owf::quant::Quantiser::new(
            scheme.granularity,
            scheme.statistic,
            scheme.scale_format,
            cb,
        );
        let enc = quantiser.encode(&data, 0);
        // decode bit-exactness gate before any timing (check.sh runs this
        // at tiny n, mirroring the LUT/reference encode gate)
        let reference = quantiser.decode_ref(&enc);
        quantiser.decode_into(&enc, &mut dec_out);
        assert_eq!(
            dec_out, reference,
            "{spec}: decode_into/decode_ref disagree"
        );
        bench_rec(
            &mut rows,
            &format!("decode {spec} [dec]"),
            Some(n as f64),
            || {
                quantiser.decode_into(&enc, &mut dec_out);
                std::hint::black_box(dec_out[n / 2]);
            },
        );
        bench_rec(
            &mut rows,
            &format!("decode {spec} [dec-ref]"),
            Some(n as f64),
            || {
                let out = quantiser.decode_ref(&enc);
                std::hint::black_box(out[n / 2]);
            },
        );
    }

    // --- full tensor pipeline per scheme -----------------------------------
    println!("qdq hot path, {n} elements:");
    for spec in [
        "int@4:block128-absmax",
        "int@8:block128-absmax",
        "cbrt-t5@4:block128-absmax",
        "cbrt-t5@4:block128-signmax",
        "nf@4:block128-absmax",
        "e2m1@4:block128-absmax",
        "cbrt-t5@4:tensor-rms",
        "cbrt-t5@4:channel-absmax",
        "int@4:block128-absmax:sparse0.001",
        "cbrt-t5@4:block128-absmax:compress",
        "grid@4:tensor-rms:compress",
    ] {
        let scheme = Scheme::parse(spec)?;
        if !matches!(
            scheme.element,
            owf::coordinator::config::Element::Grid
        ) {
            let cb = scheme.build_codebook(128, Some(&data), &[])?;
            equivalence_gate(&cb, &data, spec);
        }
        bench_rec(&mut rows, spec, Some(n as f64), || {
            let out =
                qdq_tensor(&scheme, &data, &[n / 1024, 1024], Some(1), &[], 1)
                    .unwrap();
            std::hint::black_box(out.sq_err);
        });
    }

    write_bench_json("formats", Some(n), &rows);
    Ok(())
}
