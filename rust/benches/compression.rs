//! Bench: entropy-coder throughput (Huffman vs rANS, encode + decode) over
//! quantised-weight symbol streams — fig. 24's practical-compressor angle.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::bench;

use owf::compress::huffman::HuffmanCode;
use owf::compress::rans::{rans_decode, rans_encode, RansModel};
use owf::dist::{Dist, Family};
use owf::formats::cbrt::{cbrt_rms, CBRT_ALPHA};
use owf::formats::Variant;
use owf::util::rng::Rng;

fn main() {
    let n = 1 << 21;
    let mut rng = Rng::new(2);
    let data = Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let cb = cbrt_rms(Family::StudentT, 5.0, 4, Variant::Symmetric, CBRT_ALPHA);
    let symbols: Vec<u16> = data.iter().map(|&x| cb.quantise(x)).collect();
    let mut counts = vec![0u64; cb.len()];
    for &s in &symbols {
        counts[s as usize] += 1;
    }

    println!("entropy coders, {n} symbols (4-bit cbrt-t indices):");
    let huff = HuffmanCode::from_counts(&counts);
    let (encoded, bits) = huff.encode(&symbols);
    println!(
        "  rates: entropy {:.4} b/sym, huffman {:.4} b/sym",
        owf::compress::entropy_bits(&counts),
        bits as f64 / n as f64
    );
    bench("huffman encode", Some(n as f64), || {
        std::hint::black_box(huff.encode(&symbols).1);
    });
    bench("huffman decode", Some(n as f64), || {
        std::hint::black_box(huff.decode(&encoded, symbols.len()).len());
    });

    let model = RansModel::from_counts(&counts);
    let renc = rans_encode(&model, &symbols);
    println!("  rans rate {:.4} b/sym", renc.len() as f64 * 8.0 / n as f64);
    bench("rans encode", Some(n as f64), || {
        std::hint::black_box(rans_encode(&model, &symbols).len());
    });
    bench("rans decode", Some(n as f64), || {
        std::hint::black_box(rans_decode(&model, &renc, symbols.len()).len());
    });
}
