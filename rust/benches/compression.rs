//! Bench: entropy-coder throughput (Huffman vs rANS, encode + decode) over
//! quantised-weight symbol streams — fig. 24's practical-compressor angle,
//! now including the serving decode path: the table-driven K-lane
//! interleaved decoders against the single-stream `[ref]` oracles.  Every
//! interleaved container is roundtrip-gated against the oracle before any
//! timing.  Set `OWF_BENCH_JSON=<path>` (as `scripts/bench.sh` does) to
//! record the rows machine-readably.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench_rec, write_bench_json, Row};

use owf::compress::huffman::HuffmanCode;
use owf::compress::rans::{
    rans_decode, rans_decode_interleaved, rans_decode_interleaved_with,
    rans_encode, rans_encode_interleaved, RansModel,
};
use owf::dist::{Dist, Family};
use owf::formats::cbrt::{cbrt_rms, CBRT_ALPHA};
use owf::formats::Variant;
use owf::util::rng::Rng;

fn main() {
    let n = 1 << 21;
    let mut rng = Rng::new(2);
    let data = Dist::standard(Family::StudentT, 5.0).sample_vec(&mut rng, n);
    let cb = cbrt_rms(Family::StudentT, 5.0, 4, Variant::Symmetric, CBRT_ALPHA);
    let mut symbols: Vec<u16> = Vec::new();
    cb.quantise_slice(&data, &mut symbols);
    let mut counts = vec![0u64; cb.len()];
    for &s in &symbols {
        counts[s as usize] += 1;
    }
    let mut rows: Vec<Row> = Vec::new();

    println!("entropy coders, {n} symbols (4-bit cbrt-t indices):");
    let huff = HuffmanCode::from_counts(&counts);
    let (encoded, bits) = huff.encode(&symbols);
    println!(
        "  rates: entropy {:.4} b/sym, huffman {:.4} b/sym",
        owf::compress::entropy_bits(&counts),
        bits as f64 / n as f64
    );
    bench_rec(&mut rows, "huffman encode", Some(n as f64), || {
        std::hint::black_box(huff.encode(&symbols).1);
    });
    bench_rec(&mut rows, "huffman decode [ref]", Some(n as f64), || {
        std::hint::black_box(huff.decode(&encoded, symbols.len()).len());
    });
    // serving pattern: build the table decoder once, reuse per container
    let decoder = huff.decoder();
    for lanes in [1usize, 2, 4, 8] {
        let container = huff.encode_interleaved(&symbols, lanes);
        assert_eq!(
            decoder.decode_interleaved(&container, symbols.len()),
            symbols,
            "huffman x{lanes} roundtrip"
        );
        bench_rec(
            &mut rows,
            &format!("huffman decode x{lanes} [table]"),
            Some(n as f64),
            || {
                std::hint::black_box(
                    decoder
                        .decode_interleaved(&container, symbols.len())
                        .len(),
                );
            },
        );
    }

    let model = RansModel::from_counts(&counts);
    let renc = rans_encode(&model, &symbols);
    println!("  rans rate {:.4} b/sym", renc.len() as f64 * 8.0 / n as f64);
    bench_rec(&mut rows, "rans encode", Some(n as f64), || {
        std::hint::black_box(rans_encode(&model, &symbols).len());
    });
    bench_rec(&mut rows, "rans decode [ref]", Some(n as f64), || {
        std::hint::black_box(rans_decode(&model, &renc, symbols.len()).len());
    });
    for lanes in [1usize, 2, 4, 8] {
        let container = rans_encode_interleaved(&model, &symbols, lanes);
        assert_eq!(
            rans_decode_interleaved(&model, &container, symbols.len()),
            symbols,
            "rans x{lanes} roundtrip"
        );
        bench_rec(
            &mut rows,
            &format!("rans decode x{lanes}"),
            Some(n as f64),
            || {
                std::hint::black_box(
                    rans_decode_interleaved(
                        &model,
                        &container,
                        symbols.len(),
                    )
                    .len(),
                );
            },
        );
    }

    // --- explicit SIMD decode rounds vs the pinned scalar oracle ----------
    // K = the active ISA's vector width (what `owf pack` now defaults to);
    // bit-exact parity gate before any timing (EXPERIMENTS.md §SIMD).  On a
    // host with neither AVX2 nor NEON both rows time the scalar loop.
    {
        use owf::util::simd::{self, Isa};
        let active = simd::active();
        let k = simd::preferred_lanes();
        println!("simd rans decode (active ISA: {}, K={k}):", active.name());
        let container = rans_encode_interleaved(&model, &symbols, k);
        let fast =
            rans_decode_interleaved_with(&model, &container, symbols.len(), active);
        let oracle = rans_decode_interleaved_with(
            &model,
            &container,
            symbols.len(),
            Isa::Scalar,
        );
        assert_eq!(fast, oracle, "rans x{k}: {} != scalar", active.name());
        assert_eq!(fast, symbols, "rans x{k} simd roundtrip");
        for (tag, isa) in [("simd", active), ("scalar", Isa::Scalar)] {
            bench_rec(
                &mut rows,
                &format!("rans decode x{k} [{tag}]"),
                Some(n as f64),
                || {
                    std::hint::black_box(
                        rans_decode_interleaved_with(
                            &model,
                            &container,
                            symbols.len(),
                            isa,
                        )
                        .len(),
                    );
                },
            );
        }
    }

    write_bench_json("compression", Some(n), &rows);
}
