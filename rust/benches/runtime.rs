//! Bench: PJRT execution latency per artifact — forward pass, Fisher batch
//! and the Pallas qdq kernel, isolating the L1/L2 cost from L3.
//!
//! Requires `make artifacts`; exits quietly otherwise.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::bench;

use owf::runtime::model::{Checkpoint, TokenSplit};
use owf::runtime::{Runtime, Value};

fn main() -> anyhow::Result<()> {
    let Ok(rt) = Runtime::open_default() else {
        println!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };

    // Pallas qdq kernel (as lowered HLO)
    let info = rt.artifact("qdq_block_absmax")?.clone();
    let n = info.inputs[0].numel();
    let x: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.02 - 1.0).collect();
    let cb: Vec<f32> = (0..info.inputs[1].numel())
        .map(|i| -1.0 + i as f32 / 8.0)
        .collect();
    bench("pjrt qdq_block_absmax (512k elems)", Some(n as f64), || {
        let out = rt
            .execute_f32("qdq_block_absmax", &[Value::F32(&x), Value::F32(&cb)])
            .unwrap();
        std::hint::black_box(out[0][0]);
    });

    // model forward per size
    for size in ["s", "m", "l"] {
        let ck = Checkpoint::load(&rt, size)?;
        let toks = TokenSplit::load(&rt, size, "eval")?;
        let runner =
            owf::runtime::ModelRunner::new(&rt, size, ck.config.clone())?;
        let params = ck.params();
        let batch_tokens = toks.take(runner.batch).to_vec();
        let tokens_per_call = (runner.batch * ck.config.seq_len) as f64;
        bench(
            &format!("pjrt model_fwd_{size} (batch {})", runner.batch),
            Some(tokens_per_call),
            || {
                let l = runner.logits(&params, &batch_tokens).unwrap();
                std::hint::black_box(l.len());
            },
        );
    }
    Ok(())
}
