//! A minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! offline build needs no registry access.  Implements exactly what this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where it matters:
//! * `Error` does **not** implement `std::error::Error` (that is what makes
//!   the blanket `From<E: std::error::Error>` conversion coherent);
//! * `.context(..)` prepends context; the cause is folded into the message
//!   exactly once (upstream renders it as a `Caused by:` chain instead —
//!   same information, flatter form), while `From`-converted errors keep
//!   their source chain for `Debug`/`{:#}`.

use std::error::Error as StdError;
use std::fmt;

/// Drop-in subset of `anyhow::Error`: a message plus an optional source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `anyhow::Result` with the usual defaultable error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a plain message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Construct from a std error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(err: E) -> Error {
        Error {
            msg: err.to_string(),
            source: Some(Box::new(err)),
        }
    }

    /// Wrap with an outer context message.  The cause is folded into the
    /// message (so `Display` stays informative) and the source is dropped,
    /// which keeps `Debug`'s `Caused by:` chain from repeating it.
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: None,
        }
    }

    /// The root-cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_deref().map(|s| s as _);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> =
            self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

// Coherent alongside the blanket impl because `Error` (a local type) is
// known not to implement `std::error::Error` — the same shape upstream
// anyhow relies on.
impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_wraps_without_duplicating_the_cause() {
        let err = io_fail().unwrap_err();
        let display = err.to_string();
        assert!(display.starts_with("reading config: "));
        // the cause is folded into the message exactly once
        assert_eq!(format!("{err:?}"), display);
        assert_eq!(err.chain().count(), 0);
        // an uncontexted conversion keeps its source chain
        let raw = Error::new(
            std::fs::read_to_string("/definitely/not/a/file").unwrap_err(),
        );
        assert_eq!(raw.chain().count(), 1);
        assert!(format!("{raw:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let err = x.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
        let y: Option<u32> = Some(3);
        assert_eq!(y.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
    }

    #[test]
    fn question_mark_converts() {
        fn g() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(g().unwrap(), 12);
    }
}
