//! # owf — Optimal Weight Formats
//!
//! A production-grade reproduction of *"Optimal Formats for Weight
//! Quantisation"* (Orr, Ribar & Luschi, Graphcore Research, 2025): a
//! framework for systematic design and analysis of weight-quantisation
//! formats, built as a three-layer Rust + JAX + Pallas stack (Python only at
//! build time; see DESIGN.md).
//!
//! Layer map:
//! * [`dist`], [`formats`], [`scaling`], [`quant`], [`compress`] — the
//!   format-design framework (§2 of the paper);
//! * [`tensorstore`], [`runtime`] — checkpoint I/O and the PJRT executor for
//!   the AOT-compiled JAX/Pallas graphs;
//! * [`fisher`], [`alloc`], [`kl`] — Fisher estimation, variable bit-width
//!   allocation (eq. 5) and the top-k KL metric (§2.4/§D);
//! * [`coordinator`], [`eval`] — the experiment scheduler/CLI and the
//!   per-figure/table reproduction harness (§3/§4);
//! * [`artifact`] — the `OWQ1` quantised-artifact store (pack path +
//!   concurrent serving reader with decoded-tensor cache);
//! * [`util`] — from-scratch JSON / RNG / thread-pool / stats / property
//!   testing (the offline build has no external crates beyond `xla`).

pub mod alloc;
pub mod artifact;
pub mod compress;
pub mod coordinator;
pub mod dist;
pub mod eval;
pub mod fisher;
pub mod formats;
pub mod kl;
pub mod quant;
pub mod runtime;
pub mod scaling;
pub mod tensorstore;
pub mod util;
