//! Scaling schemes (§2.1): the *granularity* (tensor / channel / block),
//! the *statistic* (RMS / absmax / signmax) and the *scale storage format*
//! (bfloat16 round-away by default; E8M0 and generic EkMm for the fig. 20/21
//! sweeps).

use crate::formats::float::{round_to_bf16, round_to_e8m0, round_to_float};

/// How many elements share one scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// One scale for the whole tensor.
    Tensor,
    /// One scale per output channel (row/column per the tensor's
    /// `channel_axis`).
    Channel,
    /// One scale per contiguous block of `B` elements.
    Block(usize),
}

impl Granularity {
    pub fn name(&self) -> String {
        match self {
            Granularity::Tensor => "tensor".into(),
            Granularity::Channel => "channel".into(),
            Granularity::Block(b) => format!("block{b}"),
        }
    }
}

/// The block statistic used as the scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Statistic {
    Rms,
    Absmax,
    /// Signed absolute maximum: scale carries the max's sign, costing one
    /// extra bit per block (§2.1 "Signmax scaling").
    Signmax,
}

impl Statistic {
    pub fn name(&self) -> &'static str {
        match self {
            Statistic::Rms => "rms",
            Statistic::Absmax => "absmax",
            Statistic::Signmax => "signmax",
        }
    }

    /// Compute the (signed, for signmax) scale of one block.
    pub fn compute(&self, block: &[f32]) -> f32 {
        match self {
            Statistic::Rms => {
                let ss: f64 = block
                    .iter()
                    .map(|&x| x as f64 * x as f64)
                    .sum();
                ((ss / block.len() as f64).sqrt()) as f32
            }
            Statistic::Absmax => {
                block.iter().fold(0f32, |m, &x| m.max(x.abs()))
            }
            Statistic::Signmax => {
                let mut best = 0f32;
                for &x in block {
                    if x.abs() > best.abs() {
                        best = x;
                    }
                }
                best
            }
        }
    }
}

/// Storage format for the per-block scale value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScaleFormat {
    /// float32 passthrough (idealised; 32 bits).
    F32,
    /// bfloat16; `away` selects round-away-from-zero (the paper's default
    /// for absmax so the block max never clips outside ±1).
    Bf16 { away: bool },
    /// Power-of-two exponent-only scale (MX convention).
    E8M0 { away: bool },
    /// Generic EkMm minifloat scale (fig. 20's mantissa sweep).
    Float { exp: u32, man: u32, away: bool },
}

impl ScaleFormat {
    pub fn bits(&self) -> f64 {
        match self {
            ScaleFormat::F32 => 32.0,
            ScaleFormat::Bf16 { .. } => 16.0,
            ScaleFormat::E8M0 { .. } => 8.0,
            ScaleFormat::Float { exp, man, .. } => (1 + exp + man) as f64,
        }
    }

    pub fn name(&self) -> String {
        match self {
            ScaleFormat::F32 => "f32".into(),
            ScaleFormat::Bf16 { away } => {
                format!("bf16{}", if *away { "-away" } else { "" })
            }
            ScaleFormat::E8M0 { away } => {
                format!("e8m0{}", if *away { "-away" } else { "" })
            }
            ScaleFormat::Float { exp, man, away } => {
                format!("e{exp}m{man}{}", if *away { "-away" } else { "" })
            }
        }
    }

    /// Round a (positive-magnitude) scale; the sign (signmax) is preserved.
    pub fn round(&self, scale: f32) -> f32 {
        if scale == 0.0 {
            return 0.0;
        }
        let sign = scale.signum();
        let mag = scale.abs();
        let rounded = match *self {
            ScaleFormat::F32 => mag,
            ScaleFormat::Bf16 { away } => round_to_bf16(mag, away),
            ScaleFormat::E8M0 { away } => round_to_e8m0(mag, away),
            ScaleFormat::Float { exp, man, away } => {
                round_to_float(mag, exp, man, away)
            }
        };
        sign * rounded
    }
}

/// The paper's default scale format: bfloat16, round-away.
pub const DEFAULT_SCALE: ScaleFormat = ScaleFormat::Bf16 { away: true };

/// View a flat tensor as scale groups for a granularity. Returns a list of
/// (start, len) ranges; `channel_len` is the contiguous length of one
/// channel group (tensor shape dependent, supplied by the caller).
pub fn scale_groups(
    n: usize,
    granularity: Granularity,
    channel_len: usize,
) -> Vec<(usize, usize)> {
    match granularity {
        Granularity::Tensor => vec![(0, n)],
        Granularity::Channel => {
            assert!(channel_len > 0 && n % channel_len == 0,
                "channel_len {channel_len} does not divide {n}");
            (0..n / channel_len)
                .map(|i| (i * channel_len, channel_len))
                .collect()
        }
        Granularity::Block(b) => {
            assert!(b > 0);
            let mut out = Vec::with_capacity(n.div_ceil(b));
            let mut start = 0;
            while start < n {
                let len = b.min(n - start);
                out.push((start, len));
                start += len;
            }
            out
        }
    }
}

/// Average scale overhead in bits per element.
pub fn scale_overhead_bits(
    n: usize,
    granularity: Granularity,
    channel_len: usize,
    scale_format: ScaleFormat,
    statistic: Statistic,
) -> f64 {
    let groups = scale_groups(n, granularity, channel_len).len() as f64;
    let sign_bit = if statistic == Statistic::Signmax { 1.0 } else { 0.0 };
    groups * (scale_format.bits() + sign_bit) / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statistics() {
        let block = [1.0f32, -3.0, 2.0];
        assert_eq!(Statistic::Absmax.compute(&block), 3.0);
        assert_eq!(Statistic::Signmax.compute(&block), -3.0);
        let rms = Statistic::Rms.compute(&block);
        assert!((rms - ((14.0f64 / 3.0).sqrt() as f32)).abs() < 1e-6);
    }

    #[test]
    fn signmax_keeps_sign_through_rounding() {
        let s = ScaleFormat::Bf16 { away: true };
        let r = s.round(-3.0001);
        assert!(r <= -3.0001, "round-away grows magnitude: {r}");
        // bf16 ulp in the [2, 4) binade is 2^-7·4 = 0.03125
        assert!(r >= -3.04, "{r}");
    }

    #[test]
    fn groups_partition() {
        for (n, g, cl) in [
            (100, Granularity::Tensor, 0),
            (100, Granularity::Block(32), 0),
            (96, Granularity::Channel, 24),
        ] {
            let groups = scale_groups(n, g, cl);
            let total: usize = groups.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, n, "{g:?}");
            // contiguity
            let mut next = 0;
            for &(s, l) in &groups {
                assert_eq!(s, next);
                next = s + l;
            }
        }
    }

    #[test]
    fn overhead_bits() {
        // B=128 bf16 scale = 16/128 = 0.125 bits/elem
        let o = scale_overhead_bits(
            128 * 10,
            Granularity::Block(128),
            0,
            ScaleFormat::Bf16 { away: true },
            Statistic::Absmax,
        );
        assert!((o - 0.125).abs() < 1e-12);
        // signmax adds 1/128
        let s = scale_overhead_bits(
            128 * 10,
            Granularity::Block(128),
            0,
            ScaleFormat::Bf16 { away: true },
            Statistic::Signmax,
        );
        assert!((s - 0.125 - 1.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn tail_block_handled() {
        let groups = scale_groups(100, Granularity::Block(32), 0);
        assert_eq!(groups.len(), 4);
        assert_eq!(groups[3], (96, 4));
    }
}
