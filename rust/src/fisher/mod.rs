//! Fisher-information estimation (§D "Fisher estimation", eq. 8) and the
//! KL-divergence prediction rule of eq. (3)/(7).
//!
//! The heavy compute — per-sequence gradients squared — lives in the AOT
//! `fisher_<size>` artifact (L2 JAX graph: vmap(grad), sampled labels, see
//! python/compile/model.py::fisher_batch).  Rust orchestrates batches,
//! accumulates in f64 on the host (the paper's two-stage accumulator: device
//! partials, wider host accumulation) and derives per-tensor statistics.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::runtime::model::TokenSplit;
use crate::runtime::{OwnedValue, Runtime};
use crate::tensorstore::{Store, Tensor};
use crate::util::json::Json;

/// A per-parameter diagonal-Fisher estimate.
pub struct FisherEstimate {
    /// name → per-element Fisher diagonal (sequence-level, mean over
    /// sequences).
    pub diag: HashMap<String, Vec<f32>>,
    pub sequences: usize,
    pub seq_len: usize,
}

/// Per-tensor summary used by bit allocation and fig. 12-style analyses.
#[derive(Clone, Debug)]
pub struct TensorFisher {
    pub name: String,
    pub numel: usize,
    pub mean: f64,
    pub log10_within_std: f64,
}

impl FisherEstimate {
    /// Estimate over `n_batches` artifact invocations.
    ///
    /// `empirical` selects the dataset-label variant (fig. 27); otherwise
    /// labels are sampled from the model (closer to the true Fisher).
    pub fn estimate(
        rt: &Runtime,
        size: &str,
        params: &HashMap<String, Vec<f32>>,
        tokens: &TokenSplit,
        n_batches: usize,
        seed: u64,
        empirical: bool,
    ) -> Result<FisherEstimate> {
        let artifact = if empirical {
            format!("fisher_emp_{size}")
        } else {
            format!("fisher_{size}")
        };
        let info = rt.artifact(&artifact)?.clone();
        let tok_spec = info
            .inputs
            .iter()
            .find(|s| s.dtype == "int32")
            .context("no token input")?;
        let batch = tok_spec.shape[0];
        let seq = tok_spec.shape[1];
        assert_eq!(seq, tokens.seq_len);

        // f64 accumulators, one per output tensor
        let mut acc: HashMap<String, Vec<f64>> = HashMap::new();
        let mut sequences = 0usize;
        for b in 0..n_batches {
            // wrap around the split if it is smaller than the request
            let start = (b * batch) % tokens.n_seq.max(1);
            let mut chunk = vec![0i32; batch * seq];
            for row in 0..batch {
                let s = (start + row) % tokens.n_seq;
                chunk[row * seq..(row + 1) * seq]
                    .copy_from_slice(tokens.seq(s));
            }
            let key: Vec<u32> =
                vec![(seed ^ b as u64) as u32, (b as u64 + 1) as u32];
            let outputs = rt.execute_named(&artifact, |spec| {
                match spec.dtype.as_str() {
                    "int32" => Ok(OwnedValue::I32(chunk.clone())),
                    "uint32" => Ok(OwnedValue::U32(key.clone())),
                    _ => {
                        let pname = spec
                            .name
                            .strip_prefix("arg0.")
                            .context("unexpected f32 input")?;
                        Ok(OwnedValue::F32(
                            params
                                .get(pname)
                                .with_context(|| format!("missing {pname}"))?
                                .clone(),
                        ))
                    }
                }
            })?;
            for (spec, out) in info.outputs.iter().zip(outputs) {
                let pname = spec
                    .name
                    .strip_prefix("out.")
                    .unwrap_or(&spec.name)
                    .to_string();
                let slot = acc
                    .entry(pname)
                    .or_insert_with(|| vec![0f64; out.len()]);
                for (a, v) in slot.iter_mut().zip(out) {
                    *a += v as f64;
                }
            }
            sequences += batch;
        }
        let diag = acc
            .into_iter()
            .map(|(name, v)| {
                (
                    name,
                    v.into_iter()
                        .map(|x| (x / sequences as f64) as f32)
                        .collect(),
                )
            })
            .collect();
        Ok(FisherEstimate {
            diag,
            sequences,
            seq_len: seq,
        })
    }

    /// Per-tensor summary (fig. 12: across- vs within-tensor variation).
    pub fn tensor_summaries(&self) -> Vec<TensorFisher> {
        let mut out: Vec<TensorFisher> = self
            .diag
            .iter()
            .map(|(name, v)| {
                let mean = v.iter().map(|&x| x as f64).sum::<f64>()
                    / v.len() as f64;
                let logs: Vec<f64> = v
                    .iter()
                    .map(|&x| (x as f64).max(1e-30).log10())
                    .collect();
                TensorFisher {
                    name: name.clone(),
                    numel: v.len(),
                    mean,
                    log10_within_std: crate::util::stats::std(&logs),
                }
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Mean Fisher per tensor (f̄_t), keyed by name.
    pub fn tensor_means(&self) -> HashMap<String, f64> {
        self.tensor_summaries()
            .into_iter()
            .map(|t| (t.name, t.mean))
            .collect()
    }

    /// eq. (7) KL prediction for a perturbed parameter set, reported per
    /// token: ½ Σ_i F_ii Δθ_i² / (L−1).
    pub fn predict_kl(
        &self,
        original: &HashMap<String, Vec<f32>>,
        perturbed: &HashMap<String, Vec<f32>>,
    ) -> f64 {
        let mut total = 0f64;
        for (name, f) in &self.diag {
            let (Some(a), Some(b)) = (original.get(name), perturbed.get(name))
            else {
                continue;
            };
            for ((&fi, &x), &y) in f.iter().zip(a).zip(b) {
                let d = (x - y) as f64;
                total += fi as f64 * d * d;
            }
        }
        0.5 * total / (self.seq_len as f64 - 1.0)
    }

    /// Same prediction from per-tensor means only (the scaled-identity
    /// approximation of eq. 3).
    pub fn predict_kl_scaled_identity(
        &self,
        original: &HashMap<String, Vec<f32>>,
        perturbed: &HashMap<String, Vec<f32>>,
    ) -> f64 {
        let means = self.tensor_means();
        let mut total = 0f64;
        for (name, fbar) in &means {
            let (Some(a), Some(b)) = (original.get(name), perturbed.get(name))
            else {
                continue;
            };
            let sq: f64 = a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let d = (x - y) as f64;
                    d * d
                })
                .sum();
            total += fbar * sq;
        }
        0.5 * total / (self.seq_len as f64 - 1.0)
    }

    // ---- persistence ---------------------------------------------------------

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut store = Store::new(
            Json::obj()
                .push("kind", "fisher")
                .push("sequences", self.sequences)
                .push("seq_len", self.seq_len),
        );
        let mut names: Vec<&String> = self.diag.keys().collect();
        names.sort();
        for name in names {
            let v = &self.diag[name];
            store.push(Tensor::from_f32(name, vec![v.len()], v));
        }
        store.save(path)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<FisherEstimate> {
        let store = Store::load(path)?;
        let sequences = store
            .meta
            .get("sequences")
            .and_then(|j| j.as_usize())
            .context("bad fisher file")?;
        let seq_len = store
            .meta
            .get("seq_len")
            .and_then(|j| j.as_usize())
            .context("bad fisher file")?;
        Ok(FisherEstimate {
            diag: store
                .tensors
                .iter()
                .map(|t| (t.name.clone(), t.as_f32()))
                .collect(),
            sequences,
            seq_len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model::Checkpoint;

    fn setup() -> Option<(Runtime, Checkpoint, TokenSplit)> {
        let rt = Runtime::open_default().ok()?;
        let ck = Checkpoint::load(&rt, "s").ok()?;
        let toks = TokenSplit::load(&rt, "s", "fisher").ok()?;
        Some((rt, ck, toks))
    }

    #[test]
    fn fisher_is_positive_and_structured() {
        let Some((rt, ck, toks)) = setup() else { return };
        let params = ck.params();
        let est = FisherEstimate::estimate(
            &rt, "s", &params, &toks, 2, 42, false,
        )
        .unwrap();
        assert_eq!(est.diag.len(), ck.store.tensors.len());
        for (name, f) in &est.diag {
            assert_eq!(f.len(), params[name].len(), "{name}");
            assert!(f.iter().all(|&x| x >= 0.0 && x.is_finite()), "{name}");
        }
        // tensors must differ in mean Fisher (fig. 12's premise)
        let means = est.tensor_means();
        let vals: Vec<f64> = means.values().copied().collect();
        let max = vals.iter().fold(0f64, |m, &x| m.max(x));
        let min = vals.iter().fold(f64::INFINITY, |m, &x| m.min(x));
        assert!(
            max / min.max(1e-30) > 5.0,
            "expected cross-tensor variation, got {min}..{max}"
        );
    }

    #[test]
    fn prediction_increases_with_noise() {
        let Some((rt, ck, toks)) = setup() else { return };
        let params = ck.params();
        let est = FisherEstimate::estimate(
            &rt, "s", &params, &toks, 1, 7, false,
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let mut prev = 0.0;
        for sigma in [1e-3f32, 1e-2, 1e-1] {
            let perturbed: HashMap<String, Vec<f32>> = params
                .iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        v.iter()
                            .map(|&x| x + sigma * rng.normal() as f32)
                            .collect(),
                    )
                })
                .collect();
            let kl = est.predict_kl(&params, &perturbed);
            assert!(kl > prev, "kl {kl} should grow with sigma {sigma}");
            prev = kl;
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let Some((rt, ck, toks)) = setup() else { return };
        let params = ck.params();
        let est = FisherEstimate::estimate(
            &rt, "s", &params, &toks, 1, 3, false,
        )
        .unwrap();
        let path = std::env::temp_dir().join("owf_fisher_test.owt");
        est.save(&path).unwrap();
        let loaded = FisherEstimate::load(&path).unwrap();
        assert_eq!(loaded.sequences, est.sequences);
        assert_eq!(loaded.diag.len(), est.diag.len());
        for (k, v) in &est.diag {
            assert_eq!(&loaded.diag[k], v);
        }
    }

    #[test]
    fn empirical_variant_correlates() {
        let Some((rt, ck, toks)) = setup() else { return };
        if rt.artifact("fisher_emp_s").is_err() {
            return; // only exported for m; skip for s
        }
        let params = ck.params();
        let _ = (params, toks);
    }
}
