//! Filesystem helpers: crash-safe atomic file replacement.
//!
//! Every durable container in the crate (`.owt` tensor stores, `OWQ1`
//! quantised artifacts) goes through [`atomic_write`]: the bytes land in a
//! unique temp file *in the target directory* (same filesystem, so the
//! final rename cannot degrade to a copy), are synced, then renamed over
//! the destination.  A crash mid-write leaves either the old file or a
//! stray `.tmp` — never a torn target.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

/// Per-process uniquifier so concurrent writers (pool workers, tests)
/// never collide on a temp name even within one pid.
static COUNTER: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` via a same-directory temp file + rename.
pub fn atomic_write(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    atomic_write_io(path, bytes)
        .with_context(|| format!("atomic write {path:?}"))
}

/// [`atomic_write`] core, preserving the raw `io::Error` (and with it the
/// `ErrorKind`) so callers with a typed error taxonomy — the artifact
/// writer classifying transient vs permanent failures — keep the kind.
/// Each step's context is folded into the error message instead.
pub fn atomic_write_io(
    path: impl AsRef<Path>,
    bytes: &[u8],
) -> std::io::Result<()> {
    let path = path.as_ref();
    let dir = path
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .unwrap_or_else(|| Path::new("."));
    let base = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    let tmp = dir.join(format!(
        ".{base}.tmp.{}.{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let step = |what: &str, e: std::io::Error| {
        std::io::Error::new(e.kind(), format!("{what}: {e}"))
    };
    let result = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| step(&format!("create temp file {tmp:?}"), e))?;
        f.write_all(bytes)
            .map_err(|e| step(&format!("write {tmp:?}"), e))?;
        f.sync_all().map_err(|e| step(&format!("sync {tmp:?}"), e))?;
        drop(f);
        std::fs::rename(&tmp, path).map_err(|e| {
            step(&format!("rename {tmp:?} -> {path:?}"), e)
        })?;
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_replaces() {
        let dir = std::env::temp_dir().join("owf_fsx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("target.bin");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer payload");
        // no stray temp files left behind
        let strays: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().contains(".tmp.")
            })
            .collect();
        assert!(strays.is_empty(), "stray temp files: {strays:?}");
    }

    #[test]
    fn io_variant_preserves_error_kind() {
        let missing = Path::new("/nonexistent_owf_dir_zz/x/y.bin");
        let err = atomic_write_io(missing, b"x").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::NotFound);
        assert!(err.to_string().contains("create temp file"), "{err}");
    }

    #[test]
    fn bare_filename_uses_cwd() {
        // a path with no parent component must not panic
        let name = format!(
            "owf_fsx_bare_{}.tmp_target",
            std::process::id()
        );
        atomic_write(&name, b"x").unwrap();
        assert_eq!(std::fs::read(&name).unwrap(), b"x");
        std::fs::remove_file(&name).unwrap();
    }
}
