//! Summary statistics used throughout the evaluation harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    std(xs) / (xs.len() as f64 - 1.0).sqrt()
}

/// Linear-interpolated quantile, q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// RMS of an f32 slice, computed in f64.
pub fn rms(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
        / xs.len() as f64)
        .sqrt()
}

/// Sum of squared differences, f64 accumulation.
pub fn sq_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

/// The paper's R: RMS error divided by data RMS (§C). SNR = 1/R^2.
pub fn relative_rms_error(original: &[f32], reconstructed: &[f32]) -> f64 {
    let num = sq_err(original, reconstructed);
    let den: f64 = original
        .iter()
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>();
    if den == 0.0 {
        return 0.0;
    }
    (num / den).sqrt()
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        da += (x - ma).powi(2);
        db += (y - mb).powi(2);
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da * db).sqrt()
}

/// Spearman rank correlation (average ranks for ties).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    pearson(&ranks(a), &ranks(b))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&i, &j| xs[i].total_cmp(&xs[j]));
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn r_metric() {
        let orig = [1.0f32, -1.0, 1.0, -1.0];
        let same = orig;
        assert_eq!(relative_rms_error(&orig, &same), 0.0);
        let off = [0.9f32, -0.9, 0.9, -0.9];
        assert!((relative_rms_error(&orig, &off) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn correlations() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
        assert!((spearman(&a, &[1.0, 10.0, 100.0, 1000.0]) - 1.0).abs()
            < 1e-12);
    }

    #[test]
    fn spearman_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let r = ranks(&a);
        assert_eq!(r, vec![0.5, 0.5, 2.0, 3.0]);
    }
}
