//! Foundation utilities implemented from scratch for the offline build:
//! seeded RNG + samplers, JSON, data-parallel helpers, summary statistics,
//! crash-safe file replacement, deterministic fault injection and a
//! miniature property-testing harness.

pub mod faultfs;
pub mod fsx;
pub mod json;
pub mod pool;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod testing;
