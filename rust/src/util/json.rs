//! Minimal JSON value model, parser and serializer.
//!
//! serde is not available in the offline registry, so the `.owt` manifests,
//! `artifacts/manifest.json` and the coordinator's experiment configs /
//! JSONL result store use this from-scratch implementation.  It supports the
//! full JSON grammar (objects, arrays, strings with escapes incl. \uXXXX,
//! numbers, bool, null); object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep insertion order via a Vec of pairs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(pairs) = &mut self {
            pairs.push((key.to_string(), value.into()));
        } else {
            panic!("push on non-object Json");
        }
        self
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Required-field helpers for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing key {key:?}"),
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("key {key:?} is not a string"),
        })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?.as_usize().ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("key {key:?} is not a number"),
        })
    }

    // ---- parse -------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.ws();
        let value = p.value()?;
        p.ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(value)
    }

    /// Sorted-key map view (for comparisons in tests).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = self
                .peek()
                .ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&hex) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.eat(b'u')?;
                                    let low = self.hex4()? as u32;
                                    let cp = 0x10000u32
                                        + ((hex as u32 - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    out.push(
                                        char::from_u32(cp)
                                            .unwrap_or('\u{FFFD}'),
                                    );
                                } else {
                                    out.push('\u{FFFD}');
                                }
                            } else {
                                out.push(
                                    char::from_u32(hex as u32)
                                        .unwrap_or('\u{FFFD}'),
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // copy UTF-8 continuation bytes verbatim
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u16::from_str_radix(s, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---- serialize --------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(pairs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let j = Json::parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e3}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-2500.0));
        let arr = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\n"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"meta":{"kind":"test","nested":{"x":1,"y":[1,2.5,"s"]}},"tensors":[{"name":"a.weight","dtype":"f32","shape":[17,9],"offset":0,"channel_axis":1}]}"#;
        let j = Json::parse(src).unwrap();
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, round);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
        let raw = Json::parse("\"héllo\"").unwrap();
        assert_eq!(raw.as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn builder() {
        let j = Json::obj()
            .push("x", 1.5)
            .push("s", "hi")
            .push("v", vec![1usize, 2, 3]);
        assert_eq!(
            j.to_string(),
            r#"{"x":1.5,"s":"hi","v":[1,2,3]}"#
        );
    }

    #[test]
    fn key_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = j.as_obj().unwrap().iter()
            .map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }
}
