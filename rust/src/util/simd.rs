//! Runtime ISA dispatch for the explicit SIMD hot-loop kernels.
//!
//! The decode/encode hot loops (`Lut::lookup_tile`'s bucket-slot pass,
//! `Codebook::decode_block`'s scaled-codepoint gather, the K-lane rANS
//! round update, FNV checksumming) were written auto-vectorisable, but
//! nothing ever verified they vectorise.  This module makes the vector
//! paths *explicit*: AVX2 / NEON kernels behind one startup-time feature
//! probe, with every scalar kernel kept verbatim as the property-tested
//! oracle (the `decode_ref` / `quantise_ref` pattern).
//!
//! # Dispatch rules
//!
//! * [`detected`] probes the host once: AVX2 on `x86_64` (via
//!   `is_x86_feature_detected!`), NEON on `aarch64` (baseline — every
//!   aarch64 target has it), scalar everywhere else.  A host with
//!   neither AVX2 nor NEON *selects* the scalar fallback; that is a
//!   supported configuration, not an error.
//! * [`active`] resolves the ISA every production call site uses, once,
//!   honouring the forced override `OWF_ISA=scalar|avx2|neon`.  Forcing
//!   an ISA the host cannot run panics at first use (a mis-pinned CI job
//!   must fail loudly, not silently time the wrong kernel); forcing
//!   `scalar` always works.  Tests and `scripts/check.sh` pin the paths
//!   with this knob and diff the outputs.
//! * Each kernel also takes an explicit [`Isa`] so the forced-ISA parity
//!   tests (`rust/tests/simd_props.rs`) can run both paths in one
//!   process without env games.  Passing an ISA the current *binary*
//!   has no code for (e.g. `Neon` on x86_64) falls back to scalar —
//!   only [`active`]/[`supported`] guard against an ISA the *host*
//!   cannot execute.
//!
//! # Kernel invariants (bit-exactness contracts)
//!
//! Every SIMD kernel is bit-identical to its scalar oracle on **all**
//! inputs, including the adversarial set (NaN, ±inf, subnormals, exact
//! midpoints):
//!
//! * `lut_slots`: the scalar saturating `f32 → u32` cast maps NaN and
//!   negatives to 0 and +inf/overflow to `u32::MAX`, then clamps to
//!   `top`.  AVX2 has no saturating convert, so the kernel clamps in the
//!   *float* domain first — `min(max(z, 0.0), top as f32)` — which is
//!   exact because `top < 2^16 < 2^24` is representable, `maxps`
//!   returns its second operand on NaN (so NaN → 0.0 like the cast),
//!   and truncation of a clamped value agrees with clamping the
//!   truncation.  NEON's `FCVTZU` saturates exactly like the Rust cast,
//!   so it needs no float-domain clamp.
//! * `gather_u16_f32`: loads are value-exact by definition; the scalar
//!   oracle's *panic on an out-of-range index* (corrupt `Encoded`) is
//!   preserved by validating each vector of indices against the table
//!   length before any unchecked gather.
//! * `fnv1a64_with`: FNV-1a's `h = (h ^ b) * p` chain is inherently
//!   serial (multiplication does not distribute over XOR), so the fast
//!   path keeps the chain and widens the *loads*: one `u64` load per 8
//!   bytes, unrolled byte extraction from the register.  Bit-identical
//!   by construction; `rust/tests/simd_props.rs` proves it for every
//!   length 0..=64 plus the known test vectors, because every container
//!   checksum depends on it.
//! * The rANS round kernels live in `compress::rans` (they need model
//!   internals); same contract, same oracle pattern.
//!
//! # Per-target lane count
//!
//! [`preferred_lanes`] picks the interleave K for *encode time* from the
//! active ISA width (8 on AVX2 — one 256-bit vector of 32-bit states —
//! else 4).  The lane count is recorded in the container header, so
//! artifacts encoded with any K decode unchanged everywhere; K only has
//! to match the decoder's vector width for the SIMD rANS path to engage.

use std::sync::OnceLock;

/// An instruction-set path a kernel can run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// The portable oracle path — always available, always correct.
    Scalar,
    /// x86_64 AVX2 (256-bit; 8 × f32/u32 per vector).
    Avx2,
    /// aarch64 NEON (128-bit; 4 × f32/u32 per vector).
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    pub fn is_scalar(self) -> bool {
        self == Isa::Scalar
    }

    /// Parse an `OWF_ISA` value. Case-insensitive; `None` on anything
    /// outside `scalar|avx2|neon`.
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// Best ISA the running host supports (no env override applied).
pub fn detected() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Isa::Neon;
    }
    #[allow(unreachable_code)]
    Isa::Scalar
}

/// Can the running host execute `isa`?  Scalar always; AVX2/NEON only
/// with the matching architecture *and* (for AVX2) the CPUID bit.
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                std::arch::is_x86_feature_detected!("avx2")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        Isa::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// Resolve a forced override against what the host supports — the pure
/// core of [`active`], split out so the full decision matrix is unit
/// testable without touching process env.
pub fn resolve(forced: Option<&str>, detected: Isa) -> Result<Isa, String> {
    let raw = match forced {
        None => return Ok(detected),
        Some(raw) => raw,
    };
    let isa = Isa::parse(raw).ok_or_else(|| {
        format!("OWF_ISA={raw:?}: unknown ISA (expected scalar|avx2|neon)")
    })?;
    if supported(isa) {
        Ok(isa)
    } else {
        Err(format!(
            "OWF_ISA={} forced but this host cannot run it (detected: {})",
            isa.name(),
            detected.name()
        ))
    }
}

/// The ISA every production call site dispatches on, resolved once per
/// process: `OWF_ISA` override if set (panics if the host cannot run
/// it), else [`detected`].
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let forced = std::env::var("OWF_ISA").ok();
        match resolve(forced.as_deref(), detected()) {
            Ok(isa) => isa,
            Err(e) => panic!("{e}"),
        }
    })
}

/// Interleave lane count matched to an ISA's 32-bit-element vector
/// width: 8 states fill one AVX2 vector; 4 fill a NEON vector.  Scalar
/// keeps 4 — the superscalar ILP the K-lane design was built for.
pub fn lanes_for(isa: Isa) -> usize {
    match isa {
        Isa::Avx2 => 8,
        Isa::Neon | Isa::Scalar => 4,
    }
}

/// Encode-time K for this process (`lanes_for(active())`) — the `owf
/// pack` default.  Any K decodes anywhere (it is in the container
/// header); matching the target's vector width just lets the SIMD rANS
/// decode rounds engage.
pub fn preferred_lanes() -> usize {
    lanes_for(active())
}

// --------------------------------------------------------------------------
// LUT bucket-slot kernel (`Lut::lookup_tile`'s arithmetic pass)
// --------------------------------------------------------------------------

/// Bucket slots for a batch of queries:
/// `out[i] = (((ys[i] - lo) * inv_step) as u32).min(top)` — the
/// pure-arithmetic pass of `Lut::lookup_tile`, bit-exact across ISAs
/// (see the module invariants).  `top` must be < 2^16 (the LUT bucket
/// budget); lengths must match.
pub fn lut_slots(
    isa: Isa,
    ys: &[f32],
    lo: f32,
    inv_step: f32,
    top: u32,
    out: &mut [u32],
) {
    debug_assert_eq!(ys.len(), out.len());
    debug_assert!(top < 1 << 16);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only ever resolved via active()/supported()
        // on hosts whose CPUID reports it (module docs).
        Isa::Avx2 => unsafe { lut_slots_avx2(ys, lo, inv_step, top, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { lut_slots_neon(ys, lo, inv_step, top, out) },
        _ => lut_slots_scalar(ys, lo, inv_step, top, out),
    }
}

/// The scalar oracle — kept verbatim from the pre-SIMD `lookup_tile`.
fn lut_slots_scalar(
    ys: &[f32],
    lo: f32,
    inv_step: f32,
    top: u32,
    out: &mut [u32],
) {
    for (slot, &y) in out.iter_mut().zip(ys.iter()) {
        *slot = (((y - lo) * inv_step) as u32).min(top);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn lut_slots_avx2(
    ys: &[f32],
    lo: f32,
    inv_step: f32,
    top: u32,
    out: &mut [u32],
) {
    use core::arch::x86_64::*;
    let n = ys.len().min(out.len());
    let vlo = _mm256_set1_ps(lo);
    let vinv = _mm256_set1_ps(inv_step);
    let vzero = _mm256_setzero_ps();
    let vtop = _mm256_set1_ps(top as f32);
    let mut i = 0;
    while i + 8 <= n {
        let y = _mm256_loadu_ps(ys.as_ptr().add(i));
        // same two IEEE ops as the scalar path (Rust never contracts
        // into FMA), so identical rounding
        let z = _mm256_mul_ps(_mm256_sub_ps(y, vlo), vinv);
        // float-domain clamp replaces the saturating cast: maxps
        // returns its second operand on NaN (NaN → 0.0, like `as u32`),
        // negatives → 0, +inf/overflow → top (exact in f32: top < 2^24)
        let z = _mm256_min_ps(_mm256_max_ps(z, vzero), vtop);
        let t = _mm256_cvttps_epi32(z);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, t);
        i += 8;
    }
    lut_slots_scalar(&ys[i..n], lo, inv_step, top, &mut out[i..n]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn lut_slots_neon(
    ys: &[f32],
    lo: f32,
    inv_step: f32,
    top: u32,
    out: &mut [u32],
) {
    use core::arch::aarch64::*;
    let n = ys.len().min(out.len());
    let vlo = vdupq_n_f32(lo);
    let vinv = vdupq_n_f32(inv_step);
    let vtop = vdupq_n_u32(top);
    let mut i = 0;
    while i + 4 <= n {
        let y = vld1q_f32(ys.as_ptr().add(i));
        let z = vmulq_f32(vsubq_f32(y, vlo), vinv);
        // FCVTZU saturates exactly like Rust's `as u32` (NaN → 0,
        // negative → 0, overflow → u32::MAX), so clamp after converting
        let t = vminq_u32(vcvtq_u32_f32(z), vtop);
        vst1q_u32(out.as_mut_ptr().add(i), t);
        i += 4;
    }
    lut_slots_scalar(&ys[i..n], lo, inv_step, top, &mut out[i..n]);
}

// --------------------------------------------------------------------------
// Scaled-codepoint gather (`Codebook::decode_block`'s inner loop)
// --------------------------------------------------------------------------

/// `out[i] = table[indices[i]]` — the scaled-codepoint gather of
/// `Codebook::decode_block`.  Panics on an out-of-range index exactly
/// like the scalar oracle (a corrupt `Encoded` must never become an
/// unchecked out-of-bounds gather); each vector of indices is validated
/// against `table.len()` before its gather.  `table.len()` must be
/// ≤ 2^16 (u16 index space).
pub fn gather_u16_f32(
    isa: Isa,
    table: &[f32],
    indices: &[u16],
    out: &mut [f32],
) {
    debug_assert_eq!(indices.len(), out.len());
    debug_assert!(table.len() <= 1 << 16);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 only resolves on hosts that report it.
        Isa::Avx2 => unsafe { gather_u16_f32_avx2(table, indices, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { gather_u16_f32_neon(table, indices, out) },
        _ => gather_u16_f32_scalar(table, indices, out),
    }
}

/// The scalar oracle — the bounds-checked indexed loop, verbatim.
fn gather_u16_f32_scalar(table: &[f32], indices: &[u16], out: &mut [f32]) {
    for (slot, &i) in out.iter_mut().zip(indices.iter()) {
        *slot = table[i as usize];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_u16_f32_avx2(
    table: &[f32],
    indices: &[u16],
    out: &mut [f32],
) {
    use core::arch::x86_64::*;
    let n = out.len().min(indices.len());
    // signed compare is safe: zero-extended u16 and table.len() ≤ 2^16
    // are both non-negative in i32
    let limit = _mm256_set1_epi32(table.len() as i32 - 1);
    let mut i = 0;
    while i + 8 <= n {
        let idx16 =
            _mm_loadu_si128(indices.as_ptr().add(i) as *const __m128i);
        let idx = _mm256_cvtepu16_epi32(idx16);
        let oob = _mm256_cmpgt_epi32(idx, limit);
        if _mm256_movemask_epi8(oob) != 0 {
            // corrupt index: re-run the oracle for its exact panic
            gather_u16_f32_scalar(table, &indices[i..n], &mut out[i..n]);
            unreachable!("scalar gather must panic on out-of-range index");
        }
        let v = _mm256_i32gather_ps::<4>(table.as_ptr(), idx);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), v);
        i += 8;
    }
    gather_u16_f32_scalar(table, &indices[i..n], &mut out[i..n]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn gather_u16_f32_neon(
    table: &[f32],
    indices: &[u16],
    out: &mut [f32],
) {
    use core::arch::aarch64::*;
    let n = out.len().min(indices.len());
    let mut i = 0;
    while i + 8 <= n {
        let idx = vld1q_u16(indices.as_ptr().add(i));
        if (vmaxvq_u16(idx) as usize) >= table.len() {
            gather_u16_f32_scalar(table, &indices[i..n], &mut out[i..n]);
            unreachable!("scalar gather must panic on out-of-range index");
        }
        // NEON has no hardware gather; the win is one vector bounds
        // check hoisted over 8 unchecked loads
        for k in 0..8 {
            *out.get_unchecked_mut(i + k) = *table
                .get_unchecked(*indices.get_unchecked(i + k) as usize);
        }
        i += 8;
    }
    gather_u16_f32_scalar(table, &indices[i..n], &mut out[i..n]);
}

// --------------------------------------------------------------------------
// FNV-1a 64 (the container checksum)
// --------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a 64 with ISA dispatch: the byte-serial oracle under a forced
/// scalar pin, the word-at-a-time loads otherwise.  Both are
/// bit-identical (the hash chain itself is untouched — see the module
/// invariants), so container checksums never depend on the path taken.
pub fn fnv1a64_with(isa: Isa, bytes: &[u8]) -> u64 {
    if isa.is_scalar() {
        fnv1a64_ref(bytes)
    } else {
        fnv1a64_words(bytes)
    }
}

/// The byte-serial oracle — the original definition, verbatim.  Each
/// step `h = (h ^ b) * prime` is a bijection of `h` (odd multiplier mod
/// 2^64): the single-byte-flip detection guarantee the fault suite
/// leans on.
pub fn fnv1a64_ref(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Word-at-a-time FNV-1a 64: one `u64` load per 8 bytes, bytes then
/// extracted from the register in stream order (little-endian load puts
/// the first byte in the low lane).  The multiply chain stays serial —
/// it must, for bit-identity — so the speedup is purely fewer memory
/// operations and a fully unrolled inner step.
pub fn fnv1a64_words(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    let mut chunks = bytes.chunks_exact(8);
    for chunk in &mut chunks {
        let mut w = u64::from_le_bytes(chunk.try_into().unwrap());
        for _ in 0..8 {
            h = (h ^ (w & 0xFF)).wrapping_mul(FNV_PRIME);
            w >>= 8;
        }
    }
    for &b in chunks.remainder() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn isas() -> Vec<Isa> {
        let mut v = vec![Isa::Scalar];
        if detected() != Isa::Scalar {
            v.push(detected());
        }
        v
    }

    #[test]
    fn detected_is_supported_and_resolves() {
        let d = detected();
        assert!(supported(d));
        assert!(supported(Isa::Scalar), "scalar is always supported");
        assert_eq!(resolve(None, d), Ok(d));
        assert_eq!(resolve(Some("scalar"), d), Ok(Isa::Scalar));
        assert_eq!(resolve(Some("SCALAR"), d), Ok(Isa::Scalar));
        // forcing the detected ISA by name is always accepted
        assert_eq!(resolve(Some(d.name()), d), Ok(d));
        // unknown names error with the knob named
        let err = resolve(Some("sse9"), d).unwrap_err();
        assert!(err.contains("OWF_ISA"), "{err}");
        // forcing an ISA the host cannot run errors (never silently
        // falls back — a mis-pinned CI job must fail loudly)
        for isa in [Isa::Avx2, Isa::Neon] {
            if !supported(isa) {
                assert!(resolve(Some(isa.name()), d).is_err());
            }
        }
    }

    #[test]
    fn lane_counts_match_vector_widths() {
        assert_eq!(lanes_for(Isa::Scalar), 4);
        assert_eq!(lanes_for(Isa::Neon), 4);
        assert_eq!(lanes_for(Isa::Avx2), 8);
        assert_eq!(preferred_lanes(), lanes_for(active()));
    }

    #[test]
    fn lut_slots_parity_on_adversarial_inputs() {
        let mut rng = Rng::new(11);
        let mut ys: Vec<f32> = (0..333)
            .map(|_| (rng.f64() * 8.0 - 4.0) as f32)
            .collect();
        ys.extend([
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            1e-42,
            -1e-42,
            0.0,
            -0.0,
            f32::MAX,
            f32::MIN,
        ]);
        let (lo, inv_step, top) = (-3.25f32, 37.5f32, 1023u32);
        let mut want = vec![0u32; ys.len()];
        lut_slots(Isa::Scalar, &ys, lo, inv_step, top, &mut want);
        for isa in isas() {
            let mut got = vec![0u32; ys.len()];
            lut_slots(isa, &ys, lo, inv_step, top, &mut got);
            assert_eq!(got, want, "lut_slots diverges on {}", isa.name());
        }
    }

    #[test]
    fn gather_parity_and_oob_panic() {
        let mut rng = Rng::new(5);
        let table: Vec<f32> = (0..100)
            .map(|i| if i == 7 { f32::NAN } else { i as f32 * 0.5 })
            .collect();
        let indices: Vec<u16> =
            (0..517).map(|_| rng.below(100) as u16).collect();
        let mut want = vec![0f32; indices.len()];
        gather_u16_f32_scalar(&table, &indices, &mut want);
        for isa in isas() {
            let mut got = vec![0f32; indices.len()];
            gather_u16_f32(isa, &table, &indices, &mut got);
            // bit compare: NaN lanes must survive the gather too
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "gather diverges on {}", isa.name());
            // out-of-range index panics on every path (corrupt Encoded)
            let mut bad = indices.clone();
            bad[200] = 100;
            let r = std::panic::catch_unwind(|| {
                let mut out = vec![0f32; bad.len()];
                gather_u16_f32(isa, &table, &bad, &mut out);
            });
            assert!(r.is_err(), "{}: OOB index must panic", isa.name());
        }
    }

    #[test]
    fn fnv_known_vectors_and_all_lengths() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(fnv1a64_with(isa, b""), 0xcbf29ce484222325);
            assert_eq!(fnv1a64_with(isa, b"a"), 0xaf63dc4c8601ec8c);
        }
        let mut rng = Rng::new(3);
        let buf: Vec<u8> =
            (0..64).map(|_| rng.below(256) as u8).collect();
        for len in 0..=64 {
            let want = fnv1a64_ref(&buf[..len]);
            assert_eq!(fnv1a64_words(&buf[..len]), want, "len {len}");
            for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
                assert_eq!(fnv1a64_with(isa, &buf[..len]), want);
            }
        }
    }
}
