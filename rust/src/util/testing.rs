//! A miniature property-based testing harness (proptest is unavailable in
//! the offline registry).
//!
//! `check(name, cases, |g| ...)` runs a closure against `cases` seeded
//! generators; on failure it re-runs a bounded shrink loop over the seed
//! space is not attempted (seeds are reported instead so failures reproduce
//! exactly). The `Gen` type wraps [`crate::util::rng::Rng`] with convenience
//! draws used by the property tests across the crate.

use crate::util::rng::Rng;

/// Property-test input generator: a seeded RNG plus sizing helpers.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Vector length that grows with the case index (small cases first).
    pub fn size(&mut self, max: usize) -> usize {
        let cap = 1 + (self.case * max) / 96_usize.max(self.case + 1);
        self.rng.below(cap.min(max)) + 1
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|_| (self.rng.normal() as f32) * scale)
            .collect()
    }

    pub fn heavy_tailed_vec(&mut self, n: usize) -> Vec<f32> {
        let nu = self.rng.range(3.0, 12.0);
        (0..n)
            .map(|_| self.rng.student_t(nu) as f32)
            .collect()
    }

    pub fn bits(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.rng.below((hi - lo + 1) as usize) as u32
    }
}

/// Run `property` against `cases` deterministic cases. Panics with the
/// failing seed on the first violation.
pub fn check(name: &str, cases: usize, mut property: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
        };
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| property(&mut g)),
        );
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 50, |g| {
            let n = g.size(100);
            let v = g.f32_vec(n, 1.0);
            let a: f32 = v.iter().sum();
            let b: f32 = v.iter().rev().sum();
            assert!((a - b).abs() <= 1e-3 * a.abs().max(1.0));
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn reports_failing_case() {
        check("always-fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("collect", 5, |g| {
            first.push(g.rng.next_u64());
        });
        let mut second = Vec::new();
        check("collect", 5, |g| {
            second.push(g.rng.next_u64());
        });
        assert_eq!(first, second);
    }
}
