//! Deterministic pseudo-random number generation and samplers.
//!
//! The offline build environment carries no `rand`/`rand_distr`, so this is a
//! from-scratch implementation: [xoshiro256++] as the core generator plus the
//! samplers the paper's simulated-data analyses need (§3/§C): Normal
//! (polar Marsaglia), Laplace (inverse cdf), Student-t (normal / sqrt
//! (chi²/ν) with Marsaglia–Tsang gamma), uniform and categorical draws.
//!
//! [xoshiro256++]: https://prng.di.unimi.it/

/// xoshiro256++ generator. Deterministic, seedable, fast, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64, used to expand a single seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for parallel workers).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift rejection-free (bias < 2^-64 * n, fine here)
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via polar Marsaglia (pairs cached).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Standard Laplace (scale 1) via inverse cdf.
    pub fn laplace(&mut self) -> f64 {
        let u = self.f64() - 0.5;
        let inner = (1.0 - 2.0 * u.abs()).max(f64::MIN_POSITIVE);
        if u >= 0.0 {
            -inner.ln()
        } else {
            inner.ln()
        }
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (k >= 1 fast path,
    /// boost for k < 1).
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0);
            return g * self.f64_open().powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64_open();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Student-t with `nu` degrees of freedom (scale 1).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        let z = self.normal();
        let chi2 = 2.0 * self.gamma(nu / 2.0);
        z / (chi2 / nu).sqrt()
    }

    /// Fill a vector of standard-normal f32 samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    pub fn laplace_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.laplace() as f32).collect()
    }

    pub fn student_t_vec(&mut self, nu: f64, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.student_t(nu) as f32).collect()
    }

    /// Random index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

/// Zipf-distributed index sampler over `{0, .., n-1}` with exponent `s`:
/// P(k) ∝ 1/(k+1)^s.  `s == 0` is uniform; `s ≈ 1` is the classic
/// heavy-head popularity law serving benchmarks model tensor access
/// with.  The CDF is precomputed once (O(n)) so sampling is a binary
/// search (O(log n)) — cheap enough for open-loop load generation.
pub struct Zipf {
    /// Cumulative probabilities; `cdf[n-1] == 1.0`.
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf needs a non-empty support");
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 0..n {
            total += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Exact probability of index `k` (test/reporting support).
    pub fn pmf(&self, k: usize) -> f64 {
        let prev = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - prev
    }

    /// Draw one index using `rng`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // first index whose cumulative probability exceeds u
        let mut lo = 0;
        let mut hi = self.cdf.len() - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.cdf[mid] > u {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn determinism() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let (mean, var) = moments(&xs);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..200_000).map(|_| r.laplace()).collect();
        let (mean, var) = moments(&xs);
        assert!(mean.abs() < 0.02, "mean {mean}");
        // Laplace(scale 1) variance = 2
        assert!((var - 2.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn student_t_moments() {
        let mut r = Rng::new(4);
        let nu = 7.0;
        let xs: Vec<f64> = (0..200_000).map(|_| r.student_t(nu)).collect();
        let (mean, var) = moments(&xs);
        assert!(mean.abs() < 0.05, "mean {mean}");
        // var = nu / (nu - 2) = 1.4
        assert!((var - 1.4).abs() < 0.12, "var {var}");
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(5);
        for &k in &[0.5, 1.0, 2.5, 10.0] {
            let xs: Vec<f64> = (0..100_000).map(|_| r.gamma(k)).collect();
            let (mean, _) = moments(&xs);
            assert!((mean - k).abs() < 0.05 * k.max(1.0), "k={k} mean={mean}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_distribution() {
        let mut r = Rng::new(7);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 1e5 - 0.7).abs() < 0.01);
        assert!((counts[1] as f64 / 1e5 - 0.2).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_pmf_matches_samples() {
        let z = Zipf::new(8, 1.0);
        // CDF is normalised and the pmf sums to one
        let total: f64 = (0..8).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12, "pmf sums to {total}");
        // rank 0 vs rank 1 probability ratio is 2^s = 2
        assert!((z.pmf(0) / z.pmf(1) - 2.0).abs() < 1e-9);
        let mut r = Rng::new(9);
        let mut counts = [0usize; 8];
        let n = 200_000;
        for _ in 0..n {
            counts[z.sample(&mut r)] += 1;
        }
        for k in 0..8 {
            let emp = counts[k] as f64 / n as f64;
            assert!(
                (emp - z.pmf(k)).abs() < 0.01,
                "rank {k}: empirical {emp} vs pmf {}",
                z.pmf(k)
            );
        }
    }

    #[test]
    fn zipf_zero_exponent_is_uniform() {
        let z = Zipf::new(5, 0.0);
        for k in 0..5 {
            assert!((z.pmf(k) - 0.2).abs() < 1e-12);
        }
    }

    #[test]
    fn zipf_sampling_is_deterministic_per_seed() {
        let z = Zipf::new(16, 1.2);
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let xs: Vec<usize> = (0..64).map(|_| z.sample(&mut a)).collect();
        let ys: Vec<usize> = (0..64).map(|_| z.sample(&mut b)).collect();
        assert_eq!(xs, ys);
    }
}
