//! Deterministic fault injection for artifact byte access.
//!
//! [`ByteSource`] abstracts "where container bytes come from" so the
//! artifact reader runs identically over a pread-backed file (`File`,
//! the production path: positioned per-section reads at the recorded
//! offsets on one shared descriptor — no whole-file image), a pristine
//! in-memory image (`Mem` — zero-copy reads) and a fault-injecting
//! wrapper (`Fault`).  [`FaultFs`] injects the fault classes the
//! serving layer must survive:
//!
//! * **single-bit flips** at chosen byte/bit offsets (silent media or DMA
//!   corruption — the checksum layer must catch every one);
//! * **truncation** (a torn non-atomic write or short download);
//! * **transient `EIO`** that fails the next N reads and then succeeds
//!   (flaky NFS / overloaded block layer — the retry layer's territory),
//!   either counted or as a seeded per-read probability;
//! * **torn temp+rename** simulation via [`write_torn_copy`] (what a crash
//!   mid-`atomic_write` would leave if the write were *not* atomic).
//!
//! All randomness is seeded ([`crate::util::rng::Rng`]) so every fault
//! plan reproduces bit-for-bit from its seed — no `Date::now`, no OS RNG.

use std::borrow::Cow;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::rng::Rng;

/// Byte provider for artifact readers: a pread-backed file, pristine
/// memory, or faulty memory.
pub enum ByteSource {
    /// Production path: positioned reads against an open descriptor.
    File(FileSource),
    /// Whole container image in memory. Reads borrow.
    Mem(Vec<u8>),
    /// Test/chaos path: reads copy, with faults injected per the plan.
    Fault(FaultFs),
}

impl ByteSource {
    /// Open `path` for positioned per-section reads (the `Artifact::open`
    /// production path).
    pub fn open_file(path: impl AsRef<Path>) -> io::Result<ByteSource> {
        Ok(ByteSource::File(FileSource::open(path)?))
    }

    /// Visible length of the container (truncation shrinks it).
    pub fn len(&self) -> usize {
        match self {
            ByteSource::File(f) => f.len(),
            ByteSource::Mem(b) => b.len(),
            ByteSource::Fault(f) => f.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read `len` bytes at `off`. Out-of-range reads fail with
    /// `UnexpectedEof` (a permanent shape error, not a retry candidate);
    /// injected transient faults surface as `Interrupted`.
    pub fn read_at(&self, off: usize, len: usize) -> io::Result<Cow<'_, [u8]>> {
        match self {
            ByteSource::File(f) => f.read_at(off, len).map(Cow::Owned),
            ByteSource::Mem(b) => {
                let end = off.checked_add(len).filter(|&e| e <= b.len());
                match end {
                    Some(end) => Ok(Cow::Borrowed(&b[off..end])),
                    None => Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        format!(
                            "read {len} bytes at {off} beyond container \
                             end {}",
                            b.len()
                        ),
                    )),
                }
            }
            ByteSource::Fault(f) => f.read_at(off, len).map(Cow::Owned),
        }
    }
}

/// Pread-backed container access: one shared descriptor, positioned
/// reads, length snapshotted at open.  Reads past the snapshot fail
/// `UnexpectedEof` *before* touching the file (the same permanent shape
/// error `Mem` reports), and a file truncated underneath us surfaces the
/// kernel's short read as `UnexpectedEof` too — torn, not transient.  On
/// unix this is `pread(2)` (thread-safe on the shared fd — concurrent
/// decoders never contend on a cursor); elsewhere each read seeks a
/// cloned descriptor so the shared one stays position-free.
pub struct FileSource {
    file: std::fs::File,
    len: usize,
}

impl FileSource {
    pub fn open(path: impl AsRef<Path>) -> io::Result<FileSource> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        let len = usize::try_from(len).map_err(|_| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("container larger than the address space: {len}"),
            )
        })?;
        Ok(FileSource { file, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn read_at(&self, off: usize, len: usize) -> io::Result<Vec<u8>> {
        off.checked_add(len).filter(|&e| e <= self.len).ok_or_else(
            || {
                io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!(
                        "read {len} bytes at {off} beyond container end {}",
                        self.len
                    ),
                )
            },
        )?;
        let mut buf = vec![0u8; len];
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(&mut buf, off as u64)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut f = self.file.try_clone()?;
            f.seek(SeekFrom::Start(off as u64))?;
            f.read_exact(&mut buf)?;
        }
        Ok(buf)
    }
}

/// A seeded fault plan over one container image. Built with the
/// `with_*` builders, then handed to `Artifact::from_source`.
pub struct FaultFs {
    bytes: Vec<u8>,
    /// Visible length; reads past it fail `UnexpectedEof`.
    visible_len: usize,
    /// (byte offset, bit index 0..8) flips applied to read results.
    flips: Vec<(usize, u8)>,
    /// The next N reads fail with a transient `Interrupted` error.
    transient_reads: AtomicU64,
    /// Per-offset budgets: the next N reads *covering that byte* fail
    /// transiently.  Unlike the global counter this spares unrelated
    /// reads (e.g. the open-time header/manifest reads), so a test can
    /// park one specific decode in a retry backoff.
    transient_at: Vec<(usize, AtomicU64)>,
    /// Seeded per-read probability of a transient failure (0 disables).
    transient_rate: f64,
    rng: Mutex<Rng>,
    /// Total reads that were failed transiently (for test assertions).
    transient_fired: AtomicU64,
}

impl FaultFs {
    pub fn new(bytes: Vec<u8>) -> FaultFs {
        let visible_len = bytes.len();
        FaultFs {
            bytes,
            visible_len,
            flips: Vec::new(),
            transient_reads: AtomicU64::new(0),
            transient_at: Vec::new(),
            transient_rate: 0.0,
            rng: Mutex::new(Rng::new(0)),
            transient_fired: AtomicU64::new(0),
        }
    }

    /// Flip bit `bit` (0..8) of the byte at `offset` in every read that
    /// covers it.
    pub fn with_flip(mut self, offset: usize, bit: u8) -> FaultFs {
        assert!(bit < 8, "bit index out of range");
        self.flips.push((offset, bit));
        self
    }

    /// Truncate the visible container to its first `keep` bytes.
    pub fn with_truncation(mut self, keep: usize) -> FaultFs {
        self.visible_len = keep.min(self.bytes.len());
        self
    }

    /// Fail the next `n` reads with a transient error, then succeed.
    pub fn with_transient_reads(self, n: u64) -> FaultFs {
        self.transient_reads.store(n, Ordering::Relaxed);
        self
    }

    /// Fail the next `n` reads that cover byte `offset` with a transient
    /// error, then succeed.  Reads elsewhere are untouched.
    pub fn with_transient_at(mut self, offset: usize, n: u64) -> FaultFs {
        self.transient_at.push((offset, AtomicU64::new(n)));
        self
    }

    /// Fail each read independently with probability `rate`, seeded.
    pub fn with_transient_rate(mut self, rate: f64, seed: u64) -> FaultFs {
        self.transient_rate = rate.clamp(0.0, 1.0);
        self.rng = Mutex::new(Rng::new(seed));
        self
    }

    pub fn len(&self) -> usize {
        self.visible_len
    }

    pub fn is_empty(&self) -> bool {
        self.visible_len == 0
    }

    /// Number of reads that have been failed transiently so far.
    pub fn transient_fired(&self) -> u64 {
        self.transient_fired.load(Ordering::Relaxed)
    }

    /// The damaged image as the reader would see it end-to-end
    /// (truncation + flips applied) — for `from_bytes`-style tests.
    pub fn image(&self) -> Vec<u8> {
        let mut out = self.bytes[..self.visible_len].to_vec();
        for &(off, bit) in &self.flips {
            if off < out.len() {
                out[off] ^= 1 << bit;
            }
        }
        out
    }

    pub fn read_at(&self, off: usize, len: usize) -> io::Result<Vec<u8>> {
        // Transient faults fire before any byte inspection, like a real
        // block-layer error would.
        let counted = loop {
            let n = self.transient_reads.load(Ordering::Relaxed);
            if n == 0 {
                break false;
            }
            if self
                .transient_reads
                .compare_exchange(
                    n,
                    n - 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                break true;
            }
        };
        let targeted = self.transient_at.iter().any(|(toff, budget)| {
            if *toff < off || *toff >= off.saturating_add(len) {
                return false;
            }
            loop {
                let n = budget.load(Ordering::Relaxed);
                if n == 0 {
                    return false;
                }
                if budget
                    .compare_exchange(
                        n,
                        n - 1,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    return true;
                }
            }
        });
        let rolled = self.transient_rate > 0.0
            && self.rng.lock().unwrap().f64() < self.transient_rate;
        if counted || targeted || rolled {
            self.transient_fired.fetch_add(1, Ordering::Relaxed);
            return Err(io::Error::new(
                io::ErrorKind::Interrupted,
                "injected transient read fault",
            ));
        }
        let end = off.checked_add(len).filter(|&e| e <= self.visible_len);
        let end = end.ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!(
                    "read {len} bytes at {off} beyond container end {}",
                    self.visible_len
                ),
            )
        })?;
        let mut out = self.bytes[off..end].to_vec();
        for &(foff, bit) in &self.flips {
            if foff >= off && foff < end {
                out[foff - off] ^= 1 << bit;
            }
        }
        Ok(out)
    }
}

/// Simulate a crash mid non-atomic write: write only the first
/// `frac` of `bytes` to `path`, leaving a torn file on disk.
pub fn write_torn_copy(
    path: impl AsRef<Path>,
    bytes: &[u8],
    frac: f64,
) -> io::Result<()> {
    let keep = ((bytes.len() as f64) * frac.clamp(0.0, 1.0)) as usize;
    std::fs::write(path, &bytes[..keep.min(bytes.len())])
}

/// Flip one bit of a file in place (fault-injection CLI + tests).
pub fn flip_bit_in_file(
    path: impl AsRef<Path>,
    offset: usize,
    bit: u8,
) -> io::Result<()> {
    let path = path.as_ref();
    let mut bytes = std::fs::read(path)?;
    if offset >= bytes.len() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("offset {offset} beyond file end {}", bytes.len()),
        ));
    }
    bytes[offset] ^= 1 << (bit & 7);
    std::fs::write(path, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_reads_borrow_and_bound_check() {
        let src = ByteSource::Mem(vec![1, 2, 3, 4]);
        assert_eq!(&*src.read_at(1, 2).unwrap(), &[2, 3]);
        let err = src.read_at(3, 2).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // overflow-safe bounds
        assert!(src.read_at(usize::MAX, 2).is_err());
    }

    #[test]
    fn flips_apply_only_inside_read_window() {
        let f = FaultFs::new(vec![0u8; 8]).with_flip(4, 0);
        assert_eq!(f.read_at(0, 4).unwrap(), vec![0, 0, 0, 0]);
        assert_eq!(f.read_at(4, 1).unwrap(), vec![1]);
        assert_eq!(f.read_at(2, 4).unwrap(), vec![0, 0, 1, 0]);
        assert_eq!(f.image(), vec![0, 0, 0, 0, 1, 0, 0, 0]);
    }

    #[test]
    fn truncation_shrinks_visible_length() {
        let f = FaultFs::new(vec![9u8; 10]).with_truncation(6);
        assert_eq!(f.len(), 6);
        assert!(f.read_at(0, 6).is_ok());
        let err = f.read_at(4, 4).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn counted_transient_faults_then_recover() {
        let f = FaultFs::new(vec![7u8; 4]).with_transient_reads(2);
        let e1 = f.read_at(0, 4).unwrap_err();
        assert_eq!(e1.kind(), io::ErrorKind::Interrupted);
        let e2 = f.read_at(0, 4).unwrap_err();
        assert_eq!(e2.kind(), io::ErrorKind::Interrupted);
        assert_eq!(f.read_at(0, 4).unwrap(), vec![7, 7, 7, 7]);
        assert_eq!(f.transient_fired(), 2);
    }

    #[test]
    fn targeted_transients_spare_other_reads() {
        let f = FaultFs::new(vec![5u8; 16]).with_transient_at(10, 2);
        // reads not covering byte 10 never fire
        assert!(f.read_at(0, 8).is_ok());
        assert!(f.read_at(11, 4).is_ok());
        // covering reads fire exactly twice, then recover
        assert_eq!(
            f.read_at(8, 4).unwrap_err().kind(),
            io::ErrorKind::Interrupted
        );
        assert_eq!(
            f.read_at(10, 1).unwrap_err().kind(),
            io::ErrorKind::Interrupted
        );
        assert_eq!(f.read_at(8, 4).unwrap(), vec![5; 4]);
        assert_eq!(f.transient_fired(), 2);
    }

    #[test]
    fn seeded_rate_is_reproducible() {
        let run = |seed| {
            let f = FaultFs::new(vec![0u8; 2]).with_transient_rate(0.5, seed);
            (0..64).map(|_| f.read_at(0, 1).is_err()).collect::<Vec<_>>()
        };
        assert_eq!(run(41), run(41));
        assert_ne!(run(41), run(42), "different seeds, different plans");
        let fired = run(41).iter().filter(|&&e| e).count();
        assert!(fired > 8 && fired < 56, "rate wildly off: {fired}/64");
    }

    #[test]
    fn file_source_preads_match_mem() {
        let dir = std::env::temp_dir().join("owf_faultfs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path =
            dir.join(format!("pread_{}.bin", std::process::id()));
        let bytes: Vec<u8> = (0..=255u8).collect();
        std::fs::write(&path, &bytes).unwrap();
        let file = ByteSource::open_file(&path).unwrap();
        let mem = ByteSource::Mem(bytes);
        assert_eq!(file.len(), mem.len());
        for (off, len) in [(0, 256), (0, 0), (17, 99), (255, 1), (256, 0)]
        {
            assert_eq!(
                &*file.read_at(off, len).unwrap(),
                &*mem.read_at(off, len).unwrap(),
                "window ({off}, {len})"
            );
        }
        // out-of-range windows are the same permanent shape error
        for (off, len) in [(250, 10), (256, 1), (usize::MAX, 2)] {
            assert_eq!(
                file.read_at(off, len).unwrap_err().kind(),
                io::ErrorKind::UnexpectedEof,
                "window ({off}, {len})"
            );
        }
        // truncation underneath the open descriptor reads as torn, not
        // stale data: the snapshot length still admits the window but
        // the kernel's short read must surface as UnexpectedEof
        std::fs::write(&path, [1u8, 2, 3]).unwrap();
        assert_eq!(
            file.read_at(0, 256).unwrap_err().kind(),
            io::ErrorKind::UnexpectedEof
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn torn_copy_writes_prefix() {
        let dir = std::env::temp_dir().join("owf_faultfs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("torn_{}.bin", std::process::id()));
        write_torn_copy(&path, &[1, 2, 3, 4, 5, 6, 7, 8], 0.5).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2, 3, 4]);
        flip_bit_in_file(&path, 0, 1).unwrap();
        assert_eq!(std::fs::read(&path).unwrap()[0], 3);
        std::fs::remove_file(&path).unwrap();
    }
}
