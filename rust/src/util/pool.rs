//! Minimal data-parallel helpers built on `std::thread::scope`.
//!
//! rayon is not available offline, so the coordinator and the simulated-data
//! sweeps use these: `par_map` (index-preserving parallel map over items)
//! and `par_chunks_mut` (parallel mutation of disjoint slice chunks).
//!
//! Nested parallelism is flattened: a closure already running on a pool
//! worker executes nested `par_map`/`par_chunks_mut` calls serially, so a
//! sweep fanning N jobs over N workers whose per-tensor qdq also wants to
//! parallelise does not explode into N² threads.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a pool worker (nested calls go serial).
pub fn on_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Element count below which the hot paths stay serial — one shared cutoff
/// so the parallel/serial split stays consistent across `quant`, the grid
/// recon and [`par_elementwise`].
pub const PAR_THRESHOLD: usize = 1 << 16;

/// Number of worker threads to use (respects `OWF_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("OWF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Parallel map with order-preserving results and work stealing via an
/// atomic cursor. `f` must be `Sync` (called concurrently), items are read
/// by shared reference.
pub fn par_map<T: Sync, R: Send>(
    items: &[T],
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().min(n);
    if workers == 1 || on_worker() {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    // SAFETY-free design: workers collect (index, result) locally, merged
    // under the mutex at the end of each worker's life.
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                let mut guard = slots.lock().unwrap();
                for (i, r) in local {
                    guard[i] = Some(r);
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker died")).collect()
}

/// Parallel in-place transform over disjoint chunks of a mutable slice.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    chunk: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    par_chunks_mut_map(data, chunk, |idx, slice| f(idx, slice));
}

/// [`par_chunks_mut`] that also carries a per-chunk result back to the
/// caller, in chunk order — the fused encode kernel uses this to return
/// per-chunk scales, error partials and index histograms from the single
/// pass instead of re-walking the data.
pub fn par_chunks_mut_map<T: Send, R: Send>(
    data: &mut [T],
    chunk: usize,
    f: impl Fn(usize, &mut [T]) -> R + Sync,
) -> Vec<R> {
    let workers = num_threads();
    if workers == 1 || on_worker() {
        return data
            .chunks_mut(chunk.max(1))
            .enumerate()
            .map(|(idx, slice)| f(idx, slice))
            .collect();
    }
    let chunks: Vec<(usize, &mut [T])> =
        data.chunks_mut(chunk.max(1)).enumerate().collect();
    let cursor = AtomicUsize::new(0);
    let n = chunks.len();
    let chunks = Mutex::new(
        chunks
            .into_iter()
            .map(Some)
            .collect::<Vec<Option<(usize, &mut [T])>>>(),
    );
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    let workers = workers.min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let taken = chunks.lock().unwrap()[i].take();
                    if let Some((idx, slice)) = taken {
                        local.push((idx, f(idx, slice)));
                    }
                }
                let mut guard = slots.lock().unwrap();
                for (i, r) in local {
                    guard[i] = Some(r);
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker died")).collect()
}

/// Elementwise parallel transform: one contiguous chunk per worker once
/// the slice is large enough to amortise the fan-out — the shared idiom of
/// the grid-reconstruction and tensor-qdq hot paths.
pub fn par_elementwise<T: Send>(
    data: &mut [T],
    f: impl Fn(&mut T) + Sync,
) {
    if data.len() < PAR_THRESHOLD {
        for x in data.iter_mut() {
            f(x);
        }
        return;
    }
    let chunk = data.len().div_ceil(num_threads()).max(1);
    par_chunks_mut(data, chunk, |_, c| {
        for x in c.iter_mut() {
            f(x);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn nested_calls_flatten_to_serial() {
        // a nested par_map inside a pool worker must run inline (on_worker)
        // and still produce correct results
        let outer: Vec<usize> = (0..64).collect();
        let out = par_map(&outer, |_, &x| {
            assert!(
                num_threads() == 1 || on_worker(),
                "closure should run on a pool worker"
            );
            let inner: Vec<usize> = (0..50).collect();
            let inner_out = par_map(&inner, |_, &y| y + x);
            inner_out.iter().sum::<usize>()
        });
        for (x, &s) in outer.iter().zip(&out) {
            assert_eq!(s, 50 * x + 49 * 50 / 2);
        }
        assert!(!on_worker(), "flag must not leak to the caller thread");
    }

    #[test]
    fn par_map_panic_propagates_without_deadlock() {
        // a panicking closure must panic the calling thread (via scope
        // join), not hang the remaining workers — the scheduler relies on
        // this to surface worker bugs instead of stalling a 500-job sweep
        let items: Vec<usize> = (0..200).collect();
        let result = std::panic::catch_unwind(|| {
            par_map(&items, |_, &x| {
                if x == 97 {
                    panic!("worker bug");
                }
                x
            })
        });
        assert!(result.is_err(), "panic must propagate to the caller");
        // the pool must still be usable afterwards
        let ok = par_map(&items, |_, &x| x * 2);
        assert_eq!(ok[100], 200);
    }

    #[test]
    fn par_chunks_mut_panic_propagates() {
        let mut data = vec![0u32; 1000];
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                par_chunks_mut(&mut data, 100, |idx, _| {
                    if idx == 3 {
                        panic!("chunk bug");
                    }
                });
            }),
        );
        assert!(result.is_err());
    }

    #[test]
    fn par_chunks_mut_map_returns_in_chunk_order() {
        let mut data = vec![1u64; 10_000];
        let sums = par_chunks_mut_map(&mut data, 333, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x += idx as u64;
            }
            chunk.iter().sum::<u64>()
        });
        assert_eq!(sums.len(), 10_000usize.div_ceil(333));
        for (idx, &s) in sums.iter().enumerate() {
            let len = 333.min(10_000 - idx * 333) as u64;
            assert_eq!(s, len * (1 + idx as u64), "chunk {idx}");
        }
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut data = vec![0u64; 10_000];
        par_chunks_mut(&mut data, 333, |idx, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 333 + j) as u64;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }
}
