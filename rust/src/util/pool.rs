//! Minimal data-parallel helpers built on `std::thread::scope`.
//!
//! rayon is not available offline, so the coordinator and the simulated-data
//! sweeps use these: `par_map` (index-preserving parallel map over items)
//! and `par_chunks_mut` (parallel mutation of disjoint slice chunks).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (respects `OWF_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("OWF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Parallel map with order-preserving results and work stealing via an
/// atomic cursor. `f` must be `Sync` (called concurrently), items are read
/// by shared reference.
pub fn par_map<T: Sync, R: Send>(
    items: &[T],
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = num_threads().min(n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    // SAFETY-free design: workers collect (index, result) locally, merged
    // under the mutex at the end of each worker's life.
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                let mut guard = slots.lock().unwrap();
                for (i, r) in local {
                    guard[i] = Some(r);
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker died")).collect()
}

/// Parallel in-place transform over disjoint chunks of a mutable slice.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    chunk: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let chunks: Vec<(usize, &mut [T])> =
        data.chunks_mut(chunk.max(1)).enumerate().collect();
    let cursor = AtomicUsize::new(0);
    let n = chunks.len();
    let chunks = Mutex::new(
        chunks
            .into_iter()
            .map(Some)
            .collect::<Vec<Option<(usize, &mut [T])>>>(),
    );
    let workers = num_threads().min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let taken = chunks.lock().unwrap()[i].take();
                if let Some((idx, slice)) = taken {
                    f(idx, slice);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut data = vec![0u64; 10_000];
        par_chunks_mut(&mut data, 333, |idx, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 333 + j) as u64;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as u64);
        }
    }
}
