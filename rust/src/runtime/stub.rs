//! API-compatible stand-in for the `xla` (xla_extension) bindings.
//!
//! The offline build has no PJRT native library, so the runtime compiles
//! against this stub: every entry point type-checks identically to the real
//! bindings but [`PjRtClient::cpu`] fails, which makes `Runtime::open`
//! return an error and every LLM evaluation / test skip gracefully (the
//! same behaviour as missing artifacts).  Swapping in the real crate is a
//! one-line change in `runtime/mod.rs` (`use stub as xla` → `use ::xla`).

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `?` conversion.
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT unavailable: built without the xla_extension bindings \
         (stubbed runtime)"
            .to_string(),
    )
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(
        _path: impl AsRef<Path>,
    ) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
