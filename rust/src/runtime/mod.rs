//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place Python-built compute enters the Rust process —
//! and it happens strictly through `artifacts/` files; Python itself never
//! runs here.  Interchange is HLO *text* (xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos; the text parser reassigns instruction ids).
//!
//! [`Runtime`] owns the client, the artifact manifest and a compile cache;
//! [`ModelRunner`] wraps a `model_fwd_*` artifact with parameter marshalling
//! and batch chunking for evaluation-sized workloads.

pub mod model;
pub mod stub;

// The offline build has no PJRT native library; the stub type-checks
// identically and makes `Runtime::open` fail gracefully.  To use the real
// bindings, replace this alias with `use ::xla;` and add the `xla` crate.
use self::stub as xla;

pub use model::ModelRunner;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape/dtype/name of one artifact input or output (flattened order).
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req_str("name").map_err(anyhow::Error::from)?.to_string(),
            dtype: j
                .req_str("dtype")
                .map_err(anyhow::Error::from)?
                .to_string(),
            shape: j
                .req("shape")
                .map_err(anyhow::Error::from)?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().context("shape elem"))
                .collect::<Result<_>>()?,
        })
    }
}

/// Manifest entry for one AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// A typed input value for execution.
pub enum Value<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    U32(&'a [u32]),
}

impl<'a> Value<'a> {
    fn to_literal(&self, spec: &IoSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(v) => {
                if spec.dtype != "float32" {
                    bail!("{}: expected {}, got f32", spec.name, spec.dtype);
                }
                if v.len() != spec.numel() {
                    bail!(
                        "{}: expected {} elements, got {}",
                        spec.name,
                        spec.numel(),
                        v.len()
                    );
                }
                xla::Literal::vec1(v)
            }
            Value::I32(v) => {
                if spec.dtype != "int32" {
                    bail!("{}: expected {}, got i32", spec.name, spec.dtype);
                }
                xla::Literal::vec1(v)
            }
            Value::U32(v) => {
                if spec.dtype != "uint32" {
                    bail!("{}: expected {}, got u32", spec.name, spec.dtype);
                }
                xla::Literal::vec1(v)
            }
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// The PJRT runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactInfo>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(
            || format!("read {manifest_path:?} — run `make artifacts` first"),
        )?;
        let json = Json::parse(&text).context("manifest.json parse")?;
        let mut artifacts = HashMap::new();
        for a in json
            .req("artifacts")
            .map_err(anyhow::Error::from)?
            .as_arr()
            .context("artifacts not an array")?
        {
            let info = ArtifactInfo {
                name: a.req_str("name").map_err(anyhow::Error::from)?.into(),
                file: a.req_str("file").map_err(anyhow::Error::from)?.into(),
                inputs: a
                    .req("inputs")
                    .map_err(anyhow::Error::from)?
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req("outputs")
                    .map_err(anyhow::Error::from)?
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(info.name.clone(), info);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            artifacts,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts location relative to the repo root, overridable via
    /// `OWF_ARTIFACTS`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("OWF_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        // try a few anchors so tests (cwd = rust/) and the binary (repo
        // root) both work
        for candidate in [
            PathBuf::from(&dir),
            PathBuf::from("..").join(&dir),
            PathBuf::from("../..").join(&dir),
        ] {
            if candidate.join("manifest.json").exists() {
                return Runtime::open(candidate);
            }
        }
        bail!("artifacts directory not found (run `make artifacts`)")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> =
            self.artifacts.keys().map(|s| s.as_str()).collect();
        names.sort();
        names
    }

    /// Path of the `.owt` data files that accompany the artifacts.
    pub fn data_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Compile (cached) an artifact.
    fn load(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let info = self.artifact(name)?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("load {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with positional inputs (flattened manifest
    /// order). Returns one `Vec<f32>` per output (int outputs error).
    pub fn execute_f32(
        &self,
        name: &str,
        inputs: &[Value],
    ) -> Result<Vec<Vec<f32>>> {
        let info = self.artifact(name)?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&info.inputs)
            .map(|(v, spec)| v.to_literal(spec))
            .collect::<Result<_>>()?;
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        // aot.py lowers with return_tuple=True: one tuple output
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != info.outputs.len() {
            bail!(
                "{name}: manifest says {} outputs, got {}",
                info.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect()
    }

    /// Execute with a named-input provider: looks each manifest input up in
    /// `f32_map` (for float inputs) or the positional `extra` list matched
    /// by suffix order for non-float inputs.
    pub fn execute_named(
        &self,
        name: &str,
        mut provider: impl FnMut(&IoSpec) -> Result<OwnedValue>,
    ) -> Result<Vec<Vec<f32>>> {
        let info = self.artifact(name)?.clone();
        let owned: Vec<OwnedValue> = info
            .inputs
            .iter()
            .map(&mut provider)
            .collect::<Result<_>>()?;
        let values: Vec<Value> = owned.iter().map(OwnedValue::borrow).collect();
        self.execute_f32(name, &values)
    }
}

/// Owned input buffer (for provider-style execution).
pub enum OwnedValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl OwnedValue {
    pub fn borrow(&self) -> Value<'_> {
        match self {
            OwnedValue::F32(v) => Value::F32(v),
            OwnedValue::I32(v) => Value::I32(v),
            OwnedValue::U32(v) => Value::U32(v),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        Runtime::open_default().ok()
    }

    #[test]
    fn manifest_loads_and_lists_artifacts() {
        let Some(rt) = runtime() else { return };
        let names = rt.artifact_names();
        for expected in [
            "qdq_block_absmax",
            "model_fwd_s",
            "model_fwd_m",
            "fisher_s",
            "qat_step_m_block128_absmax",
        ] {
            assert!(
                names.contains(&expected),
                "missing artifact {expected}; have {names:?}"
            );
        }
        let info = rt.artifact("model_fwd_s").unwrap();
        // params… + tokens
        assert!(info.inputs.len() > 20);
        assert_eq!(info.outputs.len(), 1);
    }

    #[test]
    fn qdq_artifact_executes() {
        let Some(rt) = runtime() else { return };
        let info = rt.artifact("qdq_block_absmax").unwrap().clone();
        let n: usize = info.inputs[0].numel();
        let k = info.inputs[1].numel();
        let x: Vec<f32> = (0..n).map(|i| ((i % 37) as f32 - 18.0) * 0.1).collect();
        let cb: Vec<f32> = (0..k)
            .map(|i| -1.0 + 2.0 * i as f32 / (k - 1) as f32)
            .collect();
        let out = rt
            .execute_f32(
                "qdq_block_absmax",
                &[Value::F32(&x), Value::F32(&cb)],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), n);
        // dequantised values are finite and within the block absmax
        assert!(out[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(rt) = runtime() else { return };
        let x = vec![0f32; 4];
        assert!(rt
            .execute_f32("qdq_block_absmax", &[Value::F32(&x)])
            .is_err());
    }
}
