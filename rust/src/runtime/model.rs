//! Model-level wrapper over the `model_fwd_*` artifacts: checkpoint
//! loading, parameter marshalling (manifest order), eval-batch chunking and
//! teacher-forced logits.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::runtime::{OwnedValue, Runtime};
use crate::tensorstore::Store;

/// microllama configuration, read from the checkpoint metadata.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub n_params: usize,
}

impl ModelConfig {
    pub fn from_store(store: &Store) -> Result<ModelConfig> {
        let c = store.meta.get("config").context("no config in meta")?;
        let u = |k: &str| -> Result<usize> {
            Ok(c.req_usize(k).map_err(anyhow::Error::from)?)
        };
        Ok(ModelConfig {
            name: c
                .req_str("name")
                .map_err(anyhow::Error::from)?
                .to_string(),
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            d_ff: u("d_ff")?,
            seq_len: u("seq_len")?,
            n_params: u("n_params")?,
        })
    }
}

/// Loaded checkpoint: the parameter store plus its parsed config.
pub struct Checkpoint {
    pub store: Store,
    pub config: ModelConfig,
}

impl Checkpoint {
    pub fn load(rt: &Runtime, size: &str) -> Result<Checkpoint> {
        let store = Store::load(rt.data_path(&format!("model_{size}.owt")))?;
        let config = ModelConfig::from_store(&store)?;
        Ok(Checkpoint { store, config })
    }

    /// Parameters as a name → f32 map (a working copy to quantise).
    pub fn params(&self) -> HashMap<String, Vec<f32>> {
        self.store
            .tensors
            .iter()
            .map(|t| (t.name.clone(), t.as_f32()))
            .collect()
    }
}

/// Token split loaded from `tokens_<size>_<split>.owt`.
pub struct TokenSplit {
    pub n_seq: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
}

impl TokenSplit {
    pub fn load(rt: &Runtime, size: &str, split: &str) -> Result<TokenSplit> {
        let store =
            Store::load(rt.data_path(&format!("tokens_{size}_{split}.owt")))?;
        let t = store.require("tokens")?;
        if t.shape.len() != 2 {
            bail!("tokens must be 2-D");
        }
        Ok(TokenSplit {
            n_seq: t.shape[0],
            seq_len: t.shape[1],
            tokens: t.as_i32(),
        })
    }

    pub fn seq(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    /// First `n` sequences as a flat buffer.
    pub fn take(&self, n: usize) -> &[i32] {
        &self.tokens[..n.min(self.n_seq) * self.seq_len]
    }
}

/// Wraps one `model_fwd_<size>` artifact.
pub struct ModelRunner<'rt> {
    rt: &'rt Runtime,
    pub size: String,
    pub config: ModelConfig,
    artifact: String,
    /// sequences per PJRT call (fixed at AOT time)
    pub batch: usize,
}

impl<'rt> ModelRunner<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        size: &str,
        config: ModelConfig,
    ) -> Result<ModelRunner<'rt>> {
        let artifact = format!("model_fwd_{size}");
        let info = rt.artifact(&artifact)?;
        let tokens_spec = info
            .inputs
            .iter()
            .find(|s| s.dtype == "int32")
            .context("fwd artifact has no token input")?;
        let batch = tokens_spec.shape[0];
        if tokens_spec.shape[1] != config.seq_len {
            bail!("artifact seq_len mismatch");
        }
        Ok(ModelRunner {
            rt,
            size: size.to_string(),
            config,
            artifact,
            batch,
        })
    }

    /// Teacher-forced logits for `n_seq` sequences (flat `tokens`,
    /// n_seq × seq_len). Chunks into the artifact's fixed batch, padding the
    /// final chunk by repeating its last sequence; returns
    /// n_seq × seq_len × vocab floats.
    pub fn logits(
        &self,
        params: &HashMap<String, Vec<f32>>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let seq = self.config.seq_len;
        assert_eq!(tokens.len() % seq, 0, "ragged token buffer");
        let n_seq = tokens.len() / seq;
        let vocab = self.config.vocab;
        let mut out = Vec::with_capacity(n_seq * seq * vocab);
        let mut chunk_tokens = vec![0i32; self.batch * seq];
        let mut start = 0usize;
        while start < n_seq {
            let take = (n_seq - start).min(self.batch);
            for row in 0..self.batch {
                let src = (start + row.min(take - 1)) * seq;
                chunk_tokens[row * seq..(row + 1) * seq]
                    .copy_from_slice(&tokens[src..src + seq]);
            }
            let outputs = self.rt.execute_named(&self.artifact, |spec| {
                if spec.dtype == "int32" {
                    return Ok(OwnedValue::I32(chunk_tokens.clone()));
                }
                let pname = spec
                    .name
                    .strip_prefix("arg0.")
                    .with_context(|| format!("unexpected input {}", spec.name))?;
                let values = params
                    .get(pname)
                    .with_context(|| format!("missing param {pname}"))?;
                if values.len() != spec.numel() {
                    bail!(
                        "param {pname}: {} elements, artifact wants {}",
                        values.len(),
                        spec.numel()
                    );
                }
                Ok(OwnedValue::F32(values.clone()))
            })?;
            let logits = &outputs[0];
            out.extend_from_slice(&logits[..take * seq * vocab]);
            start += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<(Runtime, Checkpoint)> {
        let rt = Runtime::open_default().ok()?;
        let ck = Checkpoint::load(&rt, "s").ok()?;
        Some((rt, ck))
    }

    #[test]
    fn checkpoint_and_tokens_load() {
        let Some((rt, ck)) = setup() else { return };
        assert_eq!(ck.config.name, "s");
        assert_eq!(ck.store.total_f32_elements(), ck.config.n_params);
        let toks = TokenSplit::load(&rt, "s", "eval").unwrap();
        assert_eq!(toks.seq_len, ck.config.seq_len);
        assert!(toks.n_seq >= 32);
        assert!(toks
            .tokens
            .iter()
            .all(|&t| t >= 0 && (t as usize) < ck.config.vocab));
    }

    #[test]
    fn forward_logits_shape_and_sanity() {
        let Some((rt, ck)) = setup() else { return };
        let runner = ModelRunner::new(&rt, "s", ck.config.clone()).unwrap();
        let toks = TokenSplit::load(&rt, "s", "eval").unwrap();
        let n = runner.batch + 3; // force a padded second chunk
        let logits = runner.logits(&ck.params(), toks.take(n)).unwrap();
        assert_eq!(
            logits.len(),
            n * ck.config.seq_len * ck.config.vocab
        );
        assert!(logits.iter().all(|x| x.is_finite()));
        // the trained model should beat uniform cross-entropy on its corpus
        let seq = ck.config.seq_len;
        let vocab = ck.config.vocab;
        // CE of next-token predictions for the first sequence
        let mut ce = 0.0f64;
        let mut count = 0usize;
        for t in 0..seq - 1 {
            let row = &logits[t * vocab..(t + 1) * vocab];
            let target = toks.tokens[t + 1] as usize;
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let z: f64 = row
                .iter()
                .map(|&x| ((x - max) as f64).exp())
                .sum();
            ce += -(((row[target] - max) as f64) - z.ln());
            count += 1;
        }
        ce /= count as f64;
        let uniform = (vocab as f64).ln();
        assert!(
            ce < uniform * 0.8,
            "model CE {ce:.3} not beating uniform {uniform:.3}"
        );
    }

    #[test]
    fn deterministic_execution() {
        let Some((rt, ck)) = setup() else { return };
        let runner = ModelRunner::new(&rt, "s", ck.config.clone()).unwrap();
        let toks = TokenSplit::load(&rt, "s", "eval").unwrap();
        let params = ck.params();
        let a = runner.logits(&params, toks.take(2)).unwrap();
        let b = runner.logits(&params, toks.take(2)).unwrap();
        assert_eq!(a, b);
    }
}
