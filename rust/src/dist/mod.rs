//! The data distributions of §3: Normal, Laplace, Student-t and Uniform,
//! with the machinery the format constructions need — cdf/ppf, sampling,
//! the `p^α` power transform (table 4), the expected-block-absmax
//! approximations (table 4 / fig. 14) and truncation (the absmax mixture
//! model of fig. 15).
//!
//! Everything is closed-form or classic numerics (erfc, regularised
//! incomplete beta via Lentz's continued fraction, Acklam's inverse normal
//! cdf) — the offline registry has no `statrs`/`special` crates.

pub mod fit;

use crate::util::rng::Rng;

/// Euler–Mascheroni constant (the Laplace E[absmax] ≈ s·(γ + ln B) rule).
pub const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;

/// Distribution family tag (the scheme grammar's `cbrt-*` selector).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Family {
    Normal,
    Laplace,
    StudentT,
    Uniform,
}

/// A symmetric, zero-mean distribution with a scale parameter.
///
/// * `Normal { s }` — N(0, s²).
/// * `Laplace { s }` — density (1/2s)·exp(−|x|/s).
/// * `StudentT { nu, s }` — Student-t with `nu` dof, scaled by `s`.
/// * `Uniform { a }` — uniform on \[−a, a\].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dist {
    Normal { s: f64 },
    Laplace { s: f64 },
    StudentT { nu: f64, s: f64 },
    Uniform { a: f64 },
}

impl Dist {
    pub fn normal(s: f64) -> Dist {
        Dist::Normal { s }
    }

    pub fn laplace(s: f64) -> Dist {
        Dist::Laplace { s }
    }

    pub fn student_t(nu: f64, s: f64) -> Dist {
        assert!(nu > 0.0, "student-t needs nu > 0, got {nu}");
        Dist::StudentT { nu, s }
    }

    pub fn uniform(a: f64) -> Dist {
        Dist::Uniform { a }
    }

    /// The unit-RMS member of a family (`nu` ignored except for Student-t,
    /// which needs `nu > 2` for the RMS to exist).
    pub fn standard(family: Family, nu: f64) -> Dist {
        match family {
            Family::Normal => Dist::Normal { s: 1.0 },
            Family::Laplace => Dist::Laplace {
                s: std::f64::consts::FRAC_1_SQRT_2,
            },
            Family::StudentT => {
                assert!(nu > 2.0, "unit-RMS student-t needs nu > 2, got {nu}");
                Dist::StudentT {
                    nu,
                    s: ((nu - 2.0) / nu).sqrt(),
                }
            }
            Family::Uniform => Dist::Uniform { a: 3f64.sqrt() },
        }
    }

    pub fn family(&self) -> Family {
        match self {
            Dist::Normal { .. } => Family::Normal,
            Dist::Laplace { .. } => Family::Laplace,
            Dist::StudentT { .. } => Family::StudentT,
            Dist::Uniform { .. } => Family::Uniform,
        }
    }

    /// The scale parameter (whatever it means for the family).
    pub fn scale(&self) -> f64 {
        match *self {
            Dist::Normal { s } | Dist::Laplace { s } => s,
            Dist::StudentT { s, .. } => s,
            Dist::Uniform { a } => a,
        }
    }

    /// Same family, scale multiplied by `c`.
    pub fn scaled_by(&self, c: f64) -> Dist {
        match *self {
            Dist::Normal { s } => Dist::Normal { s: s * c },
            Dist::Laplace { s } => Dist::Laplace { s: s * c },
            Dist::StudentT { nu, s } => Dist::StudentT { nu, s: s * c },
            Dist::Uniform { a } => Dist::Uniform { a: a * c },
        }
    }

    // ---- sampling ----------------------------------------------------------

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Normal { s } => s * rng.normal(),
            Dist::Laplace { s } => s * rng.laplace(),
            Dist::StudentT { nu, s } => s * rng.student_t(nu),
            Dist::Uniform { a } => rng.range(-a, a),
        }
    }

    pub fn sample_vec(&self, rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.sample(rng) as f32).collect()
    }

    // ---- cdf / ppf ---------------------------------------------------------

    pub fn cdf(&self, x: f64) -> f64 {
        match *self {
            Dist::Normal { s } => normal_cdf(x / s),
            Dist::Laplace { s } => {
                let t = x / s;
                if t < 0.0 {
                    0.5 * t.exp()
                } else {
                    1.0 - 0.5 * (-t).exp()
                }
            }
            Dist::StudentT { nu, s } => student_t_cdf(x / s, nu),
            Dist::Uniform { a } => ((x + a) / (2.0 * a)).clamp(0.0, 1.0),
        }
    }

    /// Inverse cdf (quantile function).
    pub fn ppf(&self, p: f64) -> f64 {
        let p = p.clamp(1e-300, 1.0 - 1e-12);
        match *self {
            Dist::Normal { s } => s * normal_ppf(p),
            Dist::Laplace { s } => {
                if p < 0.5 {
                    s * (2.0 * p).ln()
                } else {
                    -s * (2.0 * (1.0 - p)).ln()
                }
            }
            Dist::StudentT { nu, s } => s * student_t_ppf(p, nu),
            Dist::Uniform { a } => -a + 2.0 * a * p,
        }
    }

    // ---- the p^α transform (table 4) --------------------------------------

    /// The distribution whose density is ∝ p(x)^α — closed under each
    /// family: Normal(s) → Normal(s/√α), Laplace(s) → Laplace(s/α),
    /// t(ν, s) → t(α(ν+1)−1, s·√(ν/ν′)), Uniform unchanged.
    pub fn power_transform(&self, alpha: f64) -> Dist {
        assert!(alpha > 0.0);
        match *self {
            Dist::Normal { s } => Dist::Normal {
                s: s / alpha.sqrt(),
            },
            Dist::Laplace { s } => Dist::Laplace { s: s / alpha },
            Dist::StudentT { nu, s } => {
                let nu_p = alpha * (nu + 1.0) - 1.0;
                assert!(
                    nu_p > 0.0,
                    "power transform needs alpha(nu+1) > 1 (nu={nu}, alpha={alpha})"
                );
                Dist::StudentT {
                    nu: nu_p,
                    s: s * (nu / nu_p).sqrt(),
                }
            }
            Dist::Uniform { a } => Dist::Uniform { a },
        }
    }

    /// `power_transform(1/3)` — the optimal-density exponent.
    pub fn cbrt(&self) -> Dist {
        self.power_transform(1.0 / 3.0)
    }

    // ---- block absmax model (table 4 / fig. 14) ----------------------------

    /// Approximate E\[max_{i<B} |x_i|\] for B iid draws (table 4; accurate
    /// for B ≳ 16, clamped below so tiny blocks stay finite/positive).
    pub fn expected_absmax(&self, block: usize) -> f64 {
        let b = block.max(2) as f64;
        match *self {
            // E ≈ s·√(2 ln(B/π))
            Dist::Normal { s } => s * log_term(b).sqrt(),
            // |x| is Exponential(s): E[max] = s·H_B ≈ s·(γ + ln B)
            Dist::Laplace { s } => s * (EULER_GAMMA + b.ln()),
            // E ≈ s·√(ν/(ν−2))·(2 ln(B/π))^((ν−3)/(2ν))·B^(1/ν), the
            // Fréchet-limit form interpolated so ν→∞ recovers the Normal
            Dist::StudentT { nu, s } => {
                let rms_ratio = if nu > 2.0 {
                    (nu / (nu - 2.0)).sqrt()
                } else {
                    1.0
                };
                s * rms_ratio
                    * log_term(b).powf((nu - 3.0) / (2.0 * nu))
                    * b.powf(1.0 / nu)
            }
            Dist::Uniform { a } => a * b / (b + 1.0),
        }
    }

    /// Rescale so that `E[absmax over block] = target`.
    pub fn with_absmax(&self, block: usize, target: f64) -> Dist {
        let e = self.expected_absmax(block);
        assert!(e > 0.0, "degenerate absmax model");
        self.scaled_by(target / e)
    }
}

/// `2·ln(B/π)`, clamped positive so B < π·e^(1/4) stays usable.
fn log_term(b: f64) -> f64 {
    (2.0 * (b / std::f64::consts::PI).ln()).max(0.5)
}

// ---------------------------------------------------------------------------
// Truncation (the fig. 15 mixture model, and the absmax codebook domain)
// ---------------------------------------------------------------------------

/// `base` conditioned on \[lo, hi\].
#[derive(Clone, Copy, Debug)]
pub struct Truncated {
    pub base: Dist,
    pub lo: f64,
    pub hi: f64,
    c_lo: f64,
    c_hi: f64,
}

impl Truncated {
    pub fn new(base: Dist, lo: f64, hi: f64) -> Truncated {
        assert!(lo < hi, "bad truncation [{lo}, {hi}]");
        let c_lo = base.cdf(lo);
        let c_hi = base.cdf(hi);
        assert!(c_hi > c_lo, "truncation interval has zero mass");
        Truncated {
            base,
            lo,
            hi,
            c_lo,
            c_hi,
        }
    }

    pub fn cdf(&self, x: f64) -> f64 {
        if x <= self.lo {
            0.0
        } else if x >= self.hi {
            1.0
        } else {
            (self.base.cdf(x) - self.c_lo) / (self.c_hi - self.c_lo)
        }
    }

    /// Inverse cdf; p = 0 / 1 hit the truncation endpoints exactly.
    pub fn ppf(&self, p: f64) -> f64 {
        if p <= 0.0 {
            return self.lo;
        }
        if p >= 1.0 {
            return self.hi;
        }
        let q = self.c_lo + p * (self.c_hi - self.c_lo);
        self.base.ppf(q).clamp(self.lo, self.hi)
    }
}

// ---------------------------------------------------------------------------
// scalar numerics
// ---------------------------------------------------------------------------

/// erfc via the Numerical-Recipes Chebyshev fit (|rel err| < 1.2e-7).
fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.265_512_23
            + t * (1.000_023_68
                + t * (0.374_091_96
                    + t * (0.096_784_18
                        + t * (-0.186_288_06
                            + t * (0.278_868_07
                                + t * (-1.135_203_98
                                    + t * (1.488_515_87
                                        + t * (-0.822_152_23
                                            + t * 0.170_872_77)))))))))
            .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal cdf.
fn normal_cdf(z: f64) -> f64 {
    0.5 * erfc(-z * std::f64::consts::FRAC_1_SQRT_2)
}

/// Standard normal pdf.
fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Acklam's inverse normal cdf (|rel err| < 1.15e-9) plus one Newton step
/// against our own cdf so ppf∘cdf round-trips tightly.
fn normal_ppf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_690e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.024_25;
    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Newton refinement against this module's cdf
    let pdf = normal_pdf(x);
    if pdf > 1e-280 {
        x - (normal_cdf(x) - p) / pdf
    } else {
        x
    }
}

/// ln Γ(x) (Lanczos, x > 0).
fn ln_gamma(x: f64) -> f64 {
    const COF: [f64; 6] = [
        76.180_091_729_471_46,
        -86.505_320_329_416_77,
        24.014_098_240_830_91,
        -1.231_739_572_450_155,
        0.120_865_097_386_617_5e-2,
        -0.539_523_938_495_3e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000_000_000_190_015;
    for c in COF {
        y += 1.0;
        ser += c / y;
    }
    -tmp + (2.506_628_274_631_000_5 * ser / x).ln()
}

/// Continued fraction for the incomplete beta (Lentz's method, NR §6.4).
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const TINY: f64 = 1e-30;
    const EPS: f64 = 3e-14;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularised incomplete beta I_x(a, b).
fn inc_beta(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_bt = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln();
    let bt = ln_bt.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

/// Unit-scale Student-t cdf.
fn student_t_cdf(t: f64, nu: f64) -> f64 {
    if t == 0.0 {
        return 0.5;
    }
    let x = nu / (nu + t * t);
    let tail = 0.5 * inc_beta(0.5 * nu, 0.5, x);
    if t > 0.0 {
        1.0 - tail
    } else {
        tail
    }
}

/// Unit-scale Student-t ppf by bracketed bisection on the cdf (used only at
/// codebook-construction time, so robustness beats speed).
fn student_t_ppf(p: f64, nu: f64) -> f64 {
    if p == 0.5 {
        return 0.0;
    }
    let upper = p > 0.5;
    let pu = if upper { p } else { 1.0 - p };
    // bracket [0, hi]
    let mut hi = 1.0f64;
    let mut guard = 0;
    while student_t_cdf(hi, nu) < pu && guard < 2000 {
        hi *= 2.0;
        guard += 1;
    }
    let mut lo = 0.0f64;
    for _ in 0..120 {
        let mid = 0.5 * (lo + hi);
        if student_t_cdf(mid, nu) < pu {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let x = 0.5 * (lo + hi);
    if upper {
        x
    } else {
        -x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_cdf_ppf_roundtrip() {
        let d = Dist::normal(1.0);
        for p in [1e-6, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0 - 1e-6] {
            let x = d.ppf(p);
            assert!(
                (d.cdf(x) - p).abs() < 1e-9,
                "p={p}: x={x}, cdf={}",
                d.cdf(x)
            );
        }
        // known quantiles
        assert!((d.ppf(0.975) - 1.959_964).abs() < 1e-4);
        assert!((d.cdf(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn laplace_cdf_ppf_roundtrip() {
        let d = Dist::laplace(0.7);
        for p in [0.001, 0.2, 0.5, 0.8, 0.999] {
            let x = d.ppf(p);
            assert!((d.cdf(x) - p).abs() < 1e-12, "p={p}");
        }
    }

    #[test]
    fn student_t_cdf_ppf_roundtrip() {
        for nu in [1.5, 5.0 / 3.0, 3.0, 5.0, 7.0, 30.0] {
            let d = Dist::student_t(nu, 1.0);
            for p in [0.01, 0.1, 0.5, 0.9, 0.99] {
                let x = d.ppf(p);
                assert!(
                    (d.cdf(x) - p).abs() < 1e-9,
                    "nu={nu} p={p}: x={x}"
                );
            }
        }
        // t(1) = Cauchy: ppf(0.75) = 1
        let c = Dist::student_t(1.0, 1.0);
        assert!((c.ppf(0.75) - 1.0).abs() < 1e-7);
        // large nu approaches the normal
        let t = Dist::student_t(1e6, 1.0);
        let n = Dist::normal(1.0);
        assert!((t.ppf(0.9) - n.ppf(0.9)).abs() < 1e-3);
    }

    #[test]
    fn standard_is_unit_rms() {
        let mut rng = Rng::new(7);
        for fam in [Family::Normal, Family::Laplace, Family::StudentT] {
            let d = Dist::standard(fam, 8.0);
            let xs = d.sample_vec(&mut rng, 200_000);
            let rms = crate::util::stats::rms(&xs);
            assert!(
                (rms - 1.0).abs() < 0.03,
                "{fam:?}: rms {rms}"
            );
        }
    }

    #[test]
    fn power_transform_table4() {
        // Normal: sqrt(3) blow-up at alpha = 1/3
        match Dist::normal(1.0).cbrt() {
            Dist::Normal { s } => assert!((s - 3f64.sqrt()).abs() < 1e-12),
            _ => panic!("family changed"),
        }
        // Laplace: 3x
        match Dist::laplace(2.0).cbrt() {
            Dist::Laplace { s } => assert!((s - 6.0).abs() < 1e-12),
            _ => panic!("family changed"),
        }
        // Student-t: nu' = (nu-2)/3 at alpha = 1/3
        match Dist::student_t(7.0, 1.0).cbrt() {
            Dist::StudentT { nu, s } => {
                assert!((nu - 5.0 / 3.0).abs() < 1e-12);
                assert!((s - (7.0 / (5.0 / 3.0)).sqrt()).abs() < 1e-12);
            }
            _ => panic!("family changed"),
        }
    }

    #[test]
    fn expected_absmax_tracks_monte_carlo() {
        let mut rng = Rng::new(3);
        for d in [
            Dist::normal(1.0),
            Dist::laplace(1.0),
            Dist::student_t(5.0, 1.0),
        ] {
            for block in [64usize, 256] {
                let trials = 4000;
                let mut acc = 0.0;
                for _ in 0..trials {
                    let mut m = 0f64;
                    for _ in 0..block {
                        m = m.max(d.sample(&mut rng).abs());
                    }
                    acc += m;
                }
                let mc = acc / trials as f64;
                let approx = d.expected_absmax(block);
                // table-4 approximations are ~5% for light tails and
                // within ~20% for Student-t (fig. 14 shows the same gap)
                assert!(
                    ((approx - mc) / mc).abs() < 0.25,
                    "{d:?} B={block}: approx {approx} vs mc {mc}"
                );
            }
        }
    }

    #[test]
    fn with_absmax_normalises() {
        let d = Dist::student_t(7.0, 2.0).with_absmax(128, 1.0);
        assert!((d.expected_absmax(128) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn truncated_endpoints_and_monotone() {
        let t = Truncated::new(Dist::normal(0.5), -1.0, 1.0);
        assert_eq!(t.ppf(0.0), -1.0);
        assert_eq!(t.ppf(1.0), 1.0);
        assert_eq!(t.cdf(-2.0), 0.0);
        assert_eq!(t.cdf(2.0), 1.0);
        let mut prev = -1.0;
        for i in 0..=20 {
            let x = t.ppf(i as f64 / 20.0);
            assert!(x >= prev, "ppf not monotone at {i}");
            prev = x;
        }
        // round trip through the conditional cdf
        for p in [0.1, 0.4, 0.9] {
            assert!((t.cdf(t.ppf(p)) - p).abs() < 1e-8);
        }
    }

    #[test]
    fn uniform_basics() {
        let d = Dist::standard(Family::Uniform, 0.0);
        assert!((d.cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((d.ppf(1.0) - 3f64.sqrt()).abs() < 1e-9);
    }
}
