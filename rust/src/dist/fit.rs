//! 1-D fitting helpers: golden-section minimisation, the coarse-grid +
//! golden refinement used by the quantiser-scale search (§2.2 / figs. 23,
//! 35), and the (Fisher-)weighted squared-error objective.

/// Golden-section search for the minimiser of `f` on \[lo, hi\].
/// Returns `(argmin, min)` after `iters` interval reductions.
pub fn golden_section(
    lo: f64,
    hi: f64,
    iters: usize,
    f: impl Fn(f64) -> f64,
) -> (f64, f64) {
    const INVPHI: f64 = 0.618_033_988_749_894_8;
    let (mut a, mut b) = (lo.min(hi), lo.max(hi));
    let mut c = b - INVPHI * (b - a);
    let mut d = a + INVPHI * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    for _ in 0..iters {
        if fc <= fd {
            b = d;
            d = c;
            fd = fc;
            c = b - INVPHI * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INVPHI * (b - a);
            fd = f(d);
        }
    }
    if fc <= fd {
        (c, fc)
    } else {
        (d, fd)
    }
}

/// Coarse-to-fine 1-D minimisation: evaluate `f` on `grid` (must be sorted
/// ascending), then golden-section between the best point's neighbours.
pub fn grid_then_golden(
    grid: &[f64],
    f: impl Fn(f64) -> f64,
) -> (f64, f64) {
    assert!(!grid.is_empty(), "empty search grid");
    let mut best_i = 0usize;
    let mut best_f = f64::INFINITY;
    for (i, &x) in grid.iter().enumerate() {
        let fx = f(x);
        if fx < best_f {
            best_f = fx;
            best_i = i;
        }
    }
    let lo = grid[best_i.saturating_sub(1)];
    let hi = grid[(best_i + 1).min(grid.len() - 1)];
    if hi <= lo {
        return (grid[best_i], best_f);
    }
    let (x, fx) = golden_section(lo, hi, 25, &f);
    if fx < best_f {
        (x, fx)
    } else {
        (grid[best_i], best_f)
    }
}

/// The multiplier grid of the quantiser-scale search: 2^(k/4) for
/// k ∈ \[−8, 12\] (0.25 … 8, including exactly 1).
pub fn scale_search_grid() -> Vec<f64> {
    (-8i32..=12).map(|k| 2f64.powf(k as f64 / 4.0)).collect()
}

/// Σ wᵢ(aᵢ−bᵢ)², or the plain squared error when `weights` is empty /
/// mismatched (f64 accumulation).
pub fn weighted_sq_err(a: &[f32], b: &[f32], weights: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if weights.len() != a.len() {
        return crate::util::stats::sq_err(a, b);
    }
    let mut acc = 0f64;
    for i in 0..a.len() {
        let d = a[i] as f64 - b[i] as f64;
        acc += weights[i] as f64 * d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_finds_parabola_min() {
        let (x, fx) = golden_section(0.0, 5.0, 40, |x| (x - 2.0).powi(2));
        assert!((x - 2.0).abs() < 1e-6, "{x}");
        assert!(fx < 1e-10);
    }

    #[test]
    fn grid_then_golden_refines() {
        let f = |x: f64| (x.ln() - 0.37).powi(2);
        let grid = scale_search_grid();
        let (x, _) = grid_then_golden(&grid, f);
        assert!((x.ln() - 0.37).abs() < 1e-4, "{x}");
    }

    #[test]
    fn grid_handles_edge_minima() {
        // minimum at the first / last grid point must not panic
        let grid = [1.0, 2.0, 3.0];
        let (x, _) = grid_then_golden(&grid, |x| x);
        assert!(x <= 1.0 + 1e-9);
        let (x, _) = grid_then_golden(&grid, |x| -x);
        assert!(x >= 3.0 - 1e-9);
    }

    #[test]
    fn search_grid_contains_unity() {
        let g = scale_search_grid();
        assert!(g.iter().any(|&x| (x - 1.0).abs() < 1e-12));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!((g[0] - 0.25).abs() < 1e-12);
        assert!((g.last().unwrap() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_sq_err_reduces_to_plain() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.5f32, 2.0, 2.0];
        assert!((weighted_sq_err(&a, &b, &[]) - 1.25).abs() < 1e-9);
        let w = [2.0f32, 1.0, 0.0];
        assert!((weighted_sq_err(&a, &b, &w) - 0.5).abs() < 1e-9);
    }
}
