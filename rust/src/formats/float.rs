//! Generic EkMm minifloat element formats (fig. 19's exponent sweep,
//! E2M1/E3M0 of fig. 18, and the scale formats of §scaling).
//!
//! Encodings follow the "all finite" convention used by sub-byte deep
//! learning formats (MX/FP4): 1 sign bit, `e` exponent bits (bias
//! 2^(e-1)-1), `m` mantissa bits, subnormals at exponent 0, **no inf/NaN**
//! (the top exponent is an ordinary binade).  ±0 both exist, so one encoding
//! is wasted — exactly the "represent zero twice" property the paper notes
//! for symmetric float formats.

use crate::formats::Codebook;

/// All representable values of the EkMm format, one per *encoding* (so ±0
/// duplicates; `Codebook` dedups but keeps storage at 1+e+m bits).
pub fn float_values(exp_bits: u32, man_bits: u32) -> Vec<f32> {
    assert!(exp_bits >= 1 && exp_bits <= 8, "exp bits {exp_bits}");
    assert!(man_bits <= 10, "man bits {man_bits}");
    let bias = (1i32 << (exp_bits - 1)) - 1;
    let mut out = Vec::with_capacity(1 << (1 + exp_bits + man_bits));
    for sign in [1.0f32, -1.0] {
        for e in 0..(1u32 << exp_bits) {
            for m in 0..(1u32 << man_bits) {
                let frac = m as f32 / (1u32 << man_bits) as f32;
                let v = if e == 0 {
                    // subnormal: 0.frac × 2^(1-bias)
                    frac * 2f32.powi(1 - bias)
                } else {
                    (1.0 + frac) * 2f32.powi(e as i32 - bias)
                };
                out.push(sign * v);
            }
        }
    }
    out
}

/// Largest finite magnitude of EkMm.
pub fn float_max(exp_bits: u32, man_bits: u32) -> f32 {
    float_values(exp_bits, man_bits)
        .into_iter()
        .fold(0.0, f32::max)
}

/// EkMm codebook in natural (unnormalised) space.
pub fn float_codebook(exp_bits: u32, man_bits: u32) -> Codebook {
    Codebook::with_bits(
        float_values(exp_bits, man_bits),
        (1 + exp_bits + man_bits) as f64,
    )
}

/// EkMm codebook normalised so the largest magnitude is exactly 1 (the
/// absmax-scaling convention).
pub fn float_codebook_normalised(exp_bits: u32, man_bits: u32) -> Codebook {
    let max = float_max(exp_bits, man_bits);
    let points = float_values(exp_bits, man_bits)
        .into_iter()
        .map(|v| v / max)
        .collect();
    Codebook::with_bits(points, (1 + exp_bits + man_bits) as f64)
}

/// Round an f32 to the nearest EkMm value *with round-to-nearest-even on the
/// mantissa and saturation at the max magnitude* — used for scale storage
/// (fig. 20/21's scale-format sweeps) where building a full codebook would
/// be wasteful for large e+m.
pub fn round_to_float(x: f32, exp_bits: u32, man_bits: u32, away: bool) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let bias = (1i32 << (exp_bits - 1)) - 1;
    let max = {
        let frac =
            ((1u32 << man_bits) - 1) as f32 / (1u32 << man_bits) as f32;
        (1.0 + frac) * 2f32.powi(((1i32 << exp_bits) - 1) - bias)
    };
    let sign = x.signum();
    let mag = x.abs();
    if mag >= max {
        return sign * max;
    }
    // exponent of the binade containing mag, clamped to format range
    let e = (mag.log2().floor() as i32).clamp(1 - bias, (1 << exp_bits) - 1 - bias);
    let ulp = 2f32.powi(e - man_bits as i32);
    let steps = mag / ulp;
    let rounded = if away {
        steps.ceil()
    } else {
        // round-half-even
        let f = steps.fract();
        if (f - 0.5).abs() < f32::EPSILON * steps.max(1.0) {
            let down = steps.floor();
            if (down as u64) % 2 == 0 {
                down
            } else {
                down + 1.0
            }
        } else {
            steps.round()
        }
    };
    (sign * rounded * ulp).clamp(-max, max)
}

/// E8M0: the MX power-of-two scale format (round-away optional).
pub fn round_to_e8m0(x: f32, away: bool) -> f32 {
    if x <= 0.0 || !x.is_finite() {
        return x;
    }
    let l = x.log2();
    let e = if away { l.ceil() } else { l.round() };
    2f32.powi(e.clamp(-127.0, 127.0) as i32)
}

/// bfloat16 rounding of a positive scale: `away` = round away from zero
/// (the paper's default for absmax scales — never shrinks the block max),
/// else round-to-nearest-even.
pub fn round_to_bf16(x: f32, away: bool) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let bits = x.to_bits();
    let lower = bits & 0xFFFF;
    if lower == 0 {
        return x;
    }
    let upper = bits & 0xFFFF_0000;
    if away {
        // magnitude up (works for positive scales, the only use here)
        f32::from_bits(upper.wrapping_add(0x1_0000))
    } else {
        // round-to-nearest-even on the upper half
        let round_bit = 0x8000u32;
        let mut up = upper;
        if lower > round_bit || (lower == round_bit && (upper & 0x1_0000) != 0)
        {
            up = up.wrapping_add(0x1_0000);
        }
        f32::from_bits(up)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2m1_is_fp4() {
        // E2M1 (fp4): ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}
        let mut v = float_values(2, 1);
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup();
        assert_eq!(
            v,
            vec![-6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0, 0.5, 1.0,
                 1.5, 2.0, 3.0, 4.0, 6.0]
        );
        assert_eq!(float_max(2, 1), 6.0);
    }

    #[test]
    fn e4m3_like_max() {
        // all-finite E4M3 → max = 1.875 * 2^8 = 480
        assert_eq!(float_max(4, 3), 480.0);
    }

    #[test]
    fn normalised_touches_one() {
        for (e, m) in [(2, 1), (3, 0), (3, 2), (5, 2)] {
            let cb = float_codebook_normalised(e, m);
            assert_eq!(cb.absmax(), 1.0, "E{e}M{m}");
            assert!(cb.has_zero());
            assert_eq!(cb.storage_bits(), (1 + e + m) as f64);
        }
    }

    #[test]
    fn round_to_float_exact_values_fixed() {
        for &v in &[0.5f32, 1.0, 1.5, 2.0, 3.0, 6.0, -4.0] {
            assert_eq!(round_to_float(v, 2, 1, false), v, "{v}");
        }
    }

    #[test]
    fn round_to_float_nearest_and_away() {
        // between 2.0 and 3.0 in E2M1 (ulp = 1.0 in that binade)
        assert_eq!(round_to_float(2.4, 2, 1, false), 2.0);
        assert_eq!(round_to_float(2.6, 2, 1, false), 3.0);
        assert_eq!(round_to_float(2.1, 2, 1, true), 3.0); // away
        // saturation
        assert_eq!(round_to_float(100.0, 2, 1, false), 6.0);
        assert_eq!(round_to_float(-100.0, 2, 1, true), -6.0);
    }

    #[test]
    fn bf16_rounding() {
        let x = f32::from_bits(0x3F80_0001); // 1.0 + tiny
        assert_eq!(round_to_bf16(x, true), f32::from_bits(0x3F81_0000));
        assert_eq!(round_to_bf16(x, false), 1.0);
        assert_eq!(round_to_bf16(1.0, true), 1.0); // exact value unchanged
        // round-away never shrinks
        for i in 1..1000 {
            let v = i as f32 * 0.0137;
            assert!(round_to_bf16(v, true) >= v);
        }
    }

    #[test]
    fn e8m0_rounding() {
        assert_eq!(round_to_e8m0(1.0, false), 1.0);
        assert_eq!(round_to_e8m0(3.0, true), 4.0);
        // log-space nearest: log2(2.9) ≈ 1.536 rounds to 2 → 2^2
        assert_eq!(round_to_e8m0(2.9, false), 4.0);
        assert_eq!(round_to_e8m0(2.5, false), 2.0); // log2(2.5) ≈ 1.32 → 2^1
    }

    #[test]
    fn subnormals_present() {
        let v = float_values(3, 1);
        // smallest positive: 0.5 * 2^(1-3) = 0.125 for E3M1 (bias 3)
        let min_pos = v
            .iter()
            .copied()
            .filter(|&x| x > 0.0)
            .fold(f32::INFINITY, f32::min);
        assert_eq!(min_pos, 0.5 * 2f32.powi(-2));
    }
}
