//! The paper's √[3]p (cube-root-density) non-uniform quantisers (§2.1,
//! appendix B.1/E) for Normal, Laplace and Student-t data under RMS, absmax
//! and signmax scaling, with symmetric/asymmetric variants, generalised to
//! a `p^α` exponent for the fig. 22 sweep.
//!
//! Construction (appendix E):
//!
//! * **RMS scaling** — data is scaled to RMS 1, so take D with RMS = 1,
//!   derive D′ = p^α transform (table 4), and place 2^b codepoints at the
//!   interior quantiles `linspace(0, 1, 2^b + 2)[1:-1]` of D′.
//! * **Absmax scaling** — data is scaled so the block max is ±1; model the
//!   non-maxima as D truncated at the (expected) maximum.  Take D with
//!   `E[absmax over B] = 1`, transform to D′, truncate at ±1, and place
//!   2^b codepoints at `linspace(0, 1, 2^b)` (endpoints included, so ±1 are
//!   always codepoints).
//! * **Signmax scaling** — the block max is *+1* exactly; special codepoints
//!   {0, +1} plus 2^b − 2 quantiles of the truncated D′ on (−1, +1).

use crate::dist::{Dist, Family, Truncated};
use crate::formats::{Codebook, Variant};

/// The exponent of the optimal density rule under a codepoint constraint.
pub const CBRT_ALPHA: f64 = 1.0 / 3.0;

/// √[3]p codebook for RMS-scaled data (α generalised; α = 1/3 is optimal).
pub fn cbrt_rms(
    family: Family,
    nu: f64,
    bits: u32,
    variant: Variant,
    alpha: f64,
) -> Codebook {
    assert!(
        variant != Variant::Signmax,
        "signmax implies absmax-style scaling; use cbrt_signmax"
    );
    let k = 1usize << bits;
    let d = Dist::standard(family, nu); // RMS = 1
    let dp = d.power_transform(alpha);
    let points = match variant {
        // interior quantiles of D': linspace(0,1,K+2)[1:-1]
        Variant::Symmetric => quantiles(&dp, k),
        // K+1 interior quantiles (odd count ⇒ exact 0), drop the largest
        Variant::Asymmetric => {
            let mut pts = quantiles(&dp, k + 1);
            snap_zero(&mut pts);
            pts.pop();
            pts
        }
        Variant::Signmax => unreachable!(),
    };
    Codebook::with_bits(points, bits as f64)
}

/// √[3]p codebook for block-absmax-scaled data.
pub fn cbrt_absmax(
    family: Family,
    nu: f64,
    bits: u32,
    block: usize,
    variant: Variant,
    alpha: f64,
) -> Codebook {
    let k = 1usize << bits;
    let trunc = truncated_dprime(family, nu, block, alpha);
    let points = match variant {
        // endpoint-inclusive quantiles: ±1 always representable
        Variant::Symmetric => trunc_quantiles(&trunc, k, true),
        // one extra quantile (odd ⇒ exact 0 present), drop +1 (INT
        // convention: asymmetry sacrifices the positive endpoint)
        Variant::Asymmetric => {
            let mut pts = trunc_quantiles(&trunc, k + 1, true);
            snap_zero(&mut pts);
            pts.pop();
            pts
        }
        Variant::Signmax => {
            // {0, +1} special + K-2 interior quantiles of truncated D'
            let mut pts = vec![0.0f32, 1.0];
            pts.extend(trunc_quantiles(&trunc, k - 2, false));
            pts
        }
    };
    Codebook::with_bits(points, bits as f64)
}

/// The truncated D′ used by absmax/signmax constructions: D scaled so that
/// `E[absmax over block] = 1`, power-transformed, truncated at ±1.
pub fn truncated_dprime(
    family: Family,
    nu: f64,
    block: usize,
    alpha: f64,
) -> Truncated {
    let d = Dist::standard(family, nu);
    let scaled = d.with_absmax(block, 1.0);
    let dp = scaled.power_transform(alpha);
    Truncated::new(dp, -1.0, 1.0)
}

/// Interior quantile codepoints: linspace(0, 1, k+2)[1:-1] through the ppf.
fn quantiles(d: &Dist, k: usize) -> Vec<f32> {
    assert!(k >= 1);
    (1..=k)
        .map(|i| d.ppf(i as f64 / (k + 1) as f64) as f32)
        .collect()
}

fn trunc_quantiles(t: &Truncated, k: usize, endpoints: bool) -> Vec<f32> {
    assert!(k >= 1);
    if endpoints {
        if k == 1 {
            return vec![t.ppf(0.5) as f32];
        }
        (0..k)
            .map(|i| t.ppf(i as f64 / (k - 1) as f64) as f32)
            .collect()
    } else {
        (1..=k)
            .map(|i| t.ppf(i as f64 / (k + 1) as f64) as f32)
            .collect()
    }
}

/// Snap the value nearest zero to exact 0.0 (guards f64→f32 residue on the
/// middle quantile of odd-count constructions).
fn snap_zero(pts: &mut [f32]) {
    if let Some((i, _)) = pts.iter().enumerate().min_by(|(_, a), (_, b)| {
        a.abs().partial_cmp(&b.abs()).unwrap()
    }) {
        pts[i] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;

    /// Matches the paper's E.1 Normal example:
    /// `Q = norm.ppf(linspace(0,1,2^b+2)[1:-1], scale=sqrt(3))`.
    #[test]
    fn rms_normal_matches_e1_recipe() {
        let cb = cbrt_rms(Family::Normal, 0.0, 4, Variant::Symmetric, CBRT_ALPHA);
        assert_eq!(cb.len(), 16);
        let d = Dist::normal(3f64.sqrt());
        for (i, &p) in cb.points().iter().enumerate() {
            let want = d.ppf((i + 1) as f64 / 17.0) as f32;
            assert!((p - want).abs() < 1e-5, "i={i}: {p} vs {want}");
        }
        // symmetric, no zero
        assert!(!cb.has_zero());
        assert!((cb.points()[0] + cb.points()[15]).abs() < 1e-6);
    }

    /// Matches E.1 Student-t: `t.ppf(p, (df-2)/3, scale=sqrt(3))` for df=7.
    #[test]
    fn rms_student_matches_e1_recipe() {
        let df = 7.0;
        let cb = cbrt_rms(Family::StudentT, df, 4, Variant::Symmetric, CBRT_ALPHA);
        // D = t(7) with RMS 1 ⇒ s = sqrt(5/7); D' = t((7-2)/3) with
        // s' = s*sqrt(7/((7-2)/3)) = sqrt(5/7)*sqrt(21/5) = sqrt(3). ✓ E.1
        let dp = Dist::student_t((df - 2.0) / 3.0, 3f64.sqrt());
        for (i, &p) in cb.points().iter().enumerate() {
            let want = dp.ppf((i + 1) as f64 / 17.0) as f32;
            assert!(
                (p - want).abs() < 1e-4 * want.abs().max(1.0),
                "i={i}: {p} vs {want}"
            );
        }
    }

    /// Matches E.2: truncnorm quantiles with scale sqrt(3/(2 ln(B/π))).
    #[test]
    fn absmax_normal_matches_e2_recipe() {
        let block = 64;
        let cb = cbrt_absmax(
            Family::Normal, 0.0, 4, block, Variant::Symmetric, CBRT_ALPHA,
        );
        assert_eq!(cb.len(), 16);
        let scale = (3.0 / (2.0 * (block as f64 / std::f64::consts::PI).ln()))
            .sqrt();
        let trunc = Truncated::new(Dist::normal(scale), -1.0, 1.0);
        for (i, &p) in cb.points().iter().enumerate() {
            let want = trunc.ppf(i as f64 / 15.0) as f32;
            assert!((p - want).abs() < 1e-5, "i={i}: {p} vs {want}");
        }
        // endpoints exactly representable
        assert_eq!(cb.points()[0], -1.0);
        assert_eq!(cb.points()[15], 1.0);
    }

    #[test]
    fn absmax_laplace_matches_e2_scale() {
        let block = 64usize;
        let t = truncated_dprime(Family::Laplace, 0.0, block, CBRT_ALPHA);
        // E.2: scale = 3 / (γ + ln B)
        let want = 3.0 / (crate::dist::EULER_GAMMA + (block as f64).ln());
        match t.base {
            Dist::Laplace { s } => {
                assert!((s - want).abs() < 1e-12, "{s} vs {want}")
            }
            _ => panic!("family"),
        }
    }

    #[test]
    fn absmax_student_matches_e2_scale() {
        let block = 64usize;
        let df = 7.0;
        let t = truncated_dprime(Family::StudentT, df, block, CBRT_ALPHA);
        // E.2: scale = (2 ln(B/π))^((3-df)/(2 df)) * B^(-1/df) * sqrt(3)
        let b = block as f64;
        let want = (2.0 * (b / std::f64::consts::PI).ln())
            .powf((3.0 - df) / (2.0 * df))
            * b.powf(-1.0 / df)
            * 3f64.sqrt();
        match t.base {
            Dist::StudentT { nu, s } => {
                assert!((nu - (df - 2.0) / 3.0).abs() < 1e-12);
                assert!(
                    ((s - want) / want).abs() < 1e-10,
                    "{s} vs {want}"
                );
            }
            _ => panic!("family"),
        }
    }

    #[test]
    fn asymmetric_variants_have_zero() {
        for fam in [Family::Normal, Family::Laplace, Family::StudentT] {
            let rms = cbrt_rms(fam, 7.0, 3, Variant::Asymmetric, CBRT_ALPHA);
            assert!(rms.has_zero(), "{fam:?} rms");
            assert_eq!(rms.len(), 8);
            let am = cbrt_absmax(fam, 7.0, 3, 64, Variant::Asymmetric, CBRT_ALPHA);
            assert!(am.has_zero(), "{fam:?} absmax");
            assert_eq!(am.len(), 8);
            // asymmetric absmax keeps −1, drops +1
            assert_eq!(am.points()[0], -1.0);
            assert!(am.absmax() <= 1.0 && *am.points().last().unwrap() < 1.0);
        }
    }

    #[test]
    fn signmax_specials() {
        let cb = cbrt_absmax(
            Family::Normal, 0.0, 3, 64, Variant::Signmax, CBRT_ALPHA,
        );
        assert_eq!(cb.len(), 8);
        assert!(cb.has_zero());
        assert_eq!(*cb.points().last().unwrap(), 1.0);
        // no −1: sign is absorbed into the scale
        assert!(cb.points()[0] > -1.0);
    }

    #[test]
    fn quantile_rule_alpha_one_reduces_to_quantile_quantisation() {
        // α = 1 ⇒ D′ = D: codepoints are plain quantiles of D.
        let cb = cbrt_rms(Family::Normal, 0.0, 3, Variant::Symmetric, 1.0);
        let d = Dist::standard(Family::Normal, 0.0);
        for (i, &p) in cb.points().iter().enumerate() {
            let want = d.ppf((i + 1) as f64 / 9.0) as f32;
            assert!((p - want).abs() < 1e-5);
        }
    }

    #[test]
    fn codepoint_density_follows_cbrt_rule() {
        // Empirical check of the defining property: the number of codepoints
        // in an interval is ∝ ∫ p^(1/3). Use a large codebook for fidelity.
        let bits = 8;
        let cb = cbrt_rms(Family::Normal, 0.0, bits, Variant::Symmetric, CBRT_ALPHA);
        let dp = Dist::standard(Family::Normal, 0.0).cbrt();
        // count points in [-1, 1] vs expectation under D'
        let count = cb.points().iter().filter(|p| p.abs() <= 1.0).count();
        let expect = (dp.cdf(1.0) - dp.cdf(-1.0)) * cb.len() as f64;
        assert!(
            ((count as f64 - expect) / expect).abs() < 0.05,
            "count {count} vs expect {expect:.1}"
        );
    }
}
