//! Lloyd-Max optimal scalar quantiser (1-D weighted k-means), the
//! data-driven optimum the √[3]p formats are benchmarked against (fig. 2/16)
//! and SqueezeLLM's sensitivity-weighted variant (Fisher-diag weights).
//!
//! Implementation notes (§D of the paper):
//! * k-means++ initialisation for RMS-scaled data, uniform(-1, 1) for
//!   absmax-scaled data;
//! * iterate until the fraction of changed cluster assignments drops below
//!   1e-4;
//! * 1-D structure exploited: data is sorted once, each iteration finds
//!   segment boundaries by binary search over interval midpoints and
//!   updates centroids from prefix sums — O(K log n) per iteration.

use crate::formats::Codebook;
use crate::util::rng::Rng;

/// Initialisation strategy (paper §D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LloydInit {
    /// k-means++ (RMS-scaled data).
    KmeansPp,
    /// Uniform grid on [-1, 1] (absmax-scaled data).
    Uniform,
}

/// Configuration for the solver.
#[derive(Clone, Copy, Debug)]
pub struct LloydMax {
    pub k: usize,
    pub init: LloydInit,
    pub max_iters: usize,
    pub tol: f64,
    pub seed: u64,
}

impl LloydMax {
    pub fn new(bits: u32, init: LloydInit) -> LloydMax {
        LloydMax {
            k: 1 << bits,
            init,
            max_iters: 200,
            tol: 1e-4,
            seed: DEFAULT_SEED,
        }
    }

    /// Fit codepoints to `data` with optional per-element `weights`
    /// (empty slice = unweighted).
    pub fn fit(&self, data: &[f32], weights: &[f32]) -> Codebook {
        assert!(!data.is_empty());
        assert!(weights.is_empty() || weights.len() == data.len());
        let k = self.k.min(data.len());

        // sort data (with weights riding along)
        let mut order: Vec<u32> = (0..data.len() as u32).collect();
        order.sort_by(|&a, &b| {
            data[a as usize].total_cmp(&data[b as usize])
        });
        let xs: Vec<f64> =
            order.iter().map(|&i| data[i as usize] as f64).collect();
        let ws: Vec<f64> = if weights.is_empty() {
            vec![1.0; xs.len()]
        } else {
            order
                .iter()
                .map(|&i| (weights[i as usize] as f64).max(0.0))
                .collect()
        };
        // prefix sums of w and w*x for O(1) segment means
        let n = xs.len();
        let mut pw = vec![0.0f64; n + 1];
        let mut pwx = vec![0.0f64; n + 1];
        for i in 0..n {
            pw[i + 1] = pw[i] + ws[i];
            pwx[i + 1] = pwx[i] + ws[i] * xs[i];
        }

        let mut centroids = self.initial_centroids(&xs, &ws, k);
        centroids.sort_by(|a, b| a.total_cmp(b));

        let mut boundaries = vec![0usize; k + 1];
        let mut prev_boundaries = vec![usize::MAX; k + 1];
        for _ in 0..self.max_iters {
            // assignment boundaries: first index with x >= midpoint
            boundaries[0] = 0;
            boundaries[k] = n;
            for j in 1..k {
                let mid = 0.5 * (centroids[j - 1] + centroids[j]);
                boundaries[j] = xs.partition_point(|&x| x < mid);
            }
            // update centroids to segment weighted means
            for j in 0..k {
                let (a, b) = (boundaries[j], boundaries[j + 1]);
                if b > a && pw[b] > pw[a] {
                    centroids[j] = (pwx[b] - pwx[a]) / (pw[b] - pw[a]);
                }
                // empty segment: leave centroid in place
            }
            centroids.sort_by(|a, b| a.total_cmp(b));
            // convergence: fraction of moved assignments
            let moved: usize = boundaries
                .iter()
                .zip(prev_boundaries.iter())
                .map(|(&a, &b)| {
                    if b == usize::MAX {
                        n
                    } else {
                        a.abs_diff(b)
                    }
                })
                .sum();
            prev_boundaries.copy_from_slice(&boundaries);
            if (moved as f64) / (n as f64) < self.tol {
                break;
            }
        }
        Codebook::with_bits(
            centroids.iter().map(|&c| c as f32).collect(),
            (self.k as f64).log2(),
        )
    }

    fn initial_centroids(&self, xs: &[f64], ws: &[f64], k: usize) -> Vec<f64> {
        match self.init {
            LloydInit::Uniform => (0..k)
                .map(|i| -1.0 + 2.0 * (i as f64 + 0.5) / k as f64)
                .collect(),
            LloydInit::KmeansPp => {
                let mut rng = Rng::new(self.seed);
                let mut centroids = Vec::with_capacity(k);
                // first centroid: weighted draw
                centroids.push(xs[weighted_draw(&mut rng, ws)]);
                let mut d2: Vec<f64> = xs
                    .iter()
                    .map(|&x| (x - centroids[0]).powi(2))
                    .collect();
                while centroids.len() < k {
                    let probs: Vec<f64> = d2
                        .iter()
                        .zip(ws)
                        .map(|(&d, &w)| d * w)
                        .collect();
                    let total: f64 = probs.iter().sum();
                    let idx = if total > 0.0 {
                        weighted_draw(&mut rng, &probs)
                    } else {
                        rng.below(xs.len())
                    };
                    let c = xs[idx];
                    centroids.push(c);
                    for (d, &x) in d2.iter_mut().zip(xs) {
                        *d = d.min((x - c).powi(2));
                    }
                }
                centroids
            }
        }
    }
}

fn weighted_draw(rng: &mut Rng, weights: &[f64]) -> usize {
    rng.categorical(weights)
}

/// Default deterministic seed for k-means++ initialisation.
pub const DEFAULT_SEED: u64 = 0x1107d;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Family};
    use crate::util::rng::Rng;
    use crate::util::stats::relative_rms_error;

    fn qdq_all(cb: &Codebook, data: &[f32]) -> Vec<f32> {
        data.iter().map(|&x| cb.qdq(x)).collect()
    }

    #[test]
    fn recovers_discrete_clusters() {
        let mut rng = Rng::new(1);
        let mut data = Vec::new();
        for &c in &[-2.0f32, 0.0, 3.0] {
            for _ in 0..1000 {
                data.push(c + 0.01 * rng.normal() as f32);
            }
        }
        let lm = LloydMax {
            k: 3,
            init: LloydInit::KmeansPp,
            max_iters: 100,
            tol: 1e-6,
            seed: 7,
        };
        let cb = lm.fit(&data, &[]);
        let pts = cb.points();
        assert_eq!(pts.len(), 3);
        assert!((pts[0] + 2.0).abs() < 0.05, "{pts:?}");
        assert!(pts[1].abs() < 0.05, "{pts:?}");
        assert!((pts[2] - 3.0).abs() < 0.05, "{pts:?}");
    }

    #[test]
    fn close_to_cbrt_on_normal_data() {
        // fig. 2/16: Lloyd-Max ≈ cube-root-density quantiser for Normal data
        let mut rng = Rng::new(2);
        let data = Dist::standard(Family::Normal, 0.0).sample_vec(&mut rng, 100_000);
        let lm = LloydMax::new(4, LloydInit::KmeansPp).fit(&data, &[]);
        let cbrt = crate::formats::cbrt::cbrt_rms(
            Family::Normal, 0.0, 4, crate::formats::Variant::Symmetric,
            1.0 / 3.0,
        );
        let r_lm = relative_rms_error(&data, &qdq_all(&lm, &data));
        let r_cb = relative_rms_error(&data, &qdq_all(&cbrt, &data));
        // Lloyd-Max is the direct optimum; cbrt should be within a few %
        assert!(r_lm <= r_cb * 1.02, "lm {r_lm} vs cbrt {r_cb}");
        assert!(r_cb <= r_lm * 1.10, "cbrt {r_cb} far from lm {r_lm}");
    }

    #[test]
    fn weighted_fit_biases_centroids() {
        // two clusters; weighting one hugely should pull most centroids there
        let mut data = Vec::new();
        let mut w = Vec::new();
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            data.push(-1.0 + 0.05 * rng.normal() as f32);
            w.push(100.0f32);
            data.push(1.0 + 0.05 * rng.normal() as f32);
            w.push(0.01);
        }
        let lm = LloydMax {
            k: 8,
            init: LloydInit::KmeansPp,
            max_iters: 200,
            tol: 1e-6,
            seed: 11,
        };
        let cb = lm.fit(&data, &w);
        let near_heavy =
            cb.points().iter().filter(|p| (**p + 1.0).abs() < 0.3).count();
        let near_light =
            cb.points().iter().filter(|p| (**p - 1.0).abs() < 0.3).count();
        assert!(
            near_heavy > near_light,
            "{:?}", cb.points()
        );
    }

    #[test]
    fn uniform_init_covers_range() {
        let mut rng = Rng::new(4);
        let data: Vec<f32> =
            (0..10_000).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        let cb = LloydMax::new(3, LloydInit::Uniform).fit(&data, &[]);
        assert_eq!(cb.len(), 8);
        // uniform data ⇒ near-uniform centroids
        let pts = cb.points();
        for w in pts.windows(2) {
            let gap = w[1] - w[0];
            assert!(gap > 0.1 && gap < 0.4, "{pts:?}");
        }
    }

    #[test]
    fn k_larger_than_data_is_safe() {
        let data = [0.0f32, 1.0];
        let cb = LloydMax::new(4, LloydInit::KmeansPp).fit(&data, &[]);
        assert!(cb.len() <= 16);
        assert_eq!(cb.qdq(0.9), 1.0);
    }
}
