//! Quantile-rule baseline formats from the literature: NF4 (Dettmers et al.,
//! QLoRA), SF4 (Dotzel et al.) and AF4 (Yoshida).
//!
//! NF4 uses the published 16 constants.  SF4 follows the same
//! "information-theoretically optimal" equal-population construction as NF4
//! but under a Student-t assumption; AF4 is Yoshida's absmax-aware Normal
//! format optimising *absolute* (L1) error, which by the Panter–Dite rule
//! corresponds to codepoint density ∝ √p rather than ∛p, over the truncated
//! block-maximum model.  (Both reconstructions are documented substitutions
//! — the originals' exact constants are not published to full precision —
//! and are validated structurally in tests.)

use crate::dist::{Dist, Family, Truncated};
use crate::formats::cbrt::truncated_dprime;
use crate::formats::Codebook;

/// The published NF4 codepoints (QLoRA, Dettmers et al. 2023).
pub const NF4_POINTS: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

pub fn nf4() -> Codebook {
    Codebook::with_bits(NF4_POINTS.to_vec(), 4.0)
}

/// NF-b: the NF4 construction generalised to other bit widths — offset
/// equal-population quantiles of the standard Normal, renormalised to
/// [-1, 1], with a guaranteed 0 (Dettmers' "asymmetric halves" recipe).
pub fn nf(bits: u32) -> Codebook {
    quantile_format(Dist::normal(1.0), bits)
}

/// SF-b: the same construction under a Student-t(ν) assumption
/// (Dotzel et al. used ν fitted to LLM weights; 5 is representative).
pub fn sf(bits: u32, nu: f64) -> Codebook {
    quantile_format(Dist::student_t(nu, 1.0), bits)
}

/// The bitsandbytes NF-b recipe (Dettmers et al.): asymmetric halves of
/// equal-population quantiles sharing an exact 0 —
///
/// * positive side: `ppf(linspace(offset, 0.5, 2^(b-1)+1))[:-1]` (2^(b-1)
///   values including the extreme),
/// * negative side: `-ppf(linspace(offset, 0.5, 2^(b-1)))[:-1]` (2^(b-1)−1
///   values),
/// * plus 0; everything divided by the extreme so the ends hit ±1,
///
/// with `offset = 1 − ½(1/(2K) + 1/(2(K−1)))`, K = 2^b (0.9677 for b = 4,
/// matching the published constant).
fn quantile_format(d: Dist, bits: u32) -> Codebook {
    assert!(bits >= 2);
    let k = 1usize << bits;
    let half = k / 2;
    let offset =
        1.0 - 0.5 * (1.0 / (2.0 * k as f64) + 1.0 / (2.0 * (k - 1) as f64));
    let linspace_ppf = |n: usize| -> Vec<f64> {
        // linspace(offset, 0.5, n)[:-1] through the ppf
        (0..n - 1)
            .map(|i| {
                let p =
                    offset + (0.5 - offset) * i as f64 / (n - 1) as f64;
                d.ppf(p)
            })
            .collect()
    };
    let pos = linspace_ppf(half + 1); // 2^(b-1) values, descending
    let neg: Vec<f64> =
        linspace_ppf(half).iter().map(|&x| -x).collect();
    let mut pts: Vec<f64> = Vec::with_capacity(k);
    pts.extend(&neg);
    pts.push(0.0);
    pts.extend(&pos);
    let absmax = pts
        .iter()
        .fold(0f64, |m, &x| m.max(x.abs()));
    let points: Vec<f32> =
        pts.iter().map(|&x| (x / absmax) as f32).collect();
    Codebook::with_bits(points, bits as f64)
}

/// AF4: Yoshida's absmax-aware Normal format. Density ∝ p^(1/2) (L1-optimal
/// Panter–Dite exponent) over the truncated block-maximum mixture; ±1
/// endpoints included.
pub fn af4(block: usize) -> Codebook {
    let k = 16usize;
    let trunc = truncated_dprime(Family::Normal, 0.0, block, 0.5);
    let points: Vec<f32> = (0..k)
        .map(|i| trunc.ppf(i as f64 / (k - 1) as f64) as f32)
        .collect();
    Codebook::with_bits(points, 4.0)
}

/// Helper: equal-population check used by tests and the fig. 32 analysis.
pub fn population_of(cb: &Codebook, d: &Dist, lo: f64, hi: f64) -> Vec<f64> {
    let t = Truncated::new(*d, lo, hi);
    let pts = cb.points();
    let mut pops = Vec::with_capacity(pts.len());
    for (i, _) in pts.iter().enumerate() {
        let left = if i == 0 {
            lo
        } else {
            0.5 * (pts[i - 1] + pts[i]) as f64
        };
        let right = if i == pts.len() - 1 {
            hi
        } else {
            0.5 * (pts[i] + pts[i + 1]) as f64
        };
        pops.push(t.cdf(right) - t.cdf(left));
    }
    pops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_constants() {
        let cb = nf4();
        assert_eq!(cb.len(), 16);
        assert_eq!(cb.points()[0], -1.0);
        assert_eq!(*cb.points().last().unwrap(), 1.0);
        assert!(cb.has_zero());
        assert_eq!(cb.points()[7], 0.0);
    }

    #[test]
    fn nf_reconstruction_close_to_published_nf4() {
        // our reconstruction of the recipe should land near the published
        // constants (they used slightly different offset handling, so
        // tolerate a few % absolute)
        let ours = nf(4);
        assert_eq!(ours.len(), 16);
        assert!(ours.has_zero());
        assert_eq!(ours.points()[0], -1.0);
        assert_eq!(*ours.points().last().unwrap(), 1.0);
        for (a, b) in ours.points().iter().zip(NF4_POINTS.iter()) {
            assert!((a - b).abs() < 5e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn sf4_structure_and_tail_concentration() {
        // Student-t has heavier tails than Normal, so after renormalising
        // the extremes to ±1 the *median* |codepoint| of SF4 sits below
        // NF4's (mass concentrates centrally relative to the tails).
        let s = sf(4, 5.0);
        let n = nf(4);
        assert_eq!(s.len(), 16);
        assert!(s.has_zero());
        assert_eq!(s.points()[0], -1.0);
        assert_eq!(*s.points().last().unwrap(), 1.0);
        let med = |cb: &Codebook| {
            let mut m: Vec<f64> =
                cb.points().iter().map(|p| p.abs() as f64).collect();
            m.sort_by(|a, b| a.total_cmp(b));
            m[m.len() / 2]
        };
        assert!(
            med(&s) < med(&n) + 1e-6,
            "SF4 median |p| {} vs NF4 {}",
            med(&s),
            med(&n)
        );
    }

    #[test]
    fn af4_structure() {
        let cb = af4(64);
        assert_eq!(cb.len(), 16);
        assert_eq!(cb.points()[0], -1.0);
        assert_eq!(*cb.points().last().unwrap(), 1.0);
        // √p density is flatter than ∛p? no — α smaller = flatter. 1/2 > 1/3
        // so AF4 concentrates more than the cbrt format.
        let cbrt = crate::formats::cbrt::cbrt_absmax(
            Family::Normal, 0.0, 4, 64,
            crate::formats::Variant::Symmetric, 1.0 / 3.0,
        );
        let af_inner =
            cb.points().iter().filter(|p| p.abs() < 0.3).count();
        let cb_inner =
            cbrt.points().iter().filter(|p| p.abs() < 0.3).count();
        assert!(af_inner >= cb_inner, "{af_inner} vs {cb_inner}");
    }

    #[test]
    fn quantile_formats_equal_population() {
        // the defining property: each *interior* bin carries ~equal
        // probability mass under the source distribution, evaluated in the
        // pre-normalisation quantile space (the endpoint bins absorb the
        // offset tails, so exclude them).
        let d = Dist::normal(1.0);
        let cb = nf(4);
        // undo the per-side renormalisation: scale sides back by the
        // extreme quantiles the construction used
        let half = 8usize;
        let offset = 1.0 - 1.0 / (2.0 * half as f64);
        let neg_max = -d.ppf(1.0 - offset);
        let pos_max = d.ppf(offset);
        let unnorm: Vec<f32> = cb
            .points()
            .iter()
            .map(|&p| {
                if p < 0.0 {
                    p * neg_max as f32
                } else {
                    p * pos_max as f32
                }
            })
            .collect();
        let raw = Codebook::new(unnorm);
        let pops = population_of(&raw, &d, -8.0, 8.0);
        let interior = &pops[1..pops.len() - 1];
        let mean = crate::util::stats::mean(interior);
        let cv = crate::util::stats::std(interior) / mean;
        assert!(cv < 0.35, "interior populations uneven: cv = {cv}");
    }
}
