//! Integer element formats (normalised to the absmax convention: the
//! representable range touches ±1).
//!
//! * **Asymmetric** (the INT standard, fig. 3): codepoints k/2^(b-1) for
//!   k ∈ [-2^(b-1), 2^(b-1)-1] — contains exact 0, sacrifices +1.
//! * **Symmetric**: 2^b evenly spaced points including both ±1, no zero.
//! * **Signmax**: for signed-max scaling — {0, +1} special plus an even grid
//!   covering [-1, 1) (fig. 3 right).

use crate::formats::{Codebook, Variant};

/// Build an INT-b codebook for the given variant. `bits` ∈ [2, 8].
pub fn int_codebook(bits: u32, variant: Variant) -> Codebook {
    assert!((2..=8).contains(&bits), "int bits {bits}");
    let k = 1usize << bits;
    let points: Vec<f32> = match variant {
        Variant::Asymmetric => {
            let half = (k / 2) as f32;
            (0..k).map(|i| (i as f32 - half) / half).collect()
        }
        Variant::Symmetric => (0..k)
            .map(|i| -1.0 + 2.0 * i as f32 / (k - 1) as f32)
            .collect(),
        Variant::Signmax => {
            // {0, 1} plus k-2 evenly spaced points on [-1, 1), skipping
            // slots that would collide with the specials.
            let mut pts = vec![0.0f32, 1.0];
            let body = k - 2;
            for i in 0..body {
                let x = -1.0 + 2.0 * i as f32 / body as f32;
                if x != 0.0 {
                    pts.push(x);
                } else {
                    pts.push(1.0 / body as f32); // fill the freed slot
                }
            }
            pts
        }
    };
    Codebook::with_bits(points, bits as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_matches_int_convention() {
        let cb = int_codebook(3, Variant::Asymmetric);
        assert_eq!(cb.len(), 8);
        assert_eq!(
            cb.points(),
            &[-1.0, -0.75, -0.5, -0.25, 0.0, 0.25, 0.5, 0.75]
        );
        assert!(cb.has_zero());
    }

    #[test]
    fn symmetric_touches_both_endpoints() {
        let cb = int_codebook(4, Variant::Symmetric);
        assert_eq!(cb.len(), 16);
        assert_eq!(cb.points()[0], -1.0);
        assert_eq!(cb.points()[15], 1.0);
        assert!(!cb.has_zero());
        // mirror symmetry
        for i in 0..16 {
            assert!(
                (cb.points()[i] + cb.points()[15 - i]).abs() < 1e-6,
                "not symmetric at {i}"
            );
        }
    }

    #[test]
    fn signmax_has_specials() {
        for bits in 2..=5 {
            let cb = int_codebook(bits, Variant::Signmax);
            assert!(cb.has_zero(), "b={bits}");
            assert_eq!(cb.points().last().copied(), Some(1.0));
            assert_eq!(cb.len(), 1 << bits, "no collisions allowed b={bits}");
        }
    }

    #[test]
    fn storage_bits_recorded() {
        assert_eq!(int_codebook(4, Variant::Asymmetric).storage_bits(), 4.0);
        assert_eq!(int_codebook(2, Variant::Symmetric).storage_bits(), 2.0);
    }
}
