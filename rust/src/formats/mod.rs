//! Element formats: every fixed-length quantiser family evaluated in the
//! paper, all reduced to one machinery — a sorted [`Codebook`] of codepoints
//! in normalised space.
//!
//! | module | formats |
//! |---|---|
//! | [`int`] | INT-b, symmetric / asymmetric / signmax variants |
//! | [`float`] | generic EkMm minifloats (E2M1, E3M0, E5M2, ...) |
//! | [`cbrt`] | the paper's √[3]p Normal / Laplace / Student-t for RMS, absmax and signmax scaling |
//! | [`quantile`] | quantile-rule baselines: NF4, SF4, AF4 |
//! | [`lloyd`] | (Fisher-weighted) Lloyd-Max, k-means++ / uniform init |
//!
//! # The LUT kernel layer
//!
//! Nearest-neighbour search is served by a precomputed uniform-bucket
//! lookup table ([`Codebook::has_lut`]) built once per codebook.  The
//! invariants every path relies on:
//!
//! * **Bucket grid.** Buckets tile the midpoint span `[mids[0],
//!   mids[last]]`; `bucket(y) = ⌊(y − lo)·inv_step⌋` saturated into
//!   `[0, L−1]` (Rust's float→int cast maps NaN and negatives to 0, and
//!   +∞ to the top bucket).  The bucket map is monotone in `y`, so a
//!   midpoint assigned to an earlier bucket is `<= y` for every `y` in a
//!   later bucket — construction and query use the *same* float
//!   expression, which is what makes the argument sound under rounding.
//! * **Bucket width.** `L` starts at ~4× the codepoint count and doubles
//!   until **every bucket holds at most one midpoint** (or the 2^16-bucket
//!   budget is exhausted, in which case the codebook simply keeps the
//!   reference path — correctness never depends on the LUT existing).
//! * **Tie-break.** The stored per-bucket value is the number of midpoints
//!   in strictly earlier buckets; the (at most one) midpoint inside the
//!   bucket is resolved with a single `y >= mid` comparison, reproducing
//!   the reference "ties go to the upper codepoint" rule exactly.
//! * **Bit-exactness contract.** `quantise` (LUT) and [`Codebook::quantise_ref`]
//!   (compare-count / binary search) return identical indices for *every*
//!   `f32` input, including ±∞, subnormals, exact midpoints and NaN
//!   (NaN maps to index 0 on all paths).  `rust/tests/lut_props.rs` and the
//!   bench smoke gate in `benches/formats.rs` enforce this offline.
//! * **Batched gather.** The hot batch kernels ([`Codebook::qdq_scaled_slice`],
//!   [`Codebook::encode_block`]) walk the LUT in tiles of [`Lut::TILE`]
//!   elements: the bucket slots for the whole tile are computed as `u32`s in
//!   a straight arithmetic pass (subtract / multiply / saturating cast —
//!   auto-vectorisable) before any table load, then the `base`/`pad_mids`
//!   gathers pipeline behind it.  The `f32 → u32` saturating cast agrees
//!   with the scalar `f32 → usize` cast for every input (NaN and negatives
//!   to 0, +∞ and overflow to the top, both clamped by the same `min`), so
//!   tiled and scalar lookups are bit-identical.
//!
//! The decode side mirrors this: [`Codebook::decode_block`] hoists the
//!   per-block scale into a scaled-codepoint table once, making the inner
//!   dequantise loop a single gather (invariants in `EXPERIMENTS.md` §Decode).

pub mod cbrt;
pub mod float;
pub mod int;
pub mod lloyd;
pub mod quantile;

/// Symmetry variant of a codepoint distribution (§2.1, fig. 3).
///
/// * `Symmetric` — even count, mirror-symmetric, no exact zero.
/// * `Asymmetric` — contains exact zero; for absmax formats the `+1`
///   endpoint is sacrificed (the INT convention), for RMS formats the
///   largest positive point is dropped.
/// * `Signmax` — assumes the block maximum is at `+1` exactly (signed-max
///   scaling): special codepoints {0, +1} plus a truncated-D′ body.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Symmetric,
    Asymmetric,
    Signmax,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Symmetric => "sym",
            Variant::Asymmetric => "asym",
            Variant::Signmax => "signmax",
        }
    }
}

/// Precomputed uniform-bucket lookup table over the midpoint span — the
/// branchless nearest-neighbour kernel (module docs list the invariants).
#[derive(Clone, Debug)]
struct Lut {
    /// Bucket-grid origin: the lowest midpoint.
    lo: f32,
    /// Buckets per unit: `bucket(y) = ⌊(y − lo)·inv_step⌋`, saturated.
    inv_step: f32,
    /// Per bucket: number of midpoints in strictly earlier buckets.
    base: Vec<u16>,
    /// Midpoints plus a trailing NaN sentinel so the boundary comparison
    /// `y >= pad_mids[base]` is false (never counts) once every midpoint
    /// is already accounted for.
    pad_mids: Vec<f32>,
}

impl Lut {
    /// Budget on table length; codebooks whose midpoint density exceeds it
    /// (e.g. normalised E5M2, whose subnormal gaps are ~1e-10 of the span)
    /// keep the reference path.
    const MAX_BUCKETS: usize = 1 << 16;

    fn build(mids: &[f32]) -> Option<Lut> {
        let n = mids.len();
        if n == 0 || n >= u16::MAX as usize {
            return None;
        }
        let (lo, hi) = (mids[0], mids[n - 1]);
        if !lo.is_finite() || !hi.is_finite() {
            return None;
        }
        let span = hi - lo;
        if !(span > 0.0) {
            // degenerate span: one midpoint (or float-equal midpoints,
            // which one comparison cannot tell apart) — reference path
            return None;
        }
        let mut len = (4 * (n + 1)).next_power_of_two().max(64);
        while len <= Self::MAX_BUCKETS {
            let inv_step = len as f32 / span;
            if !inv_step.is_finite() {
                return None; // span subnormal enough to overflow the rate
            }
            // Assign each midpoint to a bucket with the *exact* query
            // expression; retry with finer buckets on any collision.
            let mut per_bucket = vec![0u16; len];
            let mut ok = true;
            for &m in mids {
                let t = (((m - lo) * inv_step) as usize).min(len - 1);
                per_bucket[t] += 1;
                if per_bucket[t] > 1 {
                    ok = false;
                    break;
                }
            }
            if !ok {
                len *= 2;
                continue;
            }
            let mut base = vec![0u16; len];
            let mut acc = 0u16;
            for (slot, c) in base.iter_mut().zip(&per_bucket) {
                *slot = acc;
                acc += c;
            }
            let mut pad_mids = mids.to_vec();
            pad_mids.push(f32::NAN);
            return Some(Lut {
                lo,
                inv_step,
                base,
                pad_mids,
            });
        }
        None
    }

    /// Nearest-codepoint index: one multiply, one table load, at most one
    /// midpoint comparison.  NaN/negative casts hit bucket 0 and the NaN
    /// sentinel comparison is always false, so no input needs a branch.
    #[inline(always)]
    fn lookup(&self, y: f32) -> u16 {
        let t = (((y - self.lo) * self.inv_step) as usize)
            .min(self.base.len() - 1);
        // SAFETY: t < base.len() by the min above; base[t] <= mids.len(),
        // and pad_mids has exactly mids.len() + 1 entries.
        let b = unsafe { *self.base.get_unchecked(t) };
        let m = unsafe { *self.pad_mids.get_unchecked(b as usize) };
        b + (y >= m) as u16
    }

    /// Elements per batched-gather tile (module docs, "Batched gather").
    const TILE: usize = 32;

    /// Batched [`Lut::lookup`] over one tile: bucket slots for all lanes
    /// are computed as `u32`s in a pure-arithmetic pass before the table
    /// gathers run.  The slot pass dispatches to an explicit AVX2/NEON
    /// kernel ([`crate::util::simd::lut_slots`]); the scalar oracle lives
    /// there verbatim and every path is bit-identical to the scalar
    /// lookup: the saturating `f32 → u32` cast matches `f32 → usize` for
    /// every input once both are clamped to the (≤ 2^16-entry) table.
    #[inline]
    fn lookup_tile(
        &self,
        ys: &[f32; Self::TILE],
        out: &mut [u16; Self::TILE],
    ) {
        let top = (self.base.len() - 1) as u32;
        let mut slots = [0u32; Self::TILE];
        crate::util::simd::lut_slots(
            crate::util::simd::active(),
            ys,
            self.lo,
            self.inv_step,
            top,
            &mut slots,
        );
        for ((o, &t), &y) in out.iter_mut().zip(slots.iter()).zip(ys.iter())
        {
            // SAFETY: t <= top < base.len(); base[t] <= mids.len(), and
            // pad_mids has exactly mids.len() + 1 entries.
            let b = unsafe { *self.base.get_unchecked(t as usize) };
            let m = unsafe { *self.pad_mids.get_unchecked(b as usize) };
            *o = b + (y >= m) as u16;
        }
    }
}

/// A finite, sorted set of codepoints plus nearest-neighbour machinery.
///
/// `storage_bits` is the bit width of the *stored index* (may exceed
/// log2(len) when a format wastes encodings, e.g. duplicate float zero).
#[derive(Clone, Debug)]
pub struct Codebook {
    points: Vec<f32>,
    mids: Vec<f32>,
    storage_bits: f64,
    lut: Option<Lut>,
}

impl Codebook {
    /// Build from codepoints (sorted internally). `storage_bits` defaults
    /// to ⌈log2 n⌉ via [`Codebook::new`].
    pub fn with_bits(mut points: Vec<f32>, storage_bits: f64) -> Codebook {
        assert!(!points.is_empty(), "empty codebook");
        points.sort_by(|a, b| a.total_cmp(b));
        points.dedup();
        let mids: Vec<f32> = points
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        let lut = Lut::build(&mids);
        Codebook {
            points,
            mids,
            storage_bits,
            lut,
        }
    }

    pub fn new(points: Vec<f32>) -> Codebook {
        let n = points.len();
        let mut cb = Codebook::with_bits(points, 0.0);
        // after dedup the *stored* width still covers the requested points
        cb.storage_bits = (n.max(2) as f64).log2().ceil();
        cb
    }

    /// Exact-entropy storage width, for non-power-of-two codebooks where the
    /// caller models ideal packing (used by some sweeps): log2(len).
    pub fn with_fractional_bits(points: Vec<f32>) -> Codebook {
        let mut cb = Codebook::with_bits(points, 0.0);
        cb.storage_bits = (cb.points.len() as f64).log2();
        cb
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.points.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn points(&self) -> &[f32] {
        &self.points
    }

    /// Bits per element when storing raw indices.
    pub fn storage_bits(&self) -> f64 {
        self.storage_bits
    }

    /// Index of the nearest codepoint (ties to the upper codepoint, matching
    /// `jnp.searchsorted(mids, y, side="right")` in the Pallas kernel;
    /// NaN maps to index 0).  Served from the precomputed LUT when one
    /// exists — bit-exact with [`Codebook::quantise_ref`] by contract.
    ///
    /// Hot loops should prefer the batch entry points
    /// ([`Codebook::quantise_slice`], [`Codebook::qdq_scaled_slice`],
    /// [`Codebook::encode_block`]) which hoist the LUT dispatch out of the
    /// per-element path; the scalar form is for one-offs and tests.
    #[inline]
    pub fn quantise(&self, y: f32) -> u16 {
        match &self.lut {
            Some(lut) => lut.lookup(y),
            None => self.quantise_ref(y),
        }
    }

    /// Reference nearest-codepoint search (compare-count for small books,
    /// binary search above 32 midpoints) — the LUT-free oracle the
    /// equivalence tests and the bench smoke gate compare against.
    #[inline]
    pub fn quantise_ref(&self, y: f32) -> u16 {
        let mids = &self.mids;
        if mids.len() <= 32 {
            // branchless compare-count (NaN compares false ⇒ index 0)
            let mut idx = 0u16;
            for &m in mids {
                idx += (y >= m) as u16;
            }
            idx
        } else {
            if y.is_nan() {
                return 0; // match the compare-count path's NaN convention
            }
            match mids.binary_search_by(|m| m.total_cmp(&y)) {
                // y == mids[i]: tie goes up
                Ok(i) => (i + 1) as u16,
                Err(i) => i as u16,
            }
        }
    }

    /// True when the uniform-bucket LUT fast path is active.
    pub fn has_lut(&self) -> bool {
        self.lut.is_some()
    }

    /// Drop the LUT so every lookup takes the reference path — for
    /// benchmarking the kernel speedup and for equivalence tests only.
    pub fn with_lut_disabled(mut self) -> Codebook {
        self.lut = None;
        self
    }

    #[inline]
    pub fn dequantise(&self, idx: u16) -> f32 {
        self.points[idx as usize]
    }

    #[inline]
    pub fn qdq(&self, y: f32) -> f32 {
        self.points[self.quantise(y) as usize]
    }

    /// The batch nearest-neighbour entry point — hot loops go through this
    /// (or the fused [`Codebook::qdq_scaled_slice`] /
    /// [`Codebook::encode_block`]) rather than scalar [`Codebook::quantise`]
    /// so the LUT dispatch happens once per slice, not once per element.
    pub fn quantise_slice(&self, ys: &[f32], out: &mut Vec<u16>) {
        out.clear();
        out.reserve(ys.len());
        match &self.lut {
            Some(lut) => out.extend(ys.iter().map(|&y| lut.lookup(y))),
            None => out.extend(ys.iter().map(|&y| self.quantise_ref(y))),
        }
    }

    pub fn qdq_slice(&self, ys: &mut [f32]) {
        // fused batch path (scale 1 ⇒ plain nearest-codepoint snap)
        self.qdq_scaled_slice(ys, 1.0, 1.0);
    }

    /// Fused scale→quantise→descale over a slice: `x ← Q(x·inv)·s`.
    /// The hot inner loop of every block qdq.  Tiered: LUT kernel when
    /// available, else a padded compare-count loop with static bounds
    /// (vectorises), else scalar binary search.
    pub fn qdq_scaled_slice(&self, xs: &mut [f32], inv: f32, s: f32) {
        let pts = &self.points;
        if let Some(lut) = &self.lut {
            // batched-gather tiles: scale the whole tile, resolve all
            // bucket slots, then gather codepoints (module docs)
            let mut ys = [0f32; Lut::TILE];
            let mut idx = [0u16; Lut::TILE];
            let mut chunks = xs.chunks_exact_mut(Lut::TILE);
            for chunk in chunks.by_ref() {
                for (y, &x) in ys.iter_mut().zip(chunk.iter()) {
                    *y = x * inv;
                }
                lut.lookup_tile(&ys, &mut idx);
                for (x, &i) in chunk.iter_mut().zip(idx.iter()) {
                    // SAFETY: lookup_tile returns < points.len()
                    *x = unsafe { *pts.get_unchecked(i as usize) } * s;
                }
            }
            for x in chunks.into_remainder().iter_mut() {
                let i = lut.lookup(*x * inv);
                // SAFETY: lookup returns < points.len()
                *x = unsafe { *pts.get_unchecked(i as usize) } * s;
            }
            return;
        }
        let mids = &self.mids;
        if mids.len() <= 32 {
            // copy midpoints into a padded local array (pad with +inf so
            // padded lanes never increment the index)
            let mut m = [f32::INFINITY; 32];
            m[..mids.len()].copy_from_slice(mids);
            let k = mids.len();
            // unrolled-by-compiler loop with static upper bound
            for x in xs.iter_mut() {
                let y = *x * inv;
                let mut idx = 0u32;
                for &mid in m[..k].iter() {
                    idx += (y >= mid) as u32;
                }
                // SAFETY: idx <= k < points.len()
                *x = unsafe { *pts.get_unchecked(idx as usize) } * s;
            }
        } else {
            for x in xs.iter_mut() {
                *x = self.qdq(*x * inv) * s;
            }
        }
    }

    /// Fused encode kernel for one scale block: quantise `block·inv`,
    /// write indices into `out`, bump the index histogram and accumulate
    /// the squared reconstruction error of `points[idx]·s` — one pass,
    /// no intermediate buffers (the [`crate::quant::Quantiser`] hot loop).
    pub fn encode_block(
        &self,
        block: &[f32],
        inv: f32,
        s: f32,
        out: &mut [u16],
        sq_err: &mut f64,
        counts: &mut [u64],
    ) {
        debug_assert_eq!(block.len(), out.len());
        // hard assert: the unchecked histogram write below relies on it
        assert_eq!(counts.len(), self.points.len());
        let pts = &self.points;
        let mut sq = *sq_err;
        match &self.lut {
            Some(lut) => {
                // same tile shape as qdq_scaled_slice: bucket arithmetic
                // for the whole tile first, then the gather + accumulate
                let mut ys = [0f32; Lut::TILE];
                let mut idx = [0u16; Lut::TILE];
                let n = block.len();
                let mut base = 0usize;
                while base + Lut::TILE <= n {
                    let tile = &block[base..base + Lut::TILE];
                    for (y, &x) in ys.iter_mut().zip(tile.iter()) {
                        *y = x * inv;
                    }
                    lut.lookup_tile(&ys, &mut idx);
                    for (j, (&x, &i)) in
                        tile.iter().zip(idx.iter()).enumerate()
                    {
                        out[base + j] = i;
                        // SAFETY: lookup_tile returns < points.len()
                        //         == counts.len()
                        let p = unsafe { *pts.get_unchecked(i as usize) };
                        unsafe {
                            *counts.get_unchecked_mut(i as usize) += 1;
                        }
                        let d = x as f64 - (p * s) as f64;
                        sq += d * d;
                    }
                    base += Lut::TILE;
                }
                for (&x, slot) in
                    block[base..].iter().zip(out[base..].iter_mut())
                {
                    let i = lut.lookup(x * inv);
                    *slot = i;
                    // SAFETY: lookup returns < points.len() == counts.len()
                    let p = unsafe { *pts.get_unchecked(i as usize) };
                    unsafe {
                        *counts.get_unchecked_mut(i as usize) += 1;
                    }
                    let d = x as f64 - (p * s) as f64;
                    sq += d * d;
                }
            }
            None => {
                for (&x, slot) in block.iter().zip(out.iter_mut()) {
                    let idx = self.quantise_ref(x * inv);
                    *slot = idx;
                    counts[idx as usize] += 1;
                    let d = x as f64 - (pts[idx as usize] * s) as f64;
                    sq += d * d;
                }
            }
        }
        *sq_err = sq;
    }

    /// Fused dequantise kernel for one scale block — the decode-side mirror
    /// of [`Codebook::encode_block`]: `out[i] = points[indices[i]]·s` with
    /// the scale multiplied into a per-block scaled-codepoint table once
    /// (`scaled` is caller-owned scratch, reused across blocks), so the
    /// inner loop is a single gather with no per-element multiply.
    /// Bit-exact with the scalar `dequantise(idx) * s` — the same f32
    /// multiply, hoisted.  Blocks shorter than the codebook skip the table
    /// (building it would dominate) and multiply per element instead.
    /// The table gather dispatches to an explicit AVX2/NEON kernel
    /// ([`crate::util::simd::gather_u16_f32`]; scalar oracle kept there
    /// verbatim) and every path panics on an out-of-range index (corrupt
    /// [`crate::quant::Encoded`]) — indices are validated before any
    /// hardware gather runs.
    pub fn decode_block(
        &self,
        indices: &[u16],
        s: f32,
        out: &mut [f32],
        scaled: &mut Vec<f32>,
    ) {
        debug_assert_eq!(indices.len(), out.len());
        let pts = &self.points;
        if indices.len() >= pts.len() {
            scaled.clear();
            scaled.extend(pts.iter().map(|&p| p * s));
            crate::util::simd::gather_u16_f32(
                crate::util::simd::active(),
                scaled,
                indices,
                out,
            );
        } else {
            for (slot, &i) in out.iter_mut().zip(indices.iter()) {
                *slot = pts[i as usize] * s;
            }
        }
    }

    /// LUT kernel parameters `(lo, inv_step, top)` when the fast path is
    /// built — exposed so the forced-ISA parity tests and benches can
    /// drive [`crate::util::simd::lut_slots`] with this codebook's exact
    /// arithmetic.  `None` on reference-path codebooks.
    #[doc(hidden)]
    pub fn lut_params(&self) -> Option<(f32, f32, u32)> {
        self.lut
            .as_ref()
            .map(|l| (l.lo, l.inv_step, (l.base.len() - 1) as u32))
    }

    /// Largest |codepoint| (the representable range).
    pub fn absmax(&self) -> f32 {
        self.points
            .iter()
            .fold(0f32, |m, &p| m.max(p.abs()))
    }

    /// RMS of the codepoints under nearest-assignment of a distribution is
    /// not stored; this is the plain codepoint RMS (used by moment checks).
    pub fn point_rms(&self) -> f64 {
        crate::util::stats::rms(&self.points)
    }

    /// True iff an exact 0.0 codepoint exists.
    pub fn has_zero(&self) -> bool {
        self.points.iter().any(|&p| p == 0.0)
    }

    /// Snap the codepoint nearest zero to exact 0.0 (count unchanged) —
    /// the minimal "give me an encoding for zero" surgery used by
    /// data-driven formats (Lloyd-Max asymmetric variant).
    pub fn asymmetrise(self) -> Codebook {
        let bits = self.storage_bits;
        let mut pts = self.points;
        let (nearest, _) = pts
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.abs().partial_cmp(&b.abs()).unwrap()
            })
            .unwrap();
        pts[nearest] = 0.0;
        Codebook::with_bits(pts, bits)
    }

    /// The adversarial probe set for LUT/reference equivalence checking —
    /// the single source of truth shared by the property tests
    /// (`rust/tests/lut_props.rs`), the unit tests and the bench smoke gate
    /// (`benches/formats.rs`): IEEE specials, subnormals, every codepoint
    /// and exact midpoint (the tie-break inputs) plus their one-ULP
    /// neighbours.
    pub fn adversarial_probes(&self) -> Vec<f32> {
        let mut ys = vec![
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            -f32::NAN,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            1e-45, // smallest positive subnormal
            -1e-45,
            f32::MAX,
            f32::MIN,
            1.0,
            -1.0,
        ];
        for &p in &self.points {
            ys.extend([p, ulp_step(p, true), ulp_step(p, false)]);
        }
        for &m in &self.mids {
            ys.extend([m, ulp_step(m, true), ulp_step(m, false)]);
        }
        ys
    }

    /// Quantisation-bucket populations for a batch of scaled samples
    /// (probability model for entropy coding / fig. 5 histograms).
    pub fn bucket_counts(&self, ys: &[f32]) -> Vec<u64> {
        let mut counts = vec![0u64; self.len()];
        match &self.lut {
            Some(lut) => {
                for &y in ys {
                    counts[lut.lookup(y) as usize] += 1;
                }
            }
            None => {
                for &y in ys {
                    counts[self.quantise_ref(y) as usize] += 1;
                }
            }
        }
        counts
    }
}

/// One ULP toward +∞ (`up`) or −∞ from a finite `x` (non-finite inputs
/// pass through) — probe-set helper for the equivalence contract.
fn ulp_step(x: f32, up: bool) -> f32 {
    if !x.is_finite() {
        return x;
    }
    if x == 0.0 {
        let tiny = f32::from_bits(1);
        return if up { tiny } else { -tiny };
    }
    let bits = x.to_bits();
    // moving the bit pattern away from zero grows the magnitude
    if (x >= 0.0) == up {
        f32::from_bits(bits + 1)
    } else {
        f32::from_bits(bits - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{check, Gen};

    #[test]
    fn quantise_nearest_small_and_large() {
        // small (compare-count) and large (binary search) paths must agree
        let pts: Vec<f32> = (0..64).map(|i| i as f32 * 0.37 - 11.0).collect();
        let small = Codebook::new(pts[..16].to_vec());
        let large = Codebook::new(pts.clone());
        for i in 0..1000 {
            let y = -15.0 + i as f32 * 0.04;
            let qs = small.qdq(y);
            // nearest by brute force
            let want = small
                .points()
                .iter()
                .copied()
                .min_by(|a, b| {
                    (a - y).abs().partial_cmp(&(b - y).abs()).unwrap()
                })
                .unwrap();
            assert!(
                (qs - want).abs() < 1e-6 || (qs - y).abs() <= (want - y).abs() + 1e-6,
                "y={y} qs={qs} want={want}"
            );
            let ql = large.qdq(y);
            let want_l = large
                .points()
                .iter()
                .copied()
                .min_by(|a, b| {
                    (a - y).abs().partial_cmp(&(b - y).abs()).unwrap()
                })
                .unwrap();
            assert!((ql - want_l).abs() < 1e-6 || (ql - y).abs() <= (want_l - y).abs() + 1e-6);
        }
    }

    #[test]
    fn paths_agree_property() {
        check("codebook-paths-agree", 100, |g: &mut Gen| {
            let n = 33 + g.rng.below(64); // force binary-search path
            let pts = g.f32_vec(n, 2.0);
            let big = Codebook::new(pts.clone());
            // A codebook with the same points but linear search, via chunks
            let ys = g.f32_vec(64, 3.0);
            for &y in &ys {
                let idx = big.quantise(y);
                // check |y - points[idx]| is minimal
                let d = (big.dequantise(idx) - y).abs();
                for &p in big.points() {
                    assert!(
                        d <= (p - y).abs() + 1e-5,
                        "idx {idx} not nearest for y={y}"
                    );
                }
            }
        });
    }

    #[test]
    fn dedup_and_sorting() {
        let cb = Codebook::new(vec![1.0, -1.0, 0.0, 1.0, -1.0]);
        assert_eq!(cb.points(), &[-1.0, 0.0, 1.0]);
        // storage bits reflect the 5 requested encodings
        assert_eq!(cb.storage_bits(), 3.0);
    }

    #[test]
    fn qdq_idempotent_on_codepoints() {
        let cb = Codebook::new(vec![-1.0, -0.25, 0.0, 0.6, 1.0]);
        for &p in cb.points() {
            assert_eq!(cb.qdq(p), p);
        }
    }

    #[test]
    fn asymmetrise_adds_zero() {
        let cb = Codebook::new(vec![-1.0, -0.3, 0.3, 1.0]);
        assert!(!cb.has_zero());
        let a = cb.asymmetrise();
        assert!(a.has_zero());
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn bucket_counts_sum() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0]);
        let ys = [-2.0f32, -0.6, -0.4, 0.1, 0.9, 2.0];
        let counts = cb.bucket_counts(&ys);
        assert_eq!(counts.iter().sum::<u64>() as usize, ys.len());
        assert_eq!(counts, vec![2, 2, 2]);
    }

    #[test]
    fn lut_active_for_real_formats_and_matches_reference() {
        use crate::formats::int::int_codebook;
        let cb = int_codebook(4, Variant::Asymmetric);
        assert!(cb.has_lut(), "int4 must take the LUT path");
        // shared adversarial set (specials, midpoints, ULP neighbours)
        // plus a dense linear sweep
        let mut probes = cb.adversarial_probes();
        for i in -400..400 {
            probes.push(i as f32 * 0.005);
        }
        for &y in &probes {
            assert_eq!(
                cb.quantise(y),
                cb.quantise_ref(y),
                "LUT vs reference at y={y:?}"
            );
        }
        // NaN contract: index 0 everywhere
        assert_eq!(cb.quantise(f32::NAN), 0);
        assert_eq!(cb.quantise_ref(f32::NAN), 0);
        let big = Codebook::new((0..64).map(|i| i as f32 * 0.1).collect());
        assert_eq!(big.quantise_ref(f32::NAN), 0);
    }

    #[test]
    fn lut_disabled_still_agrees() {
        let cb = Codebook::new(vec![-1.0, -0.4, -0.1, 0.0, 0.2, 0.7, 1.0]);
        let plain = cb.clone().with_lut_disabled();
        assert!(cb.has_lut() && !plain.has_lut());
        for i in -50..50 {
            let y = i as f32 * 0.043;
            assert_eq!(cb.quantise(y), plain.quantise(y));
        }
    }

    #[test]
    fn encode_block_matches_scalar_machinery() {
        let cb = crate::formats::int::int_codebook(4, Variant::Symmetric);
        let block: Vec<f32> = (0..64).map(|i| (i as f32 - 31.0) * 0.11).collect();
        let (inv, s) = (1.0 / 3.7, 3.7f32);
        let mut out = vec![0u16; block.len()];
        let mut sq = 0f64;
        let mut counts = vec![0u64; cb.len()];
        cb.encode_block(&block, inv, s, &mut out, &mut sq, &mut counts);
        let mut want_sq = 0f64;
        for (i, &x) in block.iter().enumerate() {
            let idx = cb.quantise(x * inv);
            assert_eq!(out[i], idx);
            let d = x as f64 - (cb.dequantise(idx) * s) as f64;
            want_sq += d * d;
        }
        assert_eq!(sq, want_sq);
        assert_eq!(counts.iter().sum::<u64>() as usize, block.len());
    }

    #[test]
    fn tiled_batch_paths_match_scalar_lookup() {
        // qdq_scaled_slice / encode_block now walk the LUT in TILE-sized
        // batches; lengths straddling tile boundaries (and the remainder
        // loop) must agree with the scalar lookup bit-for-bit, including
        // on the adversarial probe set
        let cb = crate::formats::int::int_codebook(4, Variant::Asymmetric);
        assert!(cb.has_lut());
        let mut probes = cb.adversarial_probes();
        for i in -300..300 {
            probes.push(i as f32 * 0.0071);
        }
        for len in [1usize, 31, 32, 33, 64, 95, 97] {
            let base: Vec<f32> =
                probes.iter().cycle().take(len).copied().collect();
            let (inv, s) = (1.0 / 1.3, 1.3f32);
            let mut batch = base.clone();
            cb.qdq_scaled_slice(&mut batch, inv, s);
            let mut idx = vec![0u16; len];
            let mut sq = 0f64;
            let mut counts = vec![0u64; cb.len()];
            cb.encode_block(&base, inv, s, &mut idx, &mut sq, &mut counts);
            for (j, &x) in base.iter().enumerate() {
                let want = cb.quantise(x * inv);
                assert_eq!(idx[j], want, "len={len} j={j} x={x:?}");
                let want_q = cb.dequantise(want) * s;
                assert!(
                    batch[j] == want_q
                        || (batch[j].is_nan() && want_q.is_nan()),
                    "len={len} j={j}: {} vs {want_q}",
                    batch[j]
                );
            }
        }
    }

    #[test]
    fn decode_block_matches_scalar_dequantise() {
        let cb = crate::formats::int::int_codebook(4, Variant::Symmetric);
        let mut scratch = Vec::new();
        for len in [1usize, 8, 64, 129] {
            // len 8 < codebook len 16 exercises the no-table fallback
            let indices: Vec<u16> =
                (0..len).map(|i| (i % cb.len()) as u16).collect();
            let s = 2.7f32;
            let mut out = vec![0f32; len];
            cb.decode_block(&indices, s, &mut out, &mut scratch);
            for (j, &i) in indices.iter().enumerate() {
                assert_eq!(out[j], cb.dequantise(i) * s, "len={len} j={j}");
            }
        }
        // out-of-range index must panic, not read out of bounds
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut out = vec![0f32; 2];
                let mut scratch = Vec::new();
                cb.decode_block(&[0, 999], 1.0, &mut out, &mut scratch);
            }),
        );
        assert!(r.is_err(), "corrupt index must panic");
    }

    #[test]
    fn degenerate_codebooks_fall_back() {
        // single point: no midpoints, no LUT, always index 0
        let one = Codebook::new(vec![0.5]);
        assert!(!one.has_lut());
        assert_eq!(one.quantise(99.0), 0);
        // non-finite codepoints: LUT refused, paths still agree
        let inf = Codebook::new(vec![f32::NEG_INFINITY, 0.0, f32::INFINITY]);
        assert!(!inf.has_lut());
        for &y in &[-1e30f32, 0.0, 1e30, f32::INFINITY] {
            assert_eq!(inf.quantise(y), inf.quantise_ref(y));
        }
    }
}
