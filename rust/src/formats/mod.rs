//! Element formats: every fixed-length quantiser family evaluated in the
//! paper, all reduced to one machinery — a sorted [`Codebook`] of codepoints
//! in normalised space.
//!
//! | module | formats |
//! |---|---|
//! | [`int`] | INT-b, symmetric / asymmetric / signmax variants |
//! | [`float`] | generic EkMm minifloats (E2M1, E3M0, E5M2, ...) |
//! | [`cbrt`] | the paper's √[3]p Normal / Laplace / Student-t for RMS, absmax and signmax scaling |
//! | [`quantile`] | quantile-rule baselines: NF4, SF4, AF4 |
//! | [`lloyd`] | (Fisher-weighted) Lloyd-Max, k-means++ / uniform init |

pub mod cbrt;
pub mod float;
pub mod int;
pub mod lloyd;
pub mod quantile;

/// Symmetry variant of a codepoint distribution (§2.1, fig. 3).
///
/// * `Symmetric` — even count, mirror-symmetric, no exact zero.
/// * `Asymmetric` — contains exact zero; for absmax formats the `+1`
///   endpoint is sacrificed (the INT convention), for RMS formats the
///   largest positive point is dropped.
/// * `Signmax` — assumes the block maximum is at `+1` exactly (signed-max
///   scaling): special codepoints {0, +1} plus a truncated-D′ body.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Symmetric,
    Asymmetric,
    Signmax,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Symmetric => "sym",
            Variant::Asymmetric => "asym",
            Variant::Signmax => "signmax",
        }
    }
}

/// A finite, sorted set of codepoints plus nearest-neighbour machinery.
///
/// `storage_bits` is the bit width of the *stored index* (may exceed
/// log2(len) when a format wastes encodings, e.g. duplicate float zero).
#[derive(Clone, Debug)]
pub struct Codebook {
    points: Vec<f32>,
    mids: Vec<f32>,
    storage_bits: f64,
}

impl Codebook {
    /// Build from codepoints (sorted internally). `storage_bits` defaults
    /// to ⌈log2 n⌉ via [`Codebook::new`].
    pub fn with_bits(mut points: Vec<f32>, storage_bits: f64) -> Codebook {
        assert!(!points.is_empty(), "empty codebook");
        points.sort_by(|a, b| a.total_cmp(b));
        points.dedup();
        let mids = points
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        Codebook {
            points,
            mids,
            storage_bits,
        }
    }

    pub fn new(points: Vec<f32>) -> Codebook {
        let n = points.len();
        let mut cb = Codebook::with_bits(points, 0.0);
        // after dedup the *stored* width still covers the requested points
        cb.storage_bits = (n.max(2) as f64).log2().ceil();
        cb
    }

    /// Exact-entropy storage width, for non-power-of-two codebooks where the
    /// caller models ideal packing (used by some sweeps): log2(len).
    pub fn with_fractional_bits(points: Vec<f32>) -> Codebook {
        let mut cb = Codebook::with_bits(points, 0.0);
        cb.storage_bits = (cb.points.len() as f64).log2();
        cb
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.points.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn points(&self) -> &[f32] {
        &self.points
    }

    /// Bits per element when storing raw indices.
    pub fn storage_bits(&self) -> f64 {
        self.storage_bits
    }

    /// Index of the nearest codepoint (ties to the upper codepoint, matching
    /// `jnp.searchsorted(mids, y, side="right")` in the Pallas kernel).
    #[inline]
    pub fn quantise(&self, y: f32) -> u16 {
        let mids = &self.mids;
        if mids.len() <= 32 {
            // branchless compare-count — the hot path for real formats
            let mut idx = 0u16;
            for &m in mids {
                idx += (y >= m) as u16;
            }
            idx
        } else {
            match mids.binary_search_by(|m| m.total_cmp(&y)) {
                // y == mids[i]: tie goes up
                Ok(i) => (i + 1) as u16,
                Err(i) => i as u16,
            }
        }
    }

    #[inline]
    pub fn dequantise(&self, idx: u16) -> f32 {
        self.points[idx as usize]
    }

    #[inline]
    pub fn qdq(&self, y: f32) -> f32 {
        self.points[self.quantise(y) as usize]
    }

    pub fn quantise_slice(&self, ys: &[f32], out: &mut Vec<u16>) {
        out.clear();
        out.extend(ys.iter().map(|&y| self.quantise(y)));
    }

    pub fn qdq_slice(&self, ys: &mut [f32]) {
        for y in ys {
            *y = self.qdq(*y);
        }
    }

    /// Fused scale→quantise→descale over a slice: `x ← Q(x·inv)·s`.
    /// The hot inner loop of every block qdq; for small codebooks the
    /// midpoints live in a fixed-size local array so the compare-count
    /// loop has static bounds and vectorises.
    pub fn qdq_scaled_slice(&self, xs: &mut [f32], inv: f32, s: f32) {
        let mids = &self.mids;
        let pts = &self.points;
        if mids.len() <= 32 {
            // copy midpoints into a padded local array (pad with +inf so
            // padded lanes never increment the index)
            let mut m = [f32::INFINITY; 32];
            m[..mids.len()].copy_from_slice(mids);
            let k = mids.len();
            // unrolled-by-compiler loop with static upper bound
            for x in xs.iter_mut() {
                let y = *x * inv;
                let mut idx = 0u32;
                for &mid in m[..k].iter() {
                    idx += (y >= mid) as u32;
                }
                // SAFETY: idx <= k < points.len()
                *x = unsafe { *pts.get_unchecked(idx as usize) } * s;
            }
        } else {
            for x in xs.iter_mut() {
                *x = self.qdq(*x * inv) * s;
            }
        }
    }

    /// Largest |codepoint| (the representable range).
    pub fn absmax(&self) -> f32 {
        self.points
            .iter()
            .fold(0f32, |m, &p| m.max(p.abs()))
    }

    /// RMS of the codepoints under nearest-assignment of a distribution is
    /// not stored; this is the plain codepoint RMS (used by moment checks).
    pub fn point_rms(&self) -> f64 {
        crate::util::stats::rms(&self.points)
    }

    /// True iff an exact 0.0 codepoint exists.
    pub fn has_zero(&self) -> bool {
        self.points.iter().any(|&p| p == 0.0)
    }

    /// Snap the codepoint nearest zero to exact 0.0 (count unchanged) —
    /// the minimal "give me an encoding for zero" surgery used by
    /// data-driven formats (Lloyd-Max asymmetric variant).
    pub fn asymmetrise(self) -> Codebook {
        let bits = self.storage_bits;
        let mut pts = self.points;
        let (nearest, _) = pts
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.abs().partial_cmp(&b.abs()).unwrap()
            })
            .unwrap();
        pts[nearest] = 0.0;
        Codebook::with_bits(pts, bits)
    }

    /// Quantisation-bucket populations for a batch of scaled samples
    /// (probability model for entropy coding / fig. 5 histograms).
    pub fn bucket_counts(&self, ys: &[f32]) -> Vec<u64> {
        let mut counts = vec![0u64; self.len()];
        for &y in ys {
            counts[self.quantise(y) as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{check, Gen};

    #[test]
    fn quantise_nearest_small_and_large() {
        // small (compare-count) and large (binary search) paths must agree
        let pts: Vec<f32> = (0..64).map(|i| i as f32 * 0.37 - 11.0).collect();
        let small = Codebook::new(pts[..16].to_vec());
        let large = Codebook::new(pts.clone());
        for i in 0..1000 {
            let y = -15.0 + i as f32 * 0.04;
            let qs = small.qdq(y);
            // nearest by brute force
            let want = small
                .points()
                .iter()
                .copied()
                .min_by(|a, b| {
                    (a - y).abs().partial_cmp(&(b - y).abs()).unwrap()
                })
                .unwrap();
            assert!(
                (qs - want).abs() < 1e-6 || (qs - y).abs() <= (want - y).abs() + 1e-6,
                "y={y} qs={qs} want={want}"
            );
            let ql = large.qdq(y);
            let want_l = large
                .points()
                .iter()
                .copied()
                .min_by(|a, b| {
                    (a - y).abs().partial_cmp(&(b - y).abs()).unwrap()
                })
                .unwrap();
            assert!((ql - want_l).abs() < 1e-6 || (ql - y).abs() <= (want_l - y).abs() + 1e-6);
        }
    }

    #[test]
    fn paths_agree_property() {
        check("codebook-paths-agree", 100, |g: &mut Gen| {
            let n = 33 + g.rng.below(64); // force binary-search path
            let pts = g.f32_vec(n, 2.0);
            let big = Codebook::new(pts.clone());
            // A codebook with the same points but linear search, via chunks
            let ys = g.f32_vec(64, 3.0);
            for &y in &ys {
                let idx = big.quantise(y);
                // check |y - points[idx]| is minimal
                let d = (big.dequantise(idx) - y).abs();
                for &p in big.points() {
                    assert!(
                        d <= (p - y).abs() + 1e-5,
                        "idx {idx} not nearest for y={y}"
                    );
                }
            }
        });
    }

    #[test]
    fn dedup_and_sorting() {
        let cb = Codebook::new(vec![1.0, -1.0, 0.0, 1.0, -1.0]);
        assert_eq!(cb.points(), &[-1.0, 0.0, 1.0]);
        // storage bits reflect the 5 requested encodings
        assert_eq!(cb.storage_bits(), 3.0);
    }

    #[test]
    fn qdq_idempotent_on_codepoints() {
        let cb = Codebook::new(vec![-1.0, -0.25, 0.0, 0.6, 1.0]);
        for &p in cb.points() {
            assert_eq!(cb.qdq(p), p);
        }
    }

    #[test]
    fn asymmetrise_adds_zero() {
        let cb = Codebook::new(vec![-1.0, -0.3, 0.3, 1.0]);
        assert!(!cb.has_zero());
        let a = cb.asymmetrise();
        assert!(a.has_zero());
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn bucket_counts_sum() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0]);
        let ys = [-2.0f32, -0.6, -0.4, 0.1, 0.9, 2.0];
        let counts = cb.bucket_counts(&ys);
        assert_eq!(counts.iter().sum::<u64>() as usize, ys.len());
        assert_eq!(counts, vec![2, 2, 2]);
    }
}
