//! `.owt` tensor container reader/writer — the Rust mirror of
//! `python/compile/owt.py` (see that file for the byte layout).
//!
//! Checkpoints (microllama weights + config), token splits and Fisher
//! snapshots all travel through this format; it is the only data interface
//! between the Python build path and the Rust runtime.

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"OWT1";
const ALIGN: usize = 64;

/// Element type of a stored tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        }
    }

    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

/// One stored tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// Output-channel axis for channel-scaled formats (None for 1-D).
    pub channel_axis: Option<usize>,
    /// Raw little-endian payload, reinterpreted by accessors.
    data: Vec<u8>,
}

impl Tensor {
    pub fn from_f32(name: &str, shape: Vec<usize>, values: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            name: name.to_string(),
            dtype: Dtype::F32,
            shape,
            channel_axis: None,
            data,
        }
    }

    pub fn from_i32(name: &str, shape: Vec<usize>, values: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            name: name.to_string(),
            dtype: Dtype::I32,
            shape,
            channel_axis: None,
            data,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, Dtype::F32, "{}: not f32", self.name);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, Dtype::I32, "{}: not i32", self.name);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Contiguous length of one scale channel: product of dims after the
    /// channel axis... for a (in, out) projection with channel_axis=1 the
    /// natural channel group is a *column*; we store row-major, so channel
    /// scaling groups by trailing stride. For axis = last dim the group
    /// length equals the last-dim size with a transpose view; to keep the
    /// hot path contiguous the channel group length here is the size of the
    /// *last* axis when channel_axis == ndim-1, else the product of
    /// trailing axes after `channel_axis`.
    pub fn channel_group_len(&self) -> usize {
        match self.channel_axis {
            None => self.numel(),
            Some(ax) => {
                if ax + 1 == self.shape.len() {
                    // last axis: a contiguous channel group is one row of
                    // the transpose view — its length is the axis size
                    self.shape[ax]
                } else {
                    self.shape[ax + 1..].iter().product::<usize>().max(1)
                }
            }
        }
    }
}

/// A whole container: ordered tensors + free-form JSON metadata.
#[derive(Clone, Debug)]
pub struct Store {
    pub meta: Json,
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Store {
    pub fn new(meta: Json) -> Store {
        Store {
            meta,
            tensors: Vec::new(),
            index: HashMap::new(),
        }
    }

    pub fn push(&mut self, tensor: Tensor) {
        self.index.insert(tensor.name.clone(), self.tensors.len());
        self.tensors.push(tensor);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn require(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .with_context(|| format!("tensor {name:?} not in store"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    /// Total parameter count across f32 tensors.
    pub fn total_f32_elements(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.dtype == Dtype::F32)
            .map(|t| t.numel())
            .sum()
    }

    // ---- file I/O -----------------------------------------------------------

    pub fn load(path: impl AsRef<Path>) -> Result<Store> {
        let path = path.as_ref();
        let mut raw = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut raw)?;
        if raw.len() < 8 || &raw[..4] != MAGIC {
            bail!("{path:?}: not an OWT1 container");
        }
        let mlen =
            u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]) as usize;
        let manifest = Json::parse(
            std::str::from_utf8(&raw[8..8 + mlen])
                .context("manifest not utf-8")?,
        )
        .context("manifest parse")?;
        let base = 8 + mlen;
        let meta = manifest.get("meta").cloned().unwrap_or(Json::obj());
        let mut store = Store::new(meta);
        for entry in manifest
            .req("tensors")
            .map_err(anyhow::Error::from)?
            .as_arr()
            .context("tensors not an array")?
        {
            let name = entry.req_str("name").map_err(anyhow::Error::from)?;
            let dtype = Dtype::parse(
                entry.req_str("dtype").map_err(anyhow::Error::from)?,
            )?;
            let shape: Vec<usize> = entry
                .req("shape")
                .map_err(anyhow::Error::from)?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|j| j.as_usize().context("bad shape entry"))
                .collect::<Result<_>>()?;
            let offset =
                entry.req_usize("offset").map_err(anyhow::Error::from)?;
            let channel_axis = entry
                .get("channel_axis")
                .and_then(|j| j.as_usize())
                .filter(|_| {
                    !entry
                        .get("channel_axis")
                        .map(|j| j.is_null())
                        .unwrap_or(true)
                });
            let numel: usize = shape.iter().product();
            let nbytes = numel * 4;
            let start = base + offset;
            if start + nbytes > raw.len() {
                bail!("{name}: payload out of range");
            }
            store.push(Tensor {
                name: name.to_string(),
                dtype,
                shape,
                channel_axis,
                data: raw[start..start + nbytes].to_vec(),
            });
        }
        Ok(store)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for t in &self.tensors {
            let mut e = Json::obj()
                .push("name", t.name.as_str())
                .push("dtype", t.dtype.name())
                .push("shape", t.shape.clone())
                .push("offset", offset);
            e = match t.channel_axis {
                Some(ax) => e.push("channel_axis", ax),
                None => e.push("channel_axis", Json::Null),
            };
            entries.push(e);
            offset += t.data.len();
            offset += (ALIGN - offset % ALIGN) % ALIGN;
        }
        let manifest = Json::obj()
            .push("meta", self.meta.clone())
            .push("tensors", Json::Arr(entries))
            .to_string();
        // serialize fully in memory, then replace the target atomically
        // (temp file in the same directory + rename) so a crash mid-save
        // never leaves a torn container behind
        let payload: usize =
            self.tensors.iter().map(|t| t.data.len() + ALIGN).sum();
        let mut buf =
            Vec::with_capacity(8 + manifest.len() + payload);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(manifest.len() as u32).to_le_bytes());
        buf.extend_from_slice(manifest.as_bytes());
        let mut written = 0usize;
        for t in &self.tensors {
            buf.extend_from_slice(&t.data);
            written += t.data.len();
            let pad = (ALIGN - written % ALIGN) % ALIGN;
            buf.extend(std::iter::repeat(0u8).take(pad));
            written += pad;
        }
        crate::util::fsx::atomic_write(path.as_ref(), &buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("owf_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.owt");
        let mut store = Store::new(
            Json::obj().push("kind", "test").push("x", 1.5),
        );
        let mut t =
            Tensor::from_f32("a.weight", vec![3, 4], &(0..12)
                .map(|i| i as f32 * 0.5 - 2.0)
                .collect::<Vec<_>>());
        t.channel_axis = Some(1);
        store.push(t);
        store.push(Tensor::from_i32("tokens", vec![2, 3], &[1, 2, 3, 4, 5, 6]));
        store.save(&path).unwrap();

        let loaded = Store::load(&path).unwrap();
        assert_eq!(loaded.meta.get("kind").unwrap().as_str(), Some("test"));
        assert_eq!(loaded.tensors.len(), 2);
        let a = loaded.require("a.weight").unwrap();
        assert_eq!(a.shape, vec![3, 4]);
        assert_eq!(a.channel_axis, Some(1));
        assert_eq!(a.as_f32()[3], -0.5);
        let tok = loaded.require("tokens").unwrap();
        assert_eq!(tok.as_i32(), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn reads_python_written_artifacts_if_present() {
        // integration hook: when artifacts exist (make artifacts), verify
        // the Python-written container parses and is self-consistent.
        let path = std::path::Path::new("../artifacts/model_s.owt");
        if !path.exists() {
            return;
        }
        let store = Store::load(path).unwrap();
        assert_eq!(
            store.meta.get("kind").and_then(|j| j.as_str()),
            Some("microllama-checkpoint")
        );
        let n = store
            .meta
            .get("config")
            .and_then(|c| c.get("n_params"))
            .and_then(|j| j.as_usize())
            .unwrap();
        assert_eq!(store.total_f32_elements(), n);
        let emb = store.require("embed_tokens").unwrap();
        assert_eq!(emb.shape.len(), 2);
        assert_eq!(emb.channel_axis, Some(1));
        // weights should be finite and non-trivial
        let w = emb.as_f32();
        assert!(w.iter().all(|x| x.is_finite()));
        assert!(crate::util::stats::rms(&w) > 1e-4);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("owf_test_store2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.owt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(Store::load(&path).is_err());
    }

    #[test]
    fn channel_group_len() {
        let mut t = Tensor::from_f32("w", vec![4, 6], &vec![0.0; 24]);
        // last axis (ax == ndim-1): the group is a column of the row-major
        // layout, contiguous only in the transpose view — its length is
        // the axis size, per the doc comment
        t.channel_axis = Some(1);
        assert_eq!(t.channel_group_len(), 6);
        t.channel_axis = Some(0);
        assert_eq!(t.channel_group_len(), 6);
        t.channel_axis = None;
        assert_eq!(t.channel_group_len(), 24);
        // 3-D: interior axis takes the trailing product, last axis its size
        let mut t3 = Tensor::from_f32("w3", vec![2, 3, 5], &vec![0.0; 30]);
        t3.channel_axis = Some(1);
        assert_eq!(t3.channel_group_len(), 5);
        t3.channel_axis = Some(2);
        assert_eq!(t3.channel_group_len(), 5);
        t3.channel_axis = Some(0);
        assert_eq!(t3.channel_group_len(), 15);
        // 1-D with channel axis 0 (the ax == ndim-1 degenerate case)
        let mut t1 = Tensor::from_f32("v", vec![7], &vec![0.0; 7]);
        t1.channel_axis = Some(0);
        assert_eq!(t1.channel_group_len(), 7);
    }
}
