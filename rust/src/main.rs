//! `owf` — the Optimal-Weight-Formats CLI (L3 leader entrypoint).
//!
//! Commands (arg parsing is hand-rolled; clap is unavailable offline):
//!
//! ```text
//! owf list                          list AOT artifacts + checkpoints
//! owf report <id|sim|llm|all> [--size s|m|l] [--samples N]
//!                                   [--eval-seqs N] [--qat-steps N]
//!                                   [--out results.jsonl]
//! owf quantise --spec <scheme> [--size m]   one direct-cast point
//! owf fisher --size m [--batches N]         (re)estimate + save Fisher
//! owf schemes                       print the scheme grammar + examples
//! ```

use anyhow::{Context, Result};

use owf::coordinator::config::Scheme;
use owf::coordinator::ResultSink;
use owf::eval::{self, RunOpts};
use owf::fisher::FisherEstimate;
use owf::runtime::model::{Checkpoint, TokenSplit};
use owf::runtime::Runtime;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            let value = if it
                .peek()
                .map(|v| !v.starts_with("--"))
                .unwrap_or(false)
            {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    Args { positional, flags }
}

fn opts_from(args: &Args) -> Result<RunOpts> {
    let mut opts = RunOpts::default();
    if let Some(v) = args.flags.get("samples") {
        opts.samples = v.parse().context("--samples")?;
    }
    if let Some(v) = args.flags.get("eval-seqs") {
        opts.eval_seqs = v.parse().context("--eval-seqs")?;
    }
    if let Some(v) = args.flags.get("qat-steps") {
        opts.qat_steps = v.parse().context("--qat-steps")?;
    }
    if let Some(v) = args.flags.get("size") {
        opts.size = v.clone();
    }
    Ok(opts)
}

fn main() -> Result<()> {
    let args = parse_args();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "list" => cmd_list(),
        "report" => cmd_report(&args),
        "quantise" | "quantize" => cmd_quantise(&args),
        "fisher" => cmd_fisher(&args),
        "schemes" => {
            println!("{SCHEME_HELP}");
            Ok(())
        }
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn cmd_list() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts:");
    for name in rt.artifact_names() {
        let info = rt.artifact(name)?;
        println!(
            "  {name:<28} {} inputs, {} outputs",
            info.inputs.len(),
            info.outputs.len()
        );
    }
    for size in ["s", "m", "l"] {
        if let Ok(ck) = Checkpoint::load(&rt, size) {
            let toks = TokenSplit::load(&rt, size, "eval")?;
            println!(
                "checkpoint {size}: {} params, {} tensors, eval {}x{}",
                ck.config.n_params,
                ck.store.tensors.len(),
                toks.n_seq,
                toks.seq_len
            );
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("usage: owf report <id|sim|llm|all>")?;
    let opts = opts_from(args)?;
    let reports = eval::run(id, &opts)?;
    if let Some(out) = args.flags.get("out") {
        let sink = ResultSink::open(out)?;
        for rep in &reports {
            for row in rep.to_json_rows() {
                sink.append(&row)?;
            }
        }
        println!("[wrote {} reports to {out}]", reports.len());
    }
    Ok(())
}

fn cmd_quantise(args: &Args) -> Result<()> {
    let spec = args.flags.get("spec").context("--spec <scheme> required")?;
    let opts = opts_from(args)?;
    let size = opts.size.clone();
    let scheme = Scheme::parse(spec)?;
    let mut env = eval::llm::Env::open(opts)?;
    let p = env.direct_cast(&size, &scheme, None, false)?;
    println!(
        "{spec} on microllama-{size}: b={:.3} KL={:.5}±{:.5} ΔCE={:.5} R={:.4}",
        p.bits,
        p.kl.mean,
        2.0 * p.kl.sem,
        p.delta_ce,
        p.r
    );
    Ok(())
}

fn cmd_fisher(args: &Args) -> Result<()> {
    let opts = opts_from(args)?;
    let batches: usize = args
        .flags
        .get("batches")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let rt = Runtime::open_default()?;
    let size = &opts.size;
    let ck = Checkpoint::load(&rt, size)?;
    let toks = TokenSplit::load(&rt, size, "fisher")?;
    let est = FisherEstimate::estimate(
        &rt,
        size,
        &ck.params(),
        &toks,
        batches,
        1234,
        args.flags.contains_key("empirical"),
    )?;
    let path = rt.data_path(&format!("fisher_{size}.owt"));
    est.save(&path)?;
    println!(
        "fisher({size}): {} sequences -> {:?}",
        est.sequences, path
    );
    for t in est.tensor_summaries() {
        println!("  {:<40} mean {:.3e}", t.name, t.mean);
    }
    Ok(())
}

const HELP: &str = "owf — Optimal Weight Formats (paper reproduction)

USAGE:
  owf list                              show artifacts & checkpoints
  owf report <id|sim|llm|all> [opts]    reproduce paper figures/tables
  owf quantise --spec <scheme> [opts]   one direct-cast measurement
  owf fisher [--size m] [--batches N]   estimate the Fisher diagonal
  owf schemes                           scheme grammar reference

OPTIONS:
  --size s|m|l      model for single-model reports   (default m)
  --samples N       simulated-data sample count      (default 2^20)
  --eval-seqs N     sequences per KL evaluation      (default 24)
  --qat-steps N     QAT training steps               (default 60)
  --out FILE        append report rows as JSONL
";

const SCHEME_HELP: &str = "scheme grammar:
  <element>@<bits>:<granularity>-<statistic>[:<flags>]

elements:     int | e<K>m<M> | nf | sf<nu> | af4 | lloyd |
              cbrt-normal | cbrt-laplace | cbrt-t<nu> | grid
granularity:  tensor | channel | block<B>
statistic:    rms | absmax | signmax
flags:        sym | asym | sparse<frac> | rot | compress |
              mult<x> | search | fisher

examples:
  cbrt-t7@4:block128-absmax          paper's best uncompressed format
  grid@3.5:tensor-rms:compress       entropy-coded uniform grid
  int@3:channel-absmax:sparse0.001   SpQR-style dense+sparse
  lloyd@4:tensor-rms:fisher          SqueezeLLM-style weighted k-means
";
