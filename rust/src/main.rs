//! `owf` — the Optimal-Weight-Formats CLI (L3 leader entrypoint).
//!
//! Commands (arg parsing is hand-rolled; clap is unavailable offline):
//!
//! ```text
//! owf list                          list AOT artifacts + checkpoints
//! owf report <id|sim|llm|all> [--size s|m|l] [--samples N]
//!                                   [--eval-seqs N] [--qat-steps N]
//!                                   [--out results.jsonl]
//! owf sweep <grid> [--data sim|llm] [--seeds N] [--out FILE] [--resume]
//!                                   parallel resumable scheme-grid sweep
//! owf quantise --spec <scheme> [--size m]   one direct-cast point
//! owf fisher --size m [--batches N]         (re)estimate + save Fisher
//! owf schemes                       print the scheme + grid grammar
//! ```

use std::path::PathBuf;

use anyhow::{Context, Result};

use owf::coordinator::config::Scheme;
use owf::coordinator::{run_sweep, ResultSink, SweepData, SweepOpts};
use owf::eval::{self, RunOpts};
use owf::fisher::FisherEstimate;
use owf::runtime::model::{Checkpoint, TokenSplit};
use owf::runtime::Runtime;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

/// Flags that never take a value (so `owf sweep --resume <grid>` does not
/// swallow the grid as the flag's value).
const BOOL_FLAGS: &[&str] = &["resume", "empirical"];

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            let value = if !BOOL_FLAGS.contains(&key)
                && it
                    .peek()
                    .map(|v| !v.starts_with("--"))
                    .unwrap_or(false)
            {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    Args { positional, flags }
}

fn opts_from(args: &Args) -> Result<RunOpts> {
    let mut opts = RunOpts::default();
    if let Some(v) = args.flags.get("samples") {
        opts.samples = v.parse().context("--samples")?;
    }
    if let Some(v) = args.flags.get("eval-seqs") {
        opts.eval_seqs = v.parse().context("--eval-seqs")?;
    }
    if let Some(v) = args.flags.get("qat-steps") {
        opts.qat_steps = v.parse().context("--qat-steps")?;
    }
    if let Some(v) = args.flags.get("size") {
        opts.size = v.clone();
    }
    Ok(opts)
}

fn main() -> Result<()> {
    let args = parse_args();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "list" => cmd_list(),
        "report" => cmd_report(&args),
        "sweep" => cmd_sweep(&args),
        "quantise" | "quantize" => cmd_quantise(&args),
        "fisher" => cmd_fisher(&args),
        "schemes" => {
            println!("{SCHEME_HELP}");
            Ok(())
        }
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn cmd_list() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts:");
    for name in rt.artifact_names() {
        let info = rt.artifact(name)?;
        println!(
            "  {name:<28} {} inputs, {} outputs",
            info.inputs.len(),
            info.outputs.len()
        );
    }
    for size in ["s", "m", "l"] {
        if let Ok(ck) = Checkpoint::load(&rt, size) {
            let toks = TokenSplit::load(&rt, size, "eval")?;
            println!(
                "checkpoint {size}: {} params, {} tensors, eval {}x{}",
                ck.config.n_params,
                ck.store.tensors.len(),
                toks.n_seq,
                toks.seq_len
            );
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("usage: owf report <id|sim|llm|all>")?;
    let opts = opts_from(args)?;
    let reports = eval::run(id, &opts)?;
    if let Some(out) = args.flags.get("out") {
        let sink = ResultSink::open(out)?;
        for rep in &reports {
            for row in rep.to_json_rows() {
                sink.append(&row)?;
            }
        }
        println!("[wrote {} reports to {out}]", reports.len());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let grid = args.positional.get(1).context(
        "usage: owf sweep <grid> [--data sim|llm] [--size s|m|l] \
         [--seeds N] [--samples N] [--out FILE] [--resume]",
    )?;
    let opts = opts_from(args)?;
    let data = match args.flags.get("data").map(|s| s.as_str()) {
        None | Some("sim") => SweepData::Sim,
        Some("llm") => SweepData::Llm,
        Some(other) => {
            anyhow::bail!("--data must be sim or llm, got {other:?}")
        }
    };
    let defaults = SweepOpts::default();
    let sweep_opts = SweepOpts {
        data,
        out: args
            .flags
            .get("out")
            .map(PathBuf::from)
            .unwrap_or(defaults.out),
        resume: args.flags.contains_key("resume"),
        seeds: args
            .flags
            .get("seeds")
            .map(|v| v.parse::<u64>())
            .transpose()
            .context("--seeds")?
            .unwrap_or(defaults.seeds),
        // sweeps default to 2^16 samples/point (not the report default of
        // 2^20 — a grid multiplies the cost by its point count)
        samples: args
            .flags
            .get("samples")
            .map(|v| v.parse::<usize>())
            .transpose()
            .context("--samples")?
            .unwrap_or(defaults.samples),
        size: opts.size.clone(),
        eval_seqs: opts.eval_seqs,
    };
    let t0 = std::time::Instant::now();
    let stats = run_sweep(grid, &sweep_opts)?;
    println!(
        "sweep: {} points — {} skipped (resume), {} ran, {} failed — \
         {:.1}s on {} workers -> {:?}",
        stats.planned,
        stats.skipped,
        stats.ran,
        stats.failed,
        t0.elapsed().as_secs_f64(),
        owf::util::pool::num_threads(),
        sweep_opts.out,
    );
    if stats.failed > 0 {
        anyhow::bail!(
            "{} sweep points failed (rows with ok:false in {:?})",
            stats.failed,
            sweep_opts.out
        );
    }
    Ok(())
}

fn cmd_quantise(args: &Args) -> Result<()> {
    let spec = args.flags.get("spec").context("--spec <scheme> required")?;
    let opts = opts_from(args)?;
    let size = opts.size.clone();
    let scheme = Scheme::parse(spec)?;
    let mut env = eval::llm::Env::open(opts)?;
    let p = env.direct_cast(&size, &scheme, None, false)?;
    println!(
        "{spec} on microllama-{size}: b={:.3} KL={:.5}±{:.5} ΔCE={:.5} R={:.4}",
        p.bits,
        p.kl.mean,
        2.0 * p.kl.sem,
        p.delta_ce,
        p.r
    );
    Ok(())
}

fn cmd_fisher(args: &Args) -> Result<()> {
    let opts = opts_from(args)?;
    let batches: usize = args
        .flags
        .get("batches")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let rt = Runtime::open_default()?;
    let size = &opts.size;
    let ck = Checkpoint::load(&rt, size)?;
    let toks = TokenSplit::load(&rt, size, "fisher")?;
    let est = FisherEstimate::estimate(
        &rt,
        size,
        &ck.params(),
        &toks,
        batches,
        1234,
        args.flags.contains_key("empirical"),
    )?;
    let path = rt.data_path(&format!("fisher_{size}.owt"));
    est.save(&path)?;
    println!(
        "fisher({size}): {} sequences -> {:?}",
        est.sequences, path
    );
    for t in est.tensor_summaries() {
        println!("  {:<40} mean {:.3e}", t.name, t.mean);
    }
    Ok(())
}

const HELP: &str = "owf — Optimal Weight Formats (paper reproduction)

USAGE:
  owf list                              show artifacts & checkpoints
  owf report <id|sim|llm|all> [opts]    reproduce paper figures/tables
  owf sweep <grid> [opts]               parallel resumable scheme sweep
  owf quantise --spec <scheme> [opts]   one direct-cast measurement
  owf fisher [--size m] [--batches N]   estimate the Fisher diagonal
  owf schemes                           scheme + grid grammar reference

OPTIONS:
  --size s|m|l      model for single-model reports   (default m)
  --samples N       simulated-data sample count      (default 2^20)
  --eval-seqs N     sequences per KL evaluation      (default 24)
  --qat-steps N     QAT training steps               (default 60)
  --out FILE        append report rows as JSONL

SWEEP OPTIONS:
  --data sim|llm    evaluate on iid draws (R) or checkpoints (KL)
                    (default sim; llm needs `make artifacts`)
  --samples N       samples per sim point             (sweep default 2^16)
  --seeds N         seeds per grid point, sim only    (default 1)
  --out FILE        JSONL output / resume state       (default sweep.jsonl)
  --resume          skip points already completed in --out (keyed by
                    scheme, size, seed and the run parameters)
  OWF_THREADS       worker count for CPU points       (default all cores)
";

const SCHEME_HELP: &str = "scheme grammar:
  <element>@<bits>:<granularity>-<statistic>[:<flags>]

elements:     int | e<K>m<M> | nf | sf<nu> | af4 | lloyd |
              cbrt-normal | cbrt-laplace | cbrt-t<nu> | grid
granularity:  tensor | channel | block<B>
statistic:    rms | absmax | signmax
flags:        sym | asym | sparse<frac> | rot | compress |
              mult<x> | search | fisher

examples:
  cbrt-t7@4:block128-absmax          paper's best uncompressed format
  grid@3.5:tensor-rms:compress       entropy-coded uniform grid
  int@3:channel-absmax:sparse0.001   SpQR-style dense+sparse
  lloyd@4:tensor-rms:fisher          SqueezeLLM-style weighted k-means

sweep grids (owf sweep): any {...} group in a spec expands —
  {a,b,c}   comma alternation        {lo..hi}  inclusive integer range
multiple groups form the cartesian product; ';' joins several grids;
duplicates are dropped.

examples:
  cbrt-t7@{2..8}:block{32,64,128}-absmax            21 points
  {int,nf,cbrt-t5}@4:block64-absmax ; grid@{3..5}:tensor-rms:compress
";
