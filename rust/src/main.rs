//! `owf` — the Optimal-Weight-Formats CLI (L3 leader entrypoint).
//!
//! Commands (arg parsing is hand-rolled; clap is unavailable offline):
//!
//! ```text
//! owf list                          list AOT artifacts + checkpoints
//! owf report <id|sim|llm|all> [--size s|m|l] [--samples N]
//!                                   [--eval-seqs N] [--qat-steps N]
//!                                   [--out results.jsonl]
//! owf sweep <grid> [--data sim|llm] [--seeds N] [--out FILE] [--resume]
//!                                   parallel resumable scheme-grid sweep
//! owf quantise --spec <scheme> [--size m]   one direct-cast point
//! owf quantise --from <file.owq>    evaluate a packed artifact's KL
//! owf pack --spec <scheme> --out F  quantise + entropy-code to an OWQ1
//!                                   container (checkpoint or --sim data);
//!                                   --alloc fractional --bits B mixes
//!                                   schemes per block to hit fractional
//!                                   budgets
//! owf inspect <file.owq> [--verify] print a container's manifest; verify
//!                                   checksums + bit-exactness vs the
//!                                   in-memory pipeline
//! owf serve-bench <file.owq>        concurrent decode benchmark with
//!                                   cache-hit stats; optional fault
//!                                   injection (--fault-eio-rate,
//!                                   --fault-flips), bounded admission
//!                                   (--max-decodes, --queue-depth,
//!                                   --deadline-ms) and an open-loop
//!                                   Zipf saturation sweep (--rates)
//! owf fsck <file.owq>               eagerly verify every checksum and
//!                                   decode every tensor; per-tensor
//!                                   verdict table, nonzero exit on damage
//! owf fault-inject <in> --out <out>  write a deliberately damaged copy
//!                                   (bit flip per section / manifest /
//!                                   header, or truncation) for drills
//! owf fisher --size m [--batches N]         (re)estimate + save Fisher
//! owf schemes                       print the scheme + grid grammar
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use owf::artifact::writer::{pack_store, AllocMode, PackOptions};
use owf::artifact::{Artifact, ArtifactError, Codec, Deadline};
use owf::artifact::server::ArtifactServer;
use owf::coordinator::config::Scheme;
use owf::coordinator::{run_sweep, Report, ResultSink, SweepData, SweepOpts};
use owf::dist::{Dist, Family};
use owf::eval::pipeline::{qdq_tensor, qdq_tensor_mixed};
use owf::eval::{self, RunOpts};
use owf::fisher::FisherEstimate;
use owf::runtime::model::{Checkpoint, TokenSplit};
use owf::runtime::Runtime;
use owf::tensorstore::{Store, Tensor};
use owf::util::faultfs::{
    flip_bit_in_file, write_torn_copy, ByteSource, FaultFs,
};
use owf::util::json::Json;
use owf::util::rng::{Rng, Zipf};

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

/// Flags that never take a value (so `owf sweep --resume <grid>` does not
/// swallow the grid as the flag's value).
const BOOL_FLAGS: &[&str] = &["resume", "empirical", "verify"];

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            let value = if !BOOL_FLAGS.contains(&key)
                && it
                    .peek()
                    .map(|v| !v.starts_with("--"))
                    .unwrap_or(false)
            {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    Args { positional, flags }
}

fn opts_from(args: &Args) -> Result<RunOpts> {
    let mut opts = RunOpts::default();
    if let Some(v) = args.flags.get("samples") {
        opts.samples = v.parse().context("--samples")?;
    }
    if let Some(v) = args.flags.get("eval-seqs") {
        opts.eval_seqs = v.parse().context("--eval-seqs")?;
    }
    if let Some(v) = args.flags.get("qat-steps") {
        opts.qat_steps = v.parse().context("--qat-steps")?;
    }
    if let Some(v) = args.flags.get("size") {
        opts.size = v.clone();
    }
    Ok(opts)
}

fn main() -> Result<()> {
    let args = parse_args();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "list" => cmd_list(),
        "report" => cmd_report(&args),
        "sweep" => cmd_sweep(&args),
        "quantise" | "quantize" => cmd_quantise(&args),
        "pack" => cmd_pack(&args),
        "inspect" => cmd_inspect(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "fsck" => cmd_fsck(&args),
        "fault-inject" => cmd_fault_inject(&args),
        "fisher" => cmd_fisher(&args),
        "isa" => cmd_isa(),
        "schemes" => {
            println!("{SCHEME_HELP}");
            Ok(())
        }
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn cmd_list() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts:");
    for name in rt.artifact_names() {
        let info = rt.artifact(name)?;
        println!(
            "  {name:<28} {} inputs, {} outputs",
            info.inputs.len(),
            info.outputs.len()
        );
    }
    for size in ["s", "m", "l"] {
        if let Ok(ck) = Checkpoint::load(&rt, size) {
            let toks = TokenSplit::load(&rt, size, "eval")?;
            println!(
                "checkpoint {size}: {} params, {} tensors, eval {}x{}",
                ck.config.n_params,
                ck.store.tensors.len(),
                toks.n_seq,
                toks.seq_len
            );
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("usage: owf report <id|sim|llm|all>")?;
    let opts = opts_from(args)?;
    let reports = eval::run(id, &opts)?;
    if let Some(out) = args.flags.get("out") {
        let sink = ResultSink::open(out)?;
        for rep in &reports {
            for row in rep.to_json_rows() {
                sink.append(&row)?;
            }
        }
        println!("[wrote {} reports to {out}]", reports.len());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let grid = args.positional.get(1).context(
        "usage: owf sweep <grid> [--data sim|llm] [--size s|m|l] \
         [--seeds N] [--samples N] [--out FILE] [--resume]",
    )?;
    let opts = opts_from(args)?;
    let data = match args.flags.get("data").map(|s| s.as_str()) {
        None | Some("sim") => SweepData::Sim,
        Some("llm") => SweepData::Llm,
        Some(other) => {
            anyhow::bail!("--data must be sim or llm, got {other:?}")
        }
    };
    let defaults = SweepOpts::default();
    let sweep_opts = SweepOpts {
        data,
        out: args
            .flags
            .get("out")
            .map(PathBuf::from)
            .unwrap_or(defaults.out),
        resume: args.flags.contains_key("resume"),
        seeds: args
            .flags
            .get("seeds")
            .map(|v| v.parse::<u64>())
            .transpose()
            .context("--seeds")?
            .unwrap_or(defaults.seeds),
        // sweeps default to 2^16 samples/point (not the report default of
        // 2^20 — a grid multiplies the cost by its point count)
        samples: args
            .flags
            .get("samples")
            .map(|v| v.parse::<usize>())
            .transpose()
            .context("--samples")?
            .unwrap_or(defaults.samples),
        size: opts.size.clone(),
        eval_seqs: opts.eval_seqs,
    };
    let t0 = std::time::Instant::now();
    let stats = run_sweep(grid, &sweep_opts)?;
    println!(
        "sweep: {} points — {} skipped (resume), {} ran, {} failed — \
         {:.1}s on {} workers -> {:?}",
        stats.planned,
        stats.skipped,
        stats.ran,
        stats.failed,
        t0.elapsed().as_secs_f64(),
        owf::util::pool::num_threads(),
        sweep_opts.out,
    );
    if stats.failed > 0 {
        anyhow::bail!(
            "{} sweep points failed (rows with ok:false in {:?})",
            stats.failed,
            sweep_opts.out
        );
    }
    Ok(())
}

fn cmd_quantise(args: &Args) -> Result<()> {
    let opts = opts_from(args)?;
    let size = opts.size.clone();
    // packed-artifact evaluation: serve the quantised parameters out of an
    // OWQ1 container and score them exactly like an in-memory direct cast
    if let Some(from) = args.flags.get("from") {
        let art = Artifact::open(from)?;
        // KL evaluation needs the model the artifact was packed from:
        // default the size from the manifest (an explicit --size still
        // wins), and refuse sources that have no checkpoint to run
        let meta_source =
            art.meta.get("source").and_then(|j| j.as_str());
        if meta_source.is_some() && meta_source != Some("checkpoint") {
            bail!(
                "{from}: packed from source {:?} — KL evaluation needs \
                 a checkpoint-sourced artifact (owf pack --size ...)",
                meta_source.unwrap()
            );
        }
        let size = if args.flags.contains_key("size") {
            size
        } else {
            art.meta
                .get("size")
                .and_then(|j| j.as_str())
                .map(|s| s.to_string())
                .unwrap_or(size)
        };
        let total: usize = art.total_elements();
        let bits: f64 = art
            .tensors
            .iter()
            .map(|r| r.bits * r.n as f64)
            .sum::<f64>()
            / total.max(1) as f64;
        let server = ArtifactServer::new(art, 0);
        let params = server.params()?;
        let mut env = eval::llm::Env::open(opts)?;
        let (kl, delta_ce) = env.evaluate(&size, &params)?;
        println!(
            "packed {from} on microllama-{size}: b={bits:.3} \
             KL={:.5}±{:.5} ΔCE={:.5}",
            kl.mean,
            2.0 * kl.sem,
            delta_ce,
        );
        return Ok(());
    }
    let spec = args.flags.get("spec").context(
        "--spec <scheme> (or --from <file.owq>) required",
    )?;
    let scheme = Scheme::parse(spec)?;
    let mut env = eval::llm::Env::open(opts)?;
    let p = env.direct_cast(&size, &scheme, None, false)?;
    println!(
        "{spec} on microllama-{size}: b={:.3} KL={:.5}±{:.5} ΔCE={:.5} R={:.4}",
        p.bits,
        p.kl.mean,
        2.0 * p.kl.sem,
        p.delta_ce,
        p.r
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// OWQ1 artifact commands
// ---------------------------------------------------------------------------

/// Parse a `--dist` spec: `normal`, `laplace`, or `t<nu>` (default t5).
fn parse_sim_dist(s: &str) -> Result<Dist> {
    if s == "normal" {
        return Ok(Dist::standard(Family::Normal, 0.0));
    }
    if s == "laplace" {
        return Ok(Dist::standard(Family::Laplace, 0.0));
    }
    if let Some(nu) = s.strip_prefix('t') {
        let nu: f64 = nu.parse().context("bad t<nu> dist")?;
        return Ok(Dist::standard(Family::StudentT, nu));
    }
    bail!("unknown dist {s:?} (normal|laplace|t<nu>)")
}

/// Deterministically rebuild the synthetic source tensors for a
/// `--sim`-packed artifact: shapes like `64x96,4096`, one fork of the
/// seeded RNG per tensor, `channel_axis = 1` for 2-D tensors (matching
/// checkpoint weight conventions).  `owf inspect --verify` re-runs this to
/// prove the packed bytes decode bit-identically to the in-memory
/// pipeline over the *same* data.
fn sim_store(shapes: &str, dist: &str, seed: u64) -> Result<Store> {
    let d = parse_sim_dist(dist)?;
    let mut store = Store::new(
        Json::obj()
            .push("kind", "owq-sim-source")
            // decimal string: JSON numbers are f64 and would corrupt
            // seeds >= 2^53
            .push("seed", format!("{seed}"))
            .push("shapes", shapes)
            .push("dist", dist),
    );
    let mut rng = Rng::new(seed);
    for (i, spec) in shapes
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .enumerate()
    {
        let dims: Vec<usize> = spec
            .split('x')
            .map(|p| p.trim().parse().context("bad shape"))
            .collect::<Result<_>>()
            .with_context(|| format!("--sim shape {spec:?}"))?;
        let n: usize = dims.iter().product();
        if n == 0 {
            bail!("--sim shape {spec:?} has zero elements");
        }
        let mut fork = rng.fork(i as u64);
        let data = d.sample_vec(&mut fork, n);
        let mut t = Tensor::from_f32(&format!("sim.{i}"), dims, &data);
        if t.shape.len() == 2 {
            t.channel_axis = Some(1);
        }
        store.push(t);
    }
    if store.tensors.is_empty() {
        bail!("--sim expands to zero tensors");
    }
    Ok(store)
}

/// Rebuild the source tensors an artifact was packed from (sim
/// regeneration or checkpoint load), per its manifest meta.
fn source_store(art: &Artifact) -> Result<Store> {
    let meta = &art.meta;
    match meta.get("source").and_then(|j| j.as_str()) {
        Some("sim") => {
            let shapes = meta
                .get("shapes")
                .and_then(|j| j.as_str())
                .context("sim artifact missing shapes meta")?;
            let dist = meta
                .get("dist")
                .and_then(|j| j.as_str())
                .unwrap_or("t5");
            let seed: u64 = meta
                .get("seed")
                .and_then(|j| j.as_str())
                .context("sim artifact missing seed meta")?
                .parse()
                .context("sim artifact seed meta not a u64")?;
            sim_store(shapes, dist, seed)
        }
        Some("checkpoint") => {
            let size = meta
                .get("size")
                .and_then(|j| j.as_str())
                .context("checkpoint artifact missing size meta")?;
            let rt = Runtime::open_default()?;
            Ok(Checkpoint::load(&rt, size)?.store)
        }
        other => bail!(
            "cannot rebuild source for meta.source = {other:?} \
             (verification needs a sim or checkpoint source)"
        ),
    }
}

/// The acceptance gate: every tensor's packed decode must be bit-identical
/// to the in-memory pipeline's reconstruction over the regenerated source
/// data, and the stored sq-err/bits must match the pipeline's to the last
/// f64 bit.
fn verify_artifact(art: &Artifact) -> Result<()> {
    art.verify_all().context("section checksums")?;
    let store = source_store(art)?;
    for (i, rec) in art.tensors.iter().enumerate() {
        let t = store.require(&rec.name)?;
        if t.shape != rec.shape {
            bail!(
                "{}: source shape {:?} != packed {:?}",
                rec.name,
                t.shape,
                rec.shape
            );
        }
        let data = t.as_f32();
        let scheme = Scheme::parse(&rec.spec)?;
        // rotated tensors replay under the recorded per-tensor seed; the
        // seed is irrelevant to every other scheme (identity rotation)
        let seed = rec.rot_seed.unwrap_or(0);
        // mixed (v3) tensors replay through the mixed pipeline under the
        // recorded part specs + block assignment — same bit-exactness bar
        let reference = if let Some(mix) = &rec.mix {
            let specs = mix
                .specs
                .iter()
                .map(|s| Scheme::parse(s))
                .collect::<Result<Vec<_>>>()?;
            let assign =
                art.block_assignment(i)?.with_context(|| {
                    format!(
                        "{}: mixed tensor without block_schemes",
                        rec.name
                    )
                })?;
            qdq_tensor_mixed(
                &specs,
                &assign,
                &data,
                &t.shape,
                t.channel_axis,
                &[],
                seed,
            )?
        } else {
            qdq_tensor(
                &scheme,
                &data,
                &t.shape,
                t.channel_axis,
                &[],
                seed,
            )?
        };
        let decoded = art.decode_tensor(i)?;
        for (j, (&a, &b)) in
            decoded.iter().zip(&reference.recon).enumerate()
        {
            if a.to_bits() != b.to_bits() {
                bail!(
                    "{}: packed decode diverges from the in-memory \
                     pipeline at element {j}: {a:?} vs {b:?}",
                    rec.name
                );
            }
        }
        if rec.sq_err.to_bits() != reference.sq_err.to_bits() {
            bail!(
                "{}: stored sq-err {} != pipeline {}",
                rec.name,
                rec.sq_err,
                reference.sq_err
            );
        }
        if rec.bits.to_bits() != reference.bits.to_bits() {
            bail!(
                "{}: stored bits {} != pipeline {}",
                rec.name,
                rec.bits,
                reference.bits
            );
        }
    }
    println!(
        "verify: {} tensors bit-identical to the in-memory pipeline \
         (recon, sq-err, bits)",
        art.tensors.len()
    );
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let spec = args
        .flags
        .get("spec")
        .context("--spec <scheme> required")?
        .clone();
    let out = args
        .flags
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("packed.owq"));
    let codec = args
        .flags
        .get("codec")
        .map(|s| Codec::parse(s))
        .transpose()?
        .unwrap_or(Codec::Huffman);
    // default K follows the active ISA's vector width (8 on AVX2, else
    // 4) — the lane count rides in the container header, so any choice
    // decodes anywhere; matching the width lets the SIMD rANS rounds
    // engage on the packing host's own decode path
    let lanes: usize = args
        .flags
        .get("lanes")
        .map(|v| v.parse())
        .transpose()
        .context("--lanes")?
        .unwrap_or_else(owf::util::simd::preferred_lanes);
    let alloc = args
        .flags
        .get("alloc")
        .map(|s| AllocMode::parse(s))
        .transpose()?
        .unwrap_or(AllocMode::Flat);
    // fractional budget target, e.g. `--bits 3.3`; the other alloc
    // modes target the spec's own width and ignore this
    let target_bits: Option<f64> = args
        .flags
        .get("bits")
        .map(|v| v.parse())
        .transpose()
        .context("--bits")?;

    let (store, fisher_mean, meta) = if let Some(shapes) =
        args.flags.get("sim")
    {
        let seed: u64 = args
            .flags
            .get("seed")
            .map(|v| v.parse())
            .transpose()
            .context("--seed")?
            .unwrap_or(1234);
        let dist = args
            .flags
            .get("dist")
            .cloned()
            .unwrap_or_else(|| "t5".to_string());
        let store = sim_store(shapes, &dist, seed)?;
        let meta = Json::obj()
            .push("source", "sim")
            .push("seed", format!("{seed}"))
            .push("shapes", shapes.as_str())
            .push("dist", dist);
        (store, std::collections::HashMap::new(), meta)
    } else {
        let opts = opts_from(args)?;
        let size = opts.size.clone();
        let rt = Runtime::open_default()?;
        let ck = Checkpoint::load(&rt, &size)?;
        // Fisher means feed the variable allocator when a saved estimate
        // exists (owf fisher); otherwise allocation falls back to pure RMS
        let fisher_path = rt.data_path(&format!("fisher_{size}.owt"));
        let fisher_mean = if fisher_path.exists() {
            FisherEstimate::load(&fisher_path)?.tensor_means()
        } else {
            if alloc == AllocMode::Variable {
                println!(
                    "[no {fisher_path:?}; variable allocation will use \
                     RMS only — run `owf fisher --size {size}` first]"
                );
            }
            std::collections::HashMap::new()
        };
        let meta = Json::obj()
            .push("source", "checkpoint")
            .push("size", size.as_str());
        (ck.store, fisher_mean, meta)
    };

    let opts = PackOptions {
        spec,
        alloc,
        codec,
        lanes,
        target_bits,
        meta,
    };
    let t0 = std::time::Instant::now();
    let summary = pack_store(&store, &fisher_mean, &opts, &out)?;
    if !summary.skipped.is_empty() {
        println!(
            "[warning: skipped {} non-f32/empty tensor(s): {} — the \
             container serves fewer tensors than its source]",
            summary.skipped.len(),
            summary.skipped.join(", "),
        );
    }
    println!(
        "pack: {} tensors, {} elements -> {:?} ({} bytes) in {:.2}s",
        summary.tensors,
        summary.elements,
        out,
        summary.file_bytes,
        t0.elapsed().as_secs_f64(),
    );
    println!(
        "  {} x{} | scheme bits {:.3}/elem | container {:.3} b/elem \
         | sq-err {:.6e}",
        opts.codec.name(),
        opts.lanes,
        summary.mean_bits,
        summary.packed_bits,
        summary.sq_err,
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("usage: owf inspect <file.owq> [--verify]")?;
    let art = Artifact::open(path)?;
    println!(
        "{path}: OWQ v{}, {} tensors, {} elements, {} payload bytes, \
         codec {} x{}",
        art.version,
        art.tensors.len(),
        art.total_elements(),
        art.payload_bytes(),
        art.codec.name(),
        art.lanes,
    );
    if let Some(a) = &art.alloc {
        println!(
            "  alloc: {} (target {:.3}, average {:.3})",
            a.scheme, a.target, a.average
        );
    }
    if !art.skipped.is_empty() {
        println!(
            "  skipped at pack time (non-f32/empty): {}",
            art.skipped.join(", ")
        );
    }
    println!("  meta: {}", art.meta);
    for rec in &art.tensors {
        let packed =
            rec.payload.len as f64 * 8.0 / rec.n.max(1) as f64;
        let mut marks = String::new();
        if rec.transposed {
            marks.push_str(" T");
        }
        if rec.rot_seed.is_some() {
            marks.push_str(" R");
        }
        if let Some(g) = &rec.grid {
            marks.push_str(&format!(" G{}", g.buckets.len()));
        }
        if rec.mix.is_some() {
            marks.push_str(" M");
        }
        println!(
            "  {:<24} {:?}{} {:<36} {:>9.3} b/elem (payload {:.3}) \
             sq-err {:.4e} outliers {}",
            rec.name,
            rec.shape,
            marks,
            rec.spec,
            rec.bits,
            packed,
            rec.sq_err,
            rec.outlier_idx.len / 4,
        );
        if let Some(mix) = &rec.mix {
            let total: usize = mix.part_elems.iter().sum();
            let parts: Vec<String> = mix
                .specs
                .iter()
                .zip(&mix.part_elems)
                .map(|(s, &e)| {
                    // "int@4:block64-absmax" -> "int4"
                    let (el, rest) =
                        s.split_once('@').unwrap_or((s.as_str(), ""));
                    let b = rest.split(':').next().unwrap_or("");
                    format!(
                        "{el}{b} {:.0}%",
                        100.0 * e as f64 / total.max(1) as f64
                    )
                })
                .collect();
            println!("      mix: {}", parts.join(" / "));
        }
    }
    if args.flags.contains_key("verify") {
        verify_artifact(&art)?;
    }
    Ok(())
}

/// `owf fsck <file.owq>`: eager integrity walk.  Every section checksum
/// is forced and every tensor is decoded end-to-end (the lazy serving
/// path only verifies what it touches), with a per-tensor verdict table.
/// Exits nonzero if the container is unreadable or any tensor is damaged.
fn cmd_fsck(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("usage: owf fsck <file.owq>")?;
    let art = match Artifact::open(path) {
        Ok(a) => a,
        Err(e) => bail!("fsck {path}: unreadable container — {e}"),
    };
    let mut report = Report::new(
        "fsck",
        &format!("fsck {path}"),
        &["tensor", "elems", "sections", "decode", "verdict"],
    );
    let mut damaged = 0usize;
    for (i, rec) in art.tensors.iter().enumerate() {
        let mut bad: Vec<&str> = Vec::new();
        for (sname, _) in rec.sections() {
            if let Some(Err(_)) = art.verify_section(i, sname) {
                bad.push(sname);
            }
        }
        let sections = if bad.is_empty() {
            "ok".to_string()
        } else {
            bad.join(",")
        };
        let decode = match art.decode_tensor(i) {
            Ok(_) => "ok".to_string(),
            Err(e) => e.kind_name().to_string(),
        };
        let ok = bad.is_empty() && decode == "ok";
        if !ok {
            damaged += 1;
        }
        report.row(vec![
            rec.name.clone(),
            rec.n.to_string(),
            sections,
            decode,
            if ok { "ok" } else { "DAMAGED" }.to_string(),
        ]);
    }
    print!("{}", report.render());
    if damaged > 0 {
        bail!(
            "fsck {path}: {damaged} of {} tensors damaged \
             (corrupt sections / failed decodes above)",
            art.tensors.len()
        );
    }
    println!(
        "fsck {path}: clean — {} tensors, every checksum verified, \
         every tensor decoded",
        art.tensors.len()
    );
    Ok(())
}

/// `owf fault-inject <in.owq> --out <out.owq> ...`: write a deliberately
/// damaged copy of a container.  Damage modes: a single bit flip aimed at
/// the middle of one tensor's section (`--section codebook|scales|payload|
/// counts|outlier_idx|outlier_val`, tensor via `--tensor`, bit via
/// `--bit`), the manifest or header, or truncation (`--truncate-frac`).
/// Drives the `scripts/check.sh` fault gate and manual fsck drills.
fn cmd_fault_inject(args: &Args) -> Result<()> {
    let input = args.positional.get(1).context(
        "usage: owf fault-inject <in.owq> --out <out.owq> \
         (--section <name>|manifest|header [--tensor T] [--bit K] \
         | --truncate-frac F)",
    )?;
    let out = args
        .flags
        .get("out")
        .context("--out <file.owq> required")?;
    let bytes = std::fs::read(input)
        .with_context(|| format!("read {input}"))?;
    if let Some(frac) = args.flags.get("truncate-frac") {
        let frac: f64 = frac.parse().context("--truncate-frac")?;
        write_torn_copy(out, &bytes, frac)
            .with_context(|| format!("write torn copy {out}"))?;
        let kept = std::fs::metadata(out)?.len();
        println!(
            "fault-inject: {input} ({} bytes) truncated -> {out} \
             ({kept} bytes)",
            bytes.len()
        );
        return Ok(());
    }
    let section = args.flags.get("section").context(
        "--section <codebook|scales|payload|counts|outlier_idx|\
         outlier_val|block_schemes|manifest|header> or \
         --truncate-frac required",
    )?;
    let bit: u8 = args
        .flags
        .get("bit")
        .map(|v| v.parse())
        .transpose()
        .context("--bit")?
        .unwrap_or(0);
    let (offset, target) = match section.as_str() {
        // magic byte: detected structurally before any checksum
        "header" => (2usize, "header magic".to_string()),
        "manifest" => {
            if bytes.len() < 16 {
                bail!("{input}: too short to hold an OWQ1 manifest");
            }
            let mlen = u32::from_le_bytes(
                bytes[4..8].try_into().unwrap(),
            ) as usize;
            if mlen == 0 || 8 + mlen > bytes.len() {
                bail!("{input}: manifest length {mlen} out of range");
            }
            (8 + mlen / 2, "manifest json".to_string())
        }
        name => {
            // open the clean container to resolve the section's file range
            let art = Artifact::open(input)
                .map_err(|e| anyhow::anyhow!("{input}: {e}"))?;
            let tensor = match args.flags.get("tensor") {
                Some(t) => t.clone(),
                None => art
                    .tensors
                    .iter()
                    .find(|r| {
                        art.section_file_range(&r.name, name)
                            .map(|(_, len)| len > 0)
                            .unwrap_or(false)
                    })
                    .map(|r| r.name.clone())
                    .with_context(|| {
                        format!(
                            "no tensor has a non-empty {name:?} section"
                        )
                    })?,
            };
            let (off, len) = art
                .section_file_range(&tensor, name)
                .with_context(|| {
                    format!(
                        "unknown tensor/section {tensor:?}/{name:?} \
                         (sections: codebook scales payload counts \
                         outlier_idx outlier_val block_schemes)"
                    )
                })?;
            if len == 0 {
                bail!("{tensor}: section {name:?} is empty");
            }
            (off + len / 2, format!("{tensor}/{name}"))
        }
    };
    std::fs::write(out, &bytes)
        .with_context(|| format!("write {out}"))?;
    flip_bit_in_file(out, offset, bit)
        .with_context(|| format!("flip bit in {out}"))?;
    println!(
        "fault-inject: {input} -> {out}, flipped bit {bit} of byte \
         {offset} ({target})"
    );
    Ok(())
}

/// Nearest-rank percentile of an already-sorted latency sample.
fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx]
}

/// Per-step tallies from one open-loop load step.
#[derive(Default)]
struct StepTally {
    ok: u64,
    deadline: u64,
    shed: u64,
    breaker: u64,
    other_err: u64,
    latencies_ms: Vec<f64>,
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let path = args.positional.get(1).context(
        "usage: owf serve-bench <file.owq> [--threads N] [--requests N] \
         [--cache-mb M] [--max-decodes N] [--queue-depth N] \
         [--deadline-ms MS] [--slow-budget-ms MS] [--rates R1,R2,..] \
         [--zipf S] [--seed N] [--json FILE] [--fault-eio-rate R] \
         [--fault-eio-seed S] [--fault-flips N] [--fault-seed S] \
         [--verify]",
    )?;
    let threads: usize = args
        .flags
        .get("threads")
        .map(|v| v.parse())
        .transpose()
        .context("--threads")?
        .unwrap_or(4)
        .max(1);
    let requests: usize = args
        .flags
        .get("requests")
        .map(|v| v.parse())
        .transpose()
        .context("--requests")?
        .unwrap_or(256)
        .max(1);
    let cache_mb: usize = args
        .flags
        .get("cache-mb")
        .map(|v| v.parse())
        .transpose()
        .context("--cache-mb")?
        .unwrap_or(64);
    let max_decodes: usize = args
        .flags
        .get("max-decodes")
        .map(|v| v.parse())
        .transpose()
        .context("--max-decodes")?
        .unwrap_or(0);
    let queue_depth: usize = args
        .flags
        .get("queue-depth")
        .map(|v| v.parse())
        .transpose()
        .context("--queue-depth")?
        .unwrap_or(0);
    let deadline_ms: u64 = args
        .flags
        .get("deadline-ms")
        .map(|v| v.parse())
        .transpose()
        .context("--deadline-ms")?
        .unwrap_or(0);
    let slow_budget_ms: u64 = args
        .flags
        .get("slow-budget-ms")
        .map(|v| v.parse())
        .transpose()
        .context("--slow-budget-ms")?
        .unwrap_or(0);
    let zipf_s: f64 = args
        .flags
        .get("zipf")
        .map(|v| v.parse())
        .transpose()
        .context("--zipf")?
        .unwrap_or(1.0);
    let load_seed: u64 = args
        .flags
        .get("seed")
        .map(|v| v.parse())
        .transpose()
        .context("--seed")?
        .unwrap_or(1234);
    let rates: Option<Vec<f64>> = args
        .flags
        .get("rates")
        .map(|v| {
            v.split(',')
                .map(|r| r.trim().parse::<f64>())
                .collect::<Result<Vec<f64>, _>>()
        })
        .transpose()
        .context("--rates")?;
    if let Some(rs) = &rates {
        if rs.is_empty() || rs.iter().any(|&r| r <= 0.0) {
            bail!("--rates needs a comma list of positive req/s values");
        }
    }
    let json_out = args.flags.get("json").cloned();
    let eio_rate: f64 = args
        .flags
        .get("fault-eio-rate")
        .map(|v| v.parse())
        .transpose()
        .context("--fault-eio-rate")?
        .unwrap_or(0.0);
    let eio_seed: u64 = args
        .flags
        .get("fault-eio-seed")
        .map(|v| v.parse())
        .transpose()
        .context("--fault-eio-seed")?
        .unwrap_or(7);
    let flips: usize = args
        .flags
        .get("fault-flips")
        .map(|v| v.parse())
        .transpose()
        .context("--fault-flips")?
        .unwrap_or(0);
    let fault_seed: u64 = args
        .flags
        .get("fault-seed")
        .map(|v| v.parse())
        .transpose()
        .context("--fault-seed")?
        .unwrap_or(42);
    let faulty = eio_rate > 0.0 || flips > 0;
    let art = if faulty {
        // chaos mode: serve through a seeded fault-injecting byte source
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {path}"))?;
        if bytes.len() < 16 {
            bail!("{path}: too short to be an OWQ1 container");
        }
        let mlen =
            u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let base = (8 + mlen + 8).min(bytes.len().saturating_sub(1));
        let len = bytes.len();
        let mut fs = FaultFs::new(bytes);
        // aim flips at the payload region so each lands inside some
        // tensor's checksummed section, exercising quarantine
        let mut rng = Rng::new(fault_seed);
        for _ in 0..flips {
            let off = base + rng.below((len - base).max(1));
            fs = fs.with_flip(off, rng.below(8) as u8);
        }
        if eio_rate > 0.0 {
            fs = fs.with_transient_rate(eio_rate, eio_seed);
        }
        Artifact::from_source(ByteSource::Fault(fs))
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?
    } else {
        Artifact::open(path)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?
    };
    if args.flags.contains_key("verify") {
        verify_artifact(&art)?;
    }
    let names: Vec<String> =
        art.tensors.iter().map(|r| r.name.clone()).collect();
    if names.is_empty() {
        bail!("{path}: artifact holds no tensors");
    }
    let mut server = ArtifactServer::new(art, cache_mb * (1 << 20))
        .with_max_decodes(max_decodes)
        .with_queue_depth(queue_depth);
    if slow_budget_ms > 0 {
        server = server
            .with_slow_budget(std::time::Duration::from_millis(slow_budget_ms));
    }
    let server = server;
    // mint a fresh per-request deadline on the server's clock
    let deadline = |server: &ArtifactServer| -> Option<Deadline> {
        (deadline_ms > 0).then(|| {
            Deadline::after(
                &*server.clock(),
                std::time::Duration::from_millis(deadline_ms),
            )
        })
    };

    let mut bench_rows: Vec<Json> = Vec::new();
    let mut total_errors = 0u64;
    if let Some(rates) = &rates {
        // open-loop saturation sweep: arrivals at a fixed rate across
        // `threads` lanes, tensor popularity Zipf(s), latency measured
        // from each request's *scheduled* arrival so lane backlog counts
        // against the server, not the load generator
        let zipf = Zipf::new(names.len(), zipf_s);
        println!(
            "serve-bench: open-loop sweep over {} tensors, zipf s={zipf_s}, \
             {requests} requests/step, {threads} lanes, deadline \
             {deadline_ms}ms",
            names.len()
        );
        for (step, &rate) in rates.iter().enumerate() {
            let mut rng = Rng::new(load_seed.wrapping_add(step as u64));
            let work: Vec<(std::time::Duration, usize)> = (0..requests)
                .map(|i| {
                    let arrival = std::time::Duration::from_secs_f64(
                        i as f64 / rate,
                    );
                    (arrival, zipf.sample(&mut rng))
                })
                .collect();
            let t0 = std::time::Instant::now();
            let mut tallies: Vec<StepTally> = Vec::new();
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for t in 0..threads {
                    let server = &server;
                    let names = &names;
                    let work = &work;
                    let deadline = &deadline;
                    handles.push(scope.spawn(move || -> StepTally {
                        let mut tally = StepTally::default();
                        for (arrival, name_ix) in
                            work.iter().skip(t).step_by(threads)
                        {
                            let now = t0.elapsed();
                            if now < *arrival {
                                std::thread::sleep(*arrival - now);
                            }
                            let res = server.get_deadline(
                                &names[*name_ix],
                                deadline(server),
                            );
                            let lat = t0
                                .elapsed()
                                .saturating_sub(*arrival)
                                .as_secs_f64()
                                * 1e3;
                            match res {
                                Ok(data) => {
                                    tally.ok += 1;
                                    tally.latencies_ms.push(lat);
                                    std::hint::black_box(
                                        data.first().copied(),
                                    );
                                }
                                Err(
                                    ArtifactError::DeadlineExceeded {
                                        ..
                                    },
                                ) => tally.deadline += 1,
                                Err(
                                    ArtifactError::Overloaded { .. }
                                    | ArtifactError::QueueFull { .. },
                                ) => tally.shed += 1,
                                Err(ArtifactError::BreakerOpen {
                                    ..
                                }) => tally.breaker += 1,
                                Err(_) => tally.other_err += 1,
                            }
                        }
                        tally
                    }));
                }
                for h in handles {
                    tallies
                        .push(h.join().expect("serve lane panicked"));
                }
            });
            let elapsed = t0.elapsed().as_secs_f64();
            let mut step_tally = StepTally::default();
            for t in tallies {
                step_tally.ok += t.ok;
                step_tally.deadline += t.deadline;
                step_tally.shed += t.shed;
                step_tally.breaker += t.breaker;
                step_tally.other_err += t.other_err;
                step_tally.latencies_ms.extend(t.latencies_ms);
            }
            step_tally
                .latencies_ms
                .sort_by(|a, b| a.partial_cmp(b).unwrap());
            let lat = &step_tally.latencies_ms;
            let goodput = step_tally.ok as f64 / elapsed;
            total_errors += step_tally.deadline
                + step_tally.shed
                + step_tally.breaker
                + step_tally.other_err;
            println!(
                "  rate {rate:7.1} req/s: goodput {goodput:7.1} req/s, \
                 p50 {:6.2}ms p99 {:6.2}ms p999 {:6.2}ms; \
                 {} ok, {} deadline, {} shed, {} breaker, {} errors",
                percentile(lat, 0.50),
                percentile(lat, 0.99),
                percentile(lat, 0.999),
                step_tally.ok,
                step_tally.deadline,
                step_tally.shed,
                step_tally.breaker,
                step_tally.other_err,
            );
            bench_rows.push(
                Json::obj()
                    .push("rate_rps", rate)
                    .push("requests", requests)
                    .push("ok", step_tally.ok as usize)
                    .push("deadline_exceeded", step_tally.deadline as usize)
                    .push("shed", step_tally.shed as usize)
                    .push("breaker_open", step_tally.breaker as usize)
                    .push("errors", step_tally.other_err as usize)
                    .push("goodput_rps", goodput)
                    .push("p50_ms", percentile(lat, 0.50))
                    .push("p99_ms", percentile(lat, 0.99))
                    .push("p999_ms", percentile(lat, 0.999)),
            );
        }
    } else {
        // closed loop: each thread issues its share back-to-back
        let per_thread = requests.div_ceil(threads);
        let t0 = std::time::Instant::now();
        let mut served: Vec<(u64, u64)> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let server = &server;
                let names = &names;
                let deadline = &deadline;
                handles.push(scope.spawn(move || -> (u64, u64) {
                    let mut elems = 0u64;
                    let mut errors = 0u64;
                    for i in 0..per_thread {
                        let name = &names[(t + i) % names.len()];
                        // fault drills keep serving through failures:
                        // count them, never abort the thread
                        match server.get_deadline(name, deadline(server))
                        {
                            Ok(data) => {
                                elems += data.len() as u64;
                                std::hint::black_box(
                                    data.first().copied(),
                                );
                            }
                            Err(_) => errors += 1,
                        }
                    }
                    (elems, errors)
                }));
            }
            for h in handles {
                served.push(h.join().expect("serve thread panicked"));
            }
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let mut total_elems = 0u64;
        for (elems, errors) in served {
            total_elems += elems;
            total_errors += errors;
        }
        let total_requests = per_thread * threads;
        println!(
            "serve-bench: {threads} threads x {total_requests} requests \
             over {} tensors in {elapsed:.3}s",
            names.len()
        );
        println!(
            "  served {:.1} MB ({:.1} Melem) — {:.0} req/s, {:.1} Melem/s",
            total_elems as f64 * 4.0 / 1e6,
            total_elems as f64 / 1e6,
            total_requests as f64 / elapsed,
            total_elems as f64 / elapsed / 1e6,
        );
    }

    let s = server.stats();
    println!(
        "  cache: {} hits / {} misses ({:.1}% hit rate), {} evictions, \
         {} resident ({:.1} MB), cap {cache_mb} MB; decoded {:.1} MB",
        s.hits,
        s.misses,
        100.0 * s.hits as f64 / s.requests.max(1) as f64,
        s.evictions,
        s.cached_tensors,
        s.cached_bytes as f64 / 1e6,
        s.decoded_bytes as f64 / 1e6,
    );
    println!(
        "  resilience: {} coalesced, {} io retries, {} overloads; \
         {} failed requests ({} decode errors, {} coalesced errors, \
         {} quarantine hits), {} tensors quarantined",
        s.coalesced,
        s.io_retries,
        s.overloads,
        total_errors,
        s.decode_errors,
        s.coalesced_errors,
        s.quarantine_hits,
        s.quarantined,
    );
    println!(
        "  backpressure: {} queued, {} queue-full, {} deadline \
         (queued {} / waiting {}), {} slow decodes, {} breaker sheds, \
         {} probes, {} breakers open",
        s.queued,
        s.queue_full,
        s.deadline_exceeded_queued + s.deadline_exceeded_waiting,
        s.deadline_exceeded_queued,
        s.deadline_exceeded_waiting,
        s.slow_decodes,
        s.breaker_open,
        s.breaker_probes,
        s.breakers_open,
    );
    if s.partition_closed() {
        println!("  partition: closed ({} requests)", s.requests);
    } else {
        bail!(
            "serve-bench: stats partition NOT closed: requests {} vs \
             hits {} + misses {} + coalesced_errors {} + quarantine \
             {} + overloads {} + queue_full {} + deadline {}+{} + \
             breaker {} + not_found {}",
            s.requests,
            s.hits,
            s.misses,
            s.coalesced_errors,
            s.quarantine_hits,
            s.overloads,
            s.queue_full,
            s.deadline_exceeded_queued,
            s.deadline_exceeded_waiting,
            s.breaker_open,
            s.not_found,
        );
    }
    if let Some(out) = json_out {
        let doc = Json::obj()
            .push("bench", "serving")
            .push("zipf_s", zipf_s)
            .push("threads", threads)
            .push("max_decodes", max_decodes)
            .push("queue_depth", queue_depth)
            .push("deadline_ms", deadline_ms as usize)
            .push("rows", Json::Arr(bench_rows));
        std::fs::write(&out, format!("{doc}\n"))
            .with_context(|| format!("write {out}"))?;
        println!("  wrote {out}");
    }
    if total_errors > 0
        && !faulty
        && max_decodes == 0
        && deadline_ms == 0
    {
        bail!(
            "serve-bench: {total_errors} requests failed on a clean \
             container with no admission gate"
        );
    }
    Ok(())
}

fn cmd_fisher(args: &Args) -> Result<()> {
    let opts = opts_from(args)?;
    let batches: usize = args
        .flags
        .get("batches")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let rt = Runtime::open_default()?;
    let size = &opts.size;
    let ck = Checkpoint::load(&rt, size)?;
    let toks = TokenSplit::load(&rt, size, "fisher")?;
    let est = FisherEstimate::estimate(
        &rt,
        size,
        &ck.params(),
        &toks,
        batches,
        1234,
        args.flags.contains_key("empirical"),
    )?;
    let path = rt.data_path(&format!("fisher_{size}.owt"));
    est.save(&path)?;
    println!(
        "fisher({size}): {} sequences -> {:?}",
        est.sequences, path
    );
    for t in est.tensor_summaries() {
        println!("  {:<40} mean {:.3e}", t.name, t.mean);
    }
    Ok(())
}

fn cmd_isa() -> Result<()> {
    use owf::util::simd;
    println!("detected: {}", simd::detected().name());
    println!(
        "active:   {} (OWF_ISA={})",
        simd::active().name(),
        std::env::var("OWF_ISA").unwrap_or_else(|_| "unset".to_string()),
    );
    println!("lanes:    {}", simd::preferred_lanes());
    Ok(())
}

const HELP: &str = "owf — Optimal Weight Formats (paper reproduction)

USAGE:
  owf list                              show artifacts & checkpoints
  owf report <id|sim|llm|all> [opts]    reproduce paper figures/tables
  owf sweep <grid> [opts]               parallel resumable scheme sweep
  owf quantise --spec <scheme> [opts]   one direct-cast measurement
  owf quantise --from <file.owq>        KL-evaluate a packed artifact
  owf pack --spec <scheme> [opts]       write an OWQ1 quantised artifact
  owf inspect <file.owq> [--verify]     print / verify a container
  owf serve-bench <file.owq> [opts]     concurrent decode benchmark
  owf fsck <file.owq>                   eager integrity check; verdict
                                        table, nonzero exit on damage
  owf fault-inject <in> --out <out>     write a damaged container copy
  owf fisher [--size m] [--batches N]   estimate the Fisher diagonal
  owf isa                               show detected/active SIMD path
                                        (pin with OWF_ISA=scalar|avx2|neon)
  owf schemes                           scheme + grid grammar reference

OPTIONS:
  --size s|m|l      model for single-model reports   (default m)
  --samples N       simulated-data sample count      (default 2^20)
  --eval-seqs N     sequences per KL evaluation      (default 24)
  --qat-steps N     QAT training steps               (default 60)
  --out FILE        append report rows as JSONL

SWEEP OPTIONS:
  --data sim|llm    evaluate on iid draws (R) or checkpoints (KL)
                    (default sim; llm needs `make artifacts`)
  --samples N       samples per sim point             (sweep default 2^16)
  --seeds N         seeds per grid point, sim only    (default 1)
  --out FILE        JSONL output / resume state       (default sweep.jsonl)
  --resume          skip points already completed in --out (keyed by
                    scheme, size, seed and the run parameters)
  OWF_THREADS       worker count for CPU points       (default all cores)

PACK OPTIONS (owf pack):
  --spec <scheme>   base scheme, any sweep-grammar spec (required)
  --out FILE        output container                  (default packed.owq)
  --size s|m|l      pack a checkpoint (needs `make artifacts`)
  --sim SHAPES      pack synthetic tensors instead, e.g. 96x64,4096
  --seed N          sim RNG seed                      (default 1234)
  --dist D          sim distribution: t<nu>|normal|laplace (default t5)
  --alloc MODE      flat | variable (eq.-5 Fisher/RMS) | fractional
                    (hull water-filling + per-block scheme mixing;
                    needs a block-granular non-grid spec) (default flat)
  --bits B          fractional target average bits/param, e.g. 3.3
                    (default: the spec's own width; fractional only)
  --codec C         huffman | rans | raw               (default huffman)
  --lanes K         interleaved entropy-coder lanes    (default: the
                    active ISA's vector width — 8 on AVX2, else 4)

SERVE-BENCH OPTIONS:
  --threads N       concurrent reader threads          (default 4)
  --requests N      decode requests (per sweep step)   (default 256)
  --cache-mb M      decoded-tensor LRU cache capacity  (default 64)
  --max-decodes N   max concurrent decodes             (0 = unbounded)
  --queue-depth N   requests that may wait FIFO for a decode permit
                    (0 = shed immediately when permits are busy)
  --deadline-ms MS  per-request deadline; expiry while queued or while
                    waiting on a coalesced decode fails typed (0 = none)
  --slow-budget-ms MS  arm the slow-decode watchdog + circuit breaker
  --rates R1,R2,..  open-loop saturation sweep: fixed arrival rates in
                    req/s; reports p50/p99/p999 + goodput per step
  --zipf S          tensor-popularity Zipf exponent     (default 1.0)
  --seed N          load-generator RNG seed             (default 1234)
  --json FILE       write the sweep as a BENCH_serving.json trajectory
  --fault-eio-rate R  inject transient EIO on reads with probability R
  --fault-eio-seed S  seed for the EIO roll               (default 7)
  --fault-flips N   flip N random payload bits (exercises quarantine)
  --fault-seed S    seed for flip placement               (default 42)
  --verify          first prove bit-exactness vs the in-memory pipeline

FAULT-INJECT OPTIONS (owf fault-inject <in> --out <out>):
  --section S       damage target: codebook|scales|payload|counts|
                    outlier_idx|outlier_val|block_schemes (middle byte
                    of that section) or manifest|header
  --tensor T        which tensor's section              (default: first
                    tensor with a non-empty such section)
  --bit K           bit index 0..7 to flip              (default 0)
  --truncate-frac F keep only the first F of the file (torn write) instead
";

const SCHEME_HELP: &str = "scheme grammar:
  <element>@<bits>:<granularity>-<statistic>[:<flags>]

elements:     int | e<K>m<M> | nf | sf<nu> | af4 | lloyd |
              cbrt-normal | cbrt-laplace | cbrt-t<nu> | grid
granularity:  tensor | channel | block<B>
statistic:    rms | absmax | signmax
flags:        sym | asym | sparse<frac> | rot | compress |
              mult<x> | search | fisher

examples:
  cbrt-t7@4:block128-absmax          paper's best uncompressed format
  grid@3.5:tensor-rms:compress       entropy-coded uniform grid
  int@3:channel-absmax:sparse0.001   SpQR-style dense+sparse
  lloyd@4:tensor-rms:fisher          SqueezeLLM-style weighted k-means

fractional allocator sweep points (owf sweep):
  frac@<bits>:<granularity>-<statistic>[:<flags>]
measures the int@2..8 candidate curve for the tail spec, water-fills
the (possibly fractional) budget over its convex hull and realises the
resulting block-level mix — so the allocator's rate–distortion curve
sweeps directly against the fixed formats.
  frac@{3,3.3,4.7}:block64-absmax                    3 points

sweep grids (owf sweep): any {...} group in a spec expands —
  {a,b,c}   comma alternation        {lo..hi}  inclusive integer range
multiple groups form the cartesian product; ';' joins several grids;
duplicates are dropped.

examples:
  cbrt-t7@{2..8}:block{32,64,128}-absmax            21 points
  {int,nf,cbrt-t5}@4:block64-absmax ; grid@{3..5}:tensor-rms:compress
";
