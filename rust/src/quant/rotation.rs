//! Random orthonormal rotations (fig. 29; QuaRot/SpinQuant-style outlier
//! suppression).
//!
//! A rotation is a seeded composition of `rounds` of
//! (random permutation → random signs → block-wise fast Walsh–Hadamard
//! transform), which is orthonormal by construction, runs in O(n log n),
//! works for any dimension (greedy power-of-two block decomposition; the
//! permutations mix across blocks between rounds) and drives heavy-tailed
//! marginals toward Normal — exactly the property fig. 29 exploits.
//!
//! Applied to a 2-D tensor as `θ_rot = V θ W` (rows rotated by V, columns
//! by W), inverted exactly by the transposes.

use crate::util::rng::Rng;

/// Orthonormal random rotation on vectors of length `dim`.
#[derive(Clone, Debug)]
pub struct RandomRotation {
    dim: usize,
    rounds: Vec<Round>,
}

#[derive(Clone, Debug)]
struct Round {
    perm: Vec<u32>,
    inv_perm: Vec<u32>,
    signs: Vec<f32>,
    /// (start, len) power-of-two FWHT blocks covering [0, dim)
    blocks: Vec<(usize, usize)>,
}

impl RandomRotation {
    pub fn new(dim: usize, seed: u64) -> RandomRotation {
        assert!(dim >= 1);
        let mut rng = Rng::new(seed ^ 0x5EED_0FA7);
        let n_rounds = 3;
        let blocks = pow2_blocks(dim);
        let rounds = (0..n_rounds)
            .map(|_| {
                let mut perm: Vec<u32> = (0..dim as u32).collect();
                rng.shuffle(&mut perm);
                let mut inv_perm = vec![0u32; dim];
                for (i, &p) in perm.iter().enumerate() {
                    inv_perm[p as usize] = i as u32;
                }
                let signs = (0..dim)
                    .map(|_| if rng.f64() < 0.5 { -1.0 } else { 1.0 })
                    .collect();
                Round {
                    perm,
                    inv_perm,
                    signs,
                    blocks: blocks.clone(),
                }
            })
            .collect();
        RandomRotation { dim, rounds }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// y = R x (in place).
    pub fn apply(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.dim);
        let mut tmp = vec![0f32; self.dim];
        for round in &self.rounds {
            // permute
            for (i, &p) in round.perm.iter().enumerate() {
                tmp[i] = x[p as usize];
            }
            // signs
            for (t, &s) in tmp.iter_mut().zip(&round.signs) {
                *t *= s;
            }
            // blockwise normalised FWHT
            for &(start, len) in &round.blocks {
                fwht(&mut tmp[start..start + len]);
            }
            x.copy_from_slice(&tmp);
        }
    }

    /// x = Rᵀ y (in place) — exact inverse of [`RandomRotation::apply`].
    pub fn apply_transpose(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.dim);
        let mut tmp = vec![0f32; self.dim];
        for round in self.rounds.iter().rev() {
            // FWHT is self-inverse (normalised)
            for &(start, len) in &round.blocks {
                fwht(&mut x[start..start + len]);
            }
            for (t, &s) in x.iter_mut().zip(&round.signs) {
                *t *= s;
            }
            for (i, &ip) in round.inv_perm.iter().enumerate() {
                tmp[i] = x[ip as usize];
            }
            x.copy_from_slice(&tmp);
        }
    }

    /// Rotate every length-`dim` row of a row-major (rows × dim) matrix.
    pub fn apply_rows(&self, data: &mut [f32]) {
        assert_eq!(data.len() % self.dim, 0);
        for row in data.chunks_mut(self.dim) {
            self.apply(row);
        }
    }

    pub fn apply_rows_transpose(&self, data: &mut [f32]) {
        assert_eq!(data.len() % self.dim, 0);
        for row in data.chunks_mut(self.dim) {
            self.apply_transpose(row);
        }
    }
}

/// Greedy power-of-two decomposition of [0, n): e.g. 192 → 128 + 64.
fn pow2_blocks(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut rem = n;
    while rem > 0 {
        let len = 1usize << (usize::BITS - 1 - rem.leading_zeros());
        out.push((start, len));
        start += len;
        rem -= len;
    }
    out
}

/// Normalised in-place fast Walsh–Hadamard transform (len = power of two).
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        for chunk in x.chunks_mut(2 * h) {
            let (a, b) = chunk.split_at_mut(h);
            for (ai, bi) in a.iter_mut().zip(b.iter_mut()) {
                let (u, v) = (*ai, *bi);
                *ai = u + v;
                *bi = u - v;
            }
        }
        h *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for v in x {
        *v *= norm;
    }
}

/// Rotate a 2-D tensor (rows × cols, row-major): θ ← V θ W, where V acts on
/// columns-as-vectors (length rows) and W on rows-as-vectors (length cols).
pub fn rotate_2d(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    v: &RandomRotation,
    w: &RandomRotation,
) {
    assert_eq!(data.len(), rows * cols);
    assert_eq!(v.dim(), rows);
    assert_eq!(w.dim(), cols);
    // W on each row
    w.apply_rows(data);
    // V on each column: transpose, rotate rows, transpose back
    let mut t = transpose(data, rows, cols);
    v.apply_rows(&mut t);
    let back = transpose(&t, cols, rows);
    data.copy_from_slice(&back);
}

/// Inverse of [`rotate_2d`].
pub fn rotate_2d_inverse(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    v: &RandomRotation,
    w: &RandomRotation,
) {
    let mut t = transpose(data, rows, cols);
    v.apply_rows_transpose(&mut t);
    let back = transpose(&t, cols, rows);
    data.copy_from_slice(&back);
    w.apply_rows_transpose(data);
}

fn transpose(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; data.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = data[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    #[test]
    fn fwht_self_inverse() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let mut x = orig.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_orthonormal() {
        for dim in [8, 100, 192, 257] {
            let rot = RandomRotation::new(dim, 42);
            let mut rng = Rng::new(2);
            let orig: Vec<f32> =
                (0..dim).map(|_| rng.normal() as f32).collect();
            let mut x = orig.clone();
            rot.apply(&mut x);
            // norm preserved
            let n0 = stats::rms(&orig);
            let n1 = stats::rms(&x);
            assert!(
                ((n0 - n1) / n0).abs() < 1e-4,
                "dim {dim}: norm {n0} -> {n1}"
            );
            // inverse restores
            rot.apply_transpose(&mut x);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-4, "dim {dim}");
            }
        }
    }

    #[test]
    fn rotation_gaussianises_heavy_tails() {
        // fig. 29's premise: rotation pulls Student-t marginals toward
        // Normal — kurtosis should drop dramatically.
        let dim = 512;
        let mut rng = Rng::new(3);
        let mut x: Vec<f32> =
            (0..dim).map(|_| rng.student_t(3.0) as f32).collect();
        let kurt = |xs: &[f32]| {
            let m = xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
            let var = xs
                .iter()
                .map(|&v| (v as f64 - m).powi(2))
                .sum::<f64>()
                / xs.len() as f64;
            let m4 = xs
                .iter()
                .map(|&v| (v as f64 - m).powi(4))
                .sum::<f64>()
                / xs.len() as f64;
            m4 / (var * var)
        };
        // make it *really* heavy by injecting a spike
        x[7] = 400.0;
        let k_before = kurt(&x);
        let rot = RandomRotation::new(dim, 4);
        rot.apply(&mut x);
        let k_after = kurt(&x);
        assert!(
            k_after < k_before * 0.2,
            "kurtosis {k_before} -> {k_after}"
        );
    }

    #[test]
    fn rotate_2d_roundtrip() {
        let (rows, cols) = (24, 40);
        let mut rng = Rng::new(5);
        let orig: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let v = RandomRotation::new(rows, 10);
        let w = RandomRotation::new(cols, 11);
        let mut x = orig.clone();
        rotate_2d(&mut x, rows, cols, &v, &w);
        assert!(stats::sq_err(&x, &orig) > 0.0); // actually rotated
        rotate_2d_inverse(&mut x, rows, cols, &v, &w);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn pow2_blocks_cover() {
        for n in [1usize, 7, 64, 100, 192, 1000] {
            let blocks = pow2_blocks(n);
            let total: usize = blocks.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, n);
            for &(_, l) in &blocks {
                assert!(l.is_power_of_two());
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let r1 = RandomRotation::new(64, 9);
        let r2 = RandomRotation::new(64, 9);
        let mut a: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut b = a.clone();
        r1.apply(&mut a);
        r2.apply(&mut b);
        assert_eq!(a, b);
        let r3 = RandomRotation::new(64, 10);
        let mut c: Vec<f32> = (0..64).map(|i| i as f32).collect();
        r3.apply(&mut c);
        assert_ne!(a, c);
    }
}
