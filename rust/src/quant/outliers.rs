//! Sparse outlier storage (§4, fig. 1 "0.1% sparse outlier removal"; the
//! SpQR/SqueezeLLM dense-and-sparse decomposition).
//!
//! A fraction of elements — chosen by |θ| or by Fisher-weighted impact
//! f·θ² — is stored exactly (f32 value + index); the remainder goes through
//! the dense quantiser.  Outliers are *removed before* the dense pass so
//! they don't inflate block scales, then patched back in.

use crate::quant::Quantiser;

/// Outlier selection criterion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutlierCriterion {
    /// Largest absolute value.
    AbsValue,
    /// Largest Fisher-weighted squared value f_i·θ_i² (needs weights).
    FisherWeighted,
}

/// Sparse outlier configuration.
#[derive(Clone, Copy, Debug)]
pub struct SparseOutliers {
    /// Fraction of elements kept dense-exempt (the paper uses 1e-3).
    pub fraction: f64,
    pub criterion: OutlierCriterion,
}

impl SparseOutliers {
    pub fn by_value(fraction: f64) -> SparseOutliers {
        SparseOutliers {
            fraction,
            criterion: OutlierCriterion::AbsValue,
        }
    }

    /// Number of outliers for a tensor of n elements.
    pub fn count(&self, n: usize) -> usize {
        ((n as f64) * self.fraction).round() as usize
    }

    /// Storage cost in bits per element of the tensor: each outlier costs a
    /// 32-bit value plus a ⌈log2 n⌉-bit index.
    pub fn overhead_bits(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let k = self.count(n) as f64;
        let idx_bits = (n as f64).log2().ceil();
        k * (32.0 + idx_bits) / n as f64
    }

    /// Select outlier indices (sorted ascending).
    pub fn select(&self, data: &[f32], fisher: &[f32]) -> Vec<u32> {
        let k = self.count(data.len());
        if k == 0 {
            return Vec::new();
        }
        let score = |i: usize| -> f64 {
            let x = data[i] as f64;
            match self.criterion {
                OutlierCriterion::AbsValue => x.abs(),
                OutlierCriterion::FisherWeighted => {
                    let f = if fisher.is_empty() {
                        1.0
                    } else {
                        fisher[i] as f64
                    };
                    f * x * x
                }
            }
        };
        let mut idx: Vec<u32> = (0..data.len() as u32).collect();
        // partial selection of the top-k by score
        idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            score(b as usize)
                .partial_cmp(&score(a as usize))
                .unwrap()
        });
        let mut top: Vec<u32> = idx[..k].to_vec();
        top.sort_unstable();
        top
    }
}

/// Dense + sparse quantise→dequantise: outliers are zeroed for the dense
/// pass (so they don't blow up absmax scales) and restored exactly after.
/// Returns (reconstruction, bits_per_element).
pub fn qdq_with_outliers(
    quantiser: &Quantiser,
    sparse: &SparseOutliers,
    data: &[f32],
    fisher: &[f32],
    channel_len: usize,
) -> (Vec<f32>, f64) {
    let outlier_idx = sparse.select(data, fisher);
    let mut dense = data.to_vec();
    for &i in &outlier_idx {
        dense[i as usize] = 0.0;
    }
    quantiser.qdq_in_place(&mut dense, channel_len);
    for &i in &outlier_idx {
        dense[i as usize] = data[i as usize];
    }
    let bits = quantiser.bits_per_element(data.len(), channel_len)
        + sparse.overhead_bits(data.len());
    (dense, bits)
}

/// [`qdq_with_outliers`] that also returns the codebook-index histogram of
/// the *dense* stream (outliers zeroed before encoding, exactly as the
/// dense pass quantises them) — the entropy model for `:compress:sparseX`
/// schemes.  One fused [`Quantiser::encode_with_stats`] pass produces the
/// indices and histogram; the reconstruction is decoded from those same
/// indices (bit-identical to the fused qdq) *into the dense buffer* via
/// the fused [`Quantiser::decode_into`] kernel — one copy of the tensor
/// total — and the outliers scatter back over it, so selection,
/// quantisation and reconstruction each touch the data exactly once.
pub fn qdq_outliers_with_hist(
    quantiser: &Quantiser,
    sparse: &SparseOutliers,
    data: &[f32],
    fisher: &[f32],
    channel_len: usize,
) -> (Vec<f32>, f64, Vec<u64>) {
    let outlier_idx = sparse.select(data, fisher);
    let mut dense = data.to_vec();
    for &i in &outlier_idx {
        dense[i as usize] = 0.0;
    }
    let (enc, stats) = quantiser.encode_with_stats(&dense, channel_len);
    quantiser.decode_into(&enc, &mut dense);
    for &i in &outlier_idx {
        dense[i as usize] = data[i as usize];
    }
    let bits = quantiser.bits_per_element(data.len(), channel_len)
        + sparse.overhead_bits(data.len());
    (dense, bits, stats.counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::int::int_codebook;
    use crate::formats::Variant;
    use crate::scaling::{Granularity, ScaleFormat, Statistic};
    use crate::util::rng::Rng;
    use crate::util::stats::relative_rms_error;

    fn quantiser() -> Quantiser {
        Quantiser::new(
            Granularity::Tensor,
            Statistic::Absmax,
            ScaleFormat::F32,
            int_codebook(4, Variant::Asymmetric),
        )
    }

    fn spiky_data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut data: Vec<f32> =
            (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
        // inject huge outliers
        for i in 0..n / 500 {
            data[(i * 499) % n] = 50.0 * if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        data
    }

    #[test]
    fn outliers_bitexact_and_error_drops() {
        let data = spiky_data(10_000, 1);
        let q = quantiser();
        let sp = SparseOutliers::by_value(0.005);
        let (recon, bits) = qdq_with_outliers(&q, &sp, &data, &[], 0);
        // every selected outlier must be exact
        for &i in &sp.select(&data, &[]) {
            assert_eq!(recon[i as usize], data[i as usize]);
        }
        // error with outlier removal should be dramatically lower than
        // plain tensor-absmax (whose scale is dominated by the spikes)
        let r_sparse = relative_rms_error(&data, &recon);
        let r_plain = relative_rms_error(&data, &q.qdq(&data, 0));
        assert!(
            r_sparse < r_plain * 0.2,
            "sparse {r_sparse} vs plain {r_plain}"
        );
        assert!(bits > 4.0 && bits < 4.5, "bits {bits}");
    }

    #[test]
    fn count_and_overhead() {
        let sp = SparseOutliers::by_value(1e-3);
        assert_eq!(sp.count(10_000), 10);
        let bits = sp.overhead_bits(10_000);
        // 10 outliers × (32 + 14) bits / 10000
        assert!((bits - 10.0 * 46.0 / 10_000.0).abs() < 1e-12);
    }

    #[test]
    fn fisher_weighted_selection_differs() {
        let data = vec![1.0f32, -2.0, 0.5, 1.5];
        let fisher = vec![0.0f32, 0.0, 100.0, 0.01];
        let by_val = SparseOutliers {
            fraction: 0.25,
            criterion: OutlierCriterion::AbsValue,
        };
        let by_fisher = SparseOutliers {
            fraction: 0.25,
            criterion: OutlierCriterion::FisherWeighted,
        };
        assert_eq!(by_val.select(&data, &fisher), vec![1]);
        assert_eq!(by_fisher.select(&data, &fisher), vec![2]);
    }

    #[test]
    fn zero_fraction_is_noop() {
        let data = spiky_data(1000, 2);
        let q = quantiser();
        let sp = SparseOutliers::by_value(0.0);
        let (recon, bits) = qdq_with_outliers(&q, &sp, &data, &[], 0);
        assert_eq!(recon, q.qdq(&data, 0));
        assert!((bits - q.bits_per_element(1000, 0)).abs() < 1e-12);
    }

    #[test]
    fn fused_outlier_hist_matches_two_pass_path() {
        let data = spiky_data(10_000, 4);
        let q = quantiser();
        let sp = SparseOutliers::by_value(0.005);
        let (recon, bits, counts) =
            qdq_outliers_with_hist(&q, &sp, &data, &[], 0);
        // reconstruction and bits must equal the unfused qdq_with_outliers
        let (recon2, bits2) = qdq_with_outliers(&q, &sp, &data, &[], 0);
        assert_eq!(recon, recon2);
        assert_eq!(bits, bits2);
        // the histogram covers the dense stream exactly
        assert_eq!(counts.iter().sum::<u64>() as usize, data.len());
        // with the spikes zeroed, the dense scale shrinks ~500× and the
        // histogram must spread at least as widely as the spiky encoding
        let occupied = counts.iter().filter(|&&c| c > 0).count();
        let (_, spiky_stats) = q.encode_with_stats(&data, 0);
        let spiky_occupied =
            spiky_stats.counts.iter().filter(|&&c| c > 0).count();
        assert!(
            occupied >= spiky_occupied,
            "dense {occupied} vs spiky {spiky_occupied}"
        );
    }

    #[test]
    fn indices_sorted_unique() {
        let data = spiky_data(5000, 3);
        let sp = SparseOutliers::by_value(0.01);
        let idx = sp.select(&data, &[]);
        assert_eq!(idx.len(), 50);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
