//! The quantisation pipeline: granularity × statistic × scale format ×
//! element codebook, plus sparse-outlier overlay and random rotations.
//!
//! This is the Rust-native hot path (the Pallas kernel implements the same
//! semantics for the QAT graphs; rust/tests/qdq_cross.rs bit-compares the
//! two through PJRT).

pub mod outliers;
pub mod rotation;

use crate::formats::Codebook;
use crate::scaling::{
    scale_groups, scale_overhead_bits, Granularity, ScaleFormat, Statistic,
};

/// A fully specified linear-scaling quantiser (§2.1 "Linear scaling").
#[derive(Clone, Debug)]
pub struct Quantiser {
    pub granularity: Granularity,
    pub statistic: Statistic,
    pub scale_format: ScaleFormat,
    pub codebook: Codebook,
    /// Extra multiplier on the scale (quantiser-scale search, §2.2 /
    /// fig. 23: `θ̃ = n'·dequantise(quantise(θ/n'))`). 1.0 = moment match.
    pub scale_multiplier: f64,
}

/// Quantised representation of one tensor (scales + codebook indices).
///
/// This is also the unit the `OWQ1` artifact store persists
/// ([`crate::artifact`]): scales and entropy-coded indices travel as
/// container sections, and `groups` is reconstructed on read from
/// `scale_groups(n, granularity, channel_len)` — so the decode contract
/// below (group starts redundant with lengths) is what makes the packed
/// round trip bit-exact.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub scales: Vec<f32>,
    pub indices: Vec<u16>,
    pub groups: Vec<(usize, usize)>,
}

/// Result summary of a quantise→dequantise pass.
#[derive(Clone, Copy, Debug)]
pub struct QdqStats {
    /// Average bits per element (element format + scale overhead).
    pub bits_per_element: f64,
    /// Sum of squared reconstruction error (f64 accumulation).
    pub sq_err: f64,
}

/// Statistics collected by the fused encode kernel in the same pass that
/// produces the indices — no re-walk of the data to histogram or score.
#[derive(Clone, Debug)]
pub struct EncodeStats {
    /// Sum of squared reconstruction error (f64 accumulation; summed in
    /// deterministic chunk order on the parallel path).
    pub sq_err: f64,
    /// Codebook-index histogram (length = codebook size) — the entropy
    /// model `:compress` schemes feed to [`crate::compress::entropy_bits`].
    pub counts: Vec<u64>,
}

impl Quantiser {
    pub fn new(
        granularity: Granularity,
        statistic: Statistic,
        scale_format: ScaleFormat,
        codebook: Codebook,
    ) -> Quantiser {
        Quantiser {
            granularity,
            statistic,
            scale_format,
            codebook,
            scale_multiplier: 1.0,
        }
    }

    pub fn with_multiplier(mut self, m: f64) -> Quantiser {
        self.scale_multiplier = m;
        self
    }

    /// Effective group scale: statistic → format rounding → multiplier,
    /// with the degenerate-block guard: zero (all-zero block), non-finite
    /// (NaN data / overflowing multiplier) and — outside signmax, whose
    /// statistic legitimately carries the max's sign — negative scales
    /// would poison every index in the block, so they snap to the neutral
    /// scale 1.
    fn group_scale(&self, block: &[f32]) -> f32 {
        let raw = self.statistic.compute(block);
        let rounded = self.scale_format.round(raw);
        let s = rounded * self.scale_multiplier as f32;
        let negative_ok = self.statistic == Statistic::Signmax;
        if !s.is_finite() || s == 0.0 || (s < 0.0 && !negative_ok) {
            1.0
        } else {
            s
        }
    }

    /// Quantise to (scales, indices).  Delegates to the fused
    /// [`Quantiser::encode_with_stats`] kernel and discards the stats —
    /// that costs a histogram increment and an f64 error accumulation per
    /// element; callers on a measured hot path that truly need stats-free
    /// encoding should say so here before a split kernel is added (every
    /// in-repo hot path wants the stats).
    pub fn encode(&self, data: &[f32], channel_len: usize) -> Encoded {
        self.encode_with_stats(data, channel_len).0
    }

    /// The fused encode kernel and the batch entry point every `:compress`
    /// call site routes through: one cache-friendly pass per scale block
    /// computes the statistic, the rounded scale, its reciprocal, the
    /// codebook indices, the index histogram and the squared-error
    /// accumulator — no per-element divide (multiply by the reciprocal,
    /// matching the fused qdq bit-for-bit) and no per-element group-id
    /// division (blocks are walked contiguously).  Large tensors fan
    /// group-aligned chunks over the worker pool; per-chunk partials merge
    /// in deterministic chunk order.
    pub fn encode_with_stats(
        &self,
        data: &[f32],
        channel_len: usize,
    ) -> (Encoded, EncodeStats) {
        use crate::util::pool::{self, PAR_THRESHOLD};
        let n = data.len();
        let k = self.codebook.len();
        let groups = scale_groups(n, self.granularity, channel_len);
        // groups are uniform-length except possibly the last, so chunks of
        // whole groups tile the index buffer
        let group_len = groups.first().map(|&(_, len)| len).unwrap_or(0);
        let mut indices = vec![0u16; n];
        let parallel =
            n >= PAR_THRESHOLD && groups.len() > 1 && group_len > 0;
        let mut scales = Vec::with_capacity(groups.len());
        let mut sq_err = 0f64;
        let mut counts = vec![0u64; k];
        if parallel {
            let per = groups
                .len()
                .div_ceil(pool::num_threads())
                .max(1);
            let chunk = per * group_len;
            let parts = pool::par_chunks_mut_map(
                &mut indices,
                chunk,
                |ci, out| {
                    let base = ci * chunk;
                    let mut chunk_scales = Vec::with_capacity(per);
                    let mut sq = 0f64;
                    let mut hist = vec![0u64; k];
                    let mut off = 0usize;
                    while off < out.len() {
                        let len = group_len.min(out.len() - off);
                        let block = &data[base + off..base + off + len];
                        let s = self.group_scale(block);
                        let inv = 1.0 / s;
                        self.codebook.encode_block(
                            block,
                            inv,
                            s,
                            &mut out[off..off + len],
                            &mut sq,
                            &mut hist,
                        );
                        chunk_scales.push(s);
                        off += len;
                    }
                    (chunk_scales, sq, hist)
                },
            );
            for (chunk_scales, sq, hist) in parts {
                scales.extend(chunk_scales);
                sq_err += sq;
                for (acc, c) in counts.iter_mut().zip(&hist) {
                    *acc += c;
                }
            }
        } else {
            for &(start, len) in &groups {
                let block = &data[start..start + len];
                let s = self.group_scale(block);
                let inv = 1.0 / s;
                self.codebook.encode_block(
                    block,
                    inv,
                    s,
                    &mut indices[start..start + len],
                    &mut sq_err,
                    &mut counts,
                );
                scales.push(s);
            }
        }
        debug_assert_eq!(scales.len(), groups.len());
        (
            Encoded {
                scales,
                indices,
                groups,
            },
            EncodeStats { sq_err, counts },
        )
    }

    /// Reconstruct from an encoding.  Allocates the output once and
    /// delegates to the fused [`Quantiser::decode_into`] kernel; callers on
    /// the serving path that already own a buffer should call `decode_into`
    /// directly and skip the allocation.
    pub fn decode(&self, enc: &Encoded) -> Vec<f32> {
        let mut out = vec![0f32; enc.indices.len()];
        self.decode_into(enc, &mut out);
        out
    }

    /// The fused decode kernel — the serving-scale counterpart of
    /// [`Quantiser::encode_with_stats`]: per block, the scale is hoisted
    /// into a scaled-codepoint table once ([`Codebook::decode_block`]) so
    /// the inner loop is a single gather, and large tensors fan
    /// group-aligned chunks over the worker pool exactly like the encode
    /// kernel (bit-identical to the serial path — every element is
    /// `points[idx]·s` whichever thread computes it).  Bit-exact with
    /// [`Quantiser::decode_ref`] and with the fused qdq by construction
    /// (`EXPERIMENTS.md` §Decode); `rust/tests/decode_props.rs` and the
    /// bench gate in `benches/formats.rs` enforce this.
    ///
    /// Panics if `out.len()`, the index count or the scale count disagree
    /// with the group table.  Group *start* offsets are redundant with the
    /// lengths and ignored, exactly as in `decode_ref`'s running-cursor
    /// walk, so no hand-built encoding can make the two paths diverge.
    pub fn decode_into(&self, enc: &Encoded, out: &mut [f32]) {
        use crate::util::pool::{self, PAR_THRESHOLD};
        let n: usize = enc.groups.iter().map(|&(_, l)| l).sum();
        assert_eq!(
            out.len(),
            n,
            "decode_into: output buffer length mismatch"
        );
        assert_eq!(
            enc.indices.len(),
            n,
            "decode_into: index/group length mismatch"
        );
        assert_eq!(
            enc.scales.len(),
            enc.groups.len(),
            "decode_into: scale/group count mismatch"
        );
        let k = self.codebook.len();
        let group_len = enc.groups.first().map(|&(_, len)| len).unwrap_or(0);
        // single-group (tensor) encodings parallelise within the group:
        // every chunk shares the one scale
        if enc.groups.len() == 1 && n >= PAR_THRESHOLD {
            let s = enc.scales[0];
            let chunk = n.div_ceil(pool::num_threads()).max(1);
            pool::par_chunks_mut(out, chunk, |ci, chunk_out| {
                let base = ci * chunk;
                let mut scaled = Vec::with_capacity(k);
                self.codebook.decode_block(
                    &enc.indices[base..base + chunk_out.len()],
                    s,
                    chunk_out,
                    &mut scaled,
                );
            });
            return;
        }
        // the chunked fan-out assumes the scale_groups layout (uniform
        // group length except possibly the last); anything else — hand-built
        // encodings — takes the serial per-group walk below
        let uniform = group_len > 0
            && enc.groups.iter().enumerate().all(|(i, &(start, len))| {
                start == i * group_len
                    && (len == group_len
                        || (i + 1 == enc.groups.len()
                            && len <= group_len))
            });
        if uniform && n >= PAR_THRESHOLD && enc.groups.len() > 1 {
            let per = enc
                .groups
                .len()
                .div_ceil(pool::num_threads())
                .max(1);
            let chunk = per * group_len;
            pool::par_chunks_mut(out, chunk, |ci, chunk_out| {
                let base = ci * chunk;
                let mut scaled = Vec::with_capacity(k);
                let mut gi = ci * per;
                let mut off = 0usize;
                while off < chunk_out.len() {
                    let len = group_len.min(chunk_out.len() - off);
                    self.codebook.decode_block(
                        &enc.indices[base + off..base + off + len],
                        enc.scales[gi],
                        &mut chunk_out[off..off + len],
                        &mut scaled,
                    );
                    gi += 1;
                    off += len;
                }
            });
        } else {
            // running-cursor walk, exactly like decode_ref (group start
            // offsets are redundant with the lengths and are ignored on
            // both paths, so hand-built encodings cannot diverge)
            let mut scaled = Vec::with_capacity(k);
            let mut cursor = 0usize;
            for (gi, &(_, len)) in enc.groups.iter().enumerate() {
                self.codebook.decode_block(
                    &enc.indices[cursor..cursor + len],
                    enc.scales[gi],
                    &mut out[cursor..cursor + len],
                    &mut scaled,
                );
                cursor += len;
            }
        }
    }

    /// Reference reconstruction — the scalar per-element walk the fused
    /// [`Quantiser::decode_into`] kernel is property-tested against (and
    /// the `[dec-ref]` rows in `benches/formats.rs` time).  Kept verbatim
    /// as the oracle; not for hot paths.
    pub fn decode_ref(&self, enc: &Encoded) -> Vec<f32> {
        let n: usize = enc.groups.iter().map(|&(_, l)| l).sum();
        let mut out = Vec::with_capacity(n);
        let mut cursor = 0usize;
        for (gi, &(_, len)) in enc.groups.iter().enumerate() {
            let s = enc.scales[gi];
            for _ in 0..len {
                out.push(self.codebook.dequantise(enc.indices[cursor]) * s);
                cursor += 1;
            }
        }
        out
    }

    /// Fused quantise→dequantise (the hot path; no index materialisation).
    pub fn qdq(&self, data: &[f32], channel_len: usize) -> Vec<f32> {
        let mut out = data.to_vec();
        self.qdq_in_place(&mut out, channel_len);
        out
    }

    /// In-place fused qdq. Parallelised across scale groups for large
    /// tensors (the hot path of every direct-cast evaluation; see
    /// EXPERIMENTS.md §Perf).
    pub fn qdq_in_place(&self, data: &mut [f32], channel_len: usize) {
        use crate::util::pool::PAR_THRESHOLD;
        let n = data.len();
        match self.granularity {
            // block/channel groups are contiguous and independent: split
            // the buffer into group-aligned chunks and fan out
            Granularity::Block(b) if n >= PAR_THRESHOLD => {
                let threads = crate::util::pool::num_threads();
                let groups_per_chunk = n.div_ceil(b).div_ceil(threads).max(1);
                crate::util::pool::par_chunks_mut(
                    data,
                    groups_per_chunk * b,
                    |_, chunk| self.qdq_serial(chunk, Granularity::Block(b), 0),
                );
            }
            Granularity::Channel
                if n >= PAR_THRESHOLD && channel_len > 0 =>
            {
                let threads = crate::util::pool::num_threads();
                let per = n
                    .div_ceil(channel_len)
                    .div_ceil(threads)
                    .max(1);
                crate::util::pool::par_chunks_mut(
                    data,
                    per * channel_len,
                    |_, chunk| {
                        self.qdq_serial(chunk, Granularity::Channel, channel_len)
                    },
                );
            }
            // tensor granularity: one scale, then parallel fused chunks
            // (qdq_scaled_slice hoists the LUT dispatch per chunk)
            Granularity::Tensor if n >= PAR_THRESHOLD => {
                let s = self.group_scale(data);
                let inv = 1.0 / s;
                let chunk = n
                    .div_ceil(crate::util::pool::num_threads())
                    .max(1);
                crate::util::pool::par_chunks_mut(data, chunk, |_, c| {
                    self.codebook.qdq_scaled_slice(c, inv, s);
                });
            }
            g => self.qdq_serial(data, g, channel_len),
        }
    }

    fn qdq_serial(
        &self,
        data: &mut [f32],
        granularity: Granularity,
        channel_len: usize,
    ) {
        let groups = scale_groups(data.len(), granularity, channel_len);
        for &(start, len) in &groups {
            let block = &mut data[start..start + len];
            let s = self.group_scale(block);
            let inv = 1.0 / s;
            self.codebook.qdq_scaled_slice(block, inv, s);
        }
    }

    /// Average storage bits per element for a tensor of `n` elements.
    pub fn bits_per_element(&self, n: usize, channel_len: usize) -> f64 {
        self.codebook.storage_bits()
            + scale_overhead_bits(
                n,
                self.granularity,
                channel_len,
                self.scale_format,
                self.statistic,
            )
    }

    /// qdq + stats in one pass.
    pub fn evaluate(&self, data: &[f32], channel_len: usize) -> (Vec<f32>, QdqStats) {
        let recon = self.qdq(data, channel_len);
        let sq_err = crate::util::stats::sq_err(data, &recon);
        (
            recon,
            QdqStats {
                bits_per_element: self.bits_per_element(data.len(), channel_len),
                sq_err,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Family};
    use crate::formats::cbrt::{cbrt_absmax, cbrt_rms, CBRT_ALPHA};
    use crate::formats::int::int_codebook;
    use crate::formats::Variant;
    use crate::scaling::DEFAULT_SCALE;
    use crate::util::rng::Rng;
    use crate::util::stats::relative_rms_error;
    use crate::util::testing::{check, Gen};

    fn block_absmax_int4() -> Quantiser {
        Quantiser::new(
            Granularity::Block(64),
            Statistic::Absmax,
            DEFAULT_SCALE,
            int_codebook(4, Variant::Asymmetric),
        )
    }

    #[test]
    fn encode_decode_matches_qdq() {
        let mut rng = Rng::new(1);
        let data = Dist::standard(Family::Normal, 0.0).sample_vec(&mut rng, 1000);
        let q = block_absmax_int4();
        let enc = q.encode(&data, 0);
        let dec = q.decode(&enc);
        let direct = q.qdq(&data, 0);
        assert_eq!(dec, direct);
        // the fused kernel, the zero-copy entry point and the scalar
        // oracle are one bit pattern
        assert_eq!(dec, q.decode_ref(&enc));
        let mut buf = vec![0f32; data.len()];
        q.decode_into(&enc, &mut buf);
        assert_eq!(buf, dec);
    }

    #[test]
    fn decode_into_parallel_matches_serial_and_ref() {
        // above the parallel threshold the fanned-out decode must agree
        // bitwise with the forced-serial path (nested guard) and with the
        // scalar oracle, for multi-group and single-group (tensor) layouts
        let mut rng = Rng::new(31);
        let data = Dist::standard(Family::StudentT, 6.0)
            .sample_vec(&mut rng, 1 << 17);
        for q in [
            block_absmax_int4(),
            Quantiser::new(
                Granularity::Tensor,
                Statistic::Rms,
                ScaleFormat::F32,
                int_codebook(4, Variant::Symmetric),
            ),
        ] {
            let enc = q.encode(&data, 0);
            let mut par = vec![0f32; data.len()];
            q.decode_into(&enc, &mut par);
            let serial = crate::util::pool::par_map(&[0, 1], |i, _| {
                (i == 0).then(|| {
                    let mut out = vec![0f32; data.len()];
                    q.decode_into(&enc, &mut out);
                    out
                })
            })
            .swap_remove(0)
            .unwrap();
            assert_eq!(par, serial);
            assert_eq!(par, q.decode_ref(&enc));
        }
    }

    #[test]
    fn decode_into_handles_nonuniform_groups_and_mismatch() {
        // hand-built group layouts fall back to the serial walk and still
        // match the oracle; a wrong-length buffer panics
        let q = block_absmax_int4();
        let enc = Encoded {
            scales: vec![2.0, 0.5, 4.0],
            indices: vec![0, 3, 7, 15, 1, 2, 9],
            groups: vec![(0, 1), (1, 4), (5, 2)],
        };
        let mut out = vec![0f32; 7];
        q.decode_into(&enc, &mut out);
        assert_eq!(out, q.decode_ref(&enc));
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut short = vec![0f32; 6];
                q.decode_into(&enc, &mut short);
            }),
        );
        assert!(r.is_err(), "length mismatch must panic");
        // group starts are redundant with the lengths and ignored on both
        // paths: even inconsistent starts cannot diverge from the oracle
        let weird = Encoded {
            scales: vec![1.0, 2.0],
            indices: vec![1, 2, 3, 4, 5, 6, 7],
            groups: vec![(0, 4), (0, 3)],
        };
        let mut out = vec![0f32; 7];
        q.decode_into(&weird, &mut out);
        assert_eq!(out, q.decode_ref(&weird));
        // an oversized LAST group (internally consistent, but not a
        // scale_groups layout) must take the serial fallback above the
        // parallel threshold, not the chunked fan-out
        let big = 1 << 17;
        let enc = Encoded {
            scales: vec![2.0, 0.5],
            indices: vec![3u16; 64 + big],
            groups: vec![(0, 64), (64, big)],
        };
        let mut out = vec![0f32; 64 + big];
        q.decode_into(&enc, &mut out);
        assert_eq!(out, q.decode_ref(&enc));
    }

    #[test]
    fn encode_parallel_matches_serial_and_qdq() {
        // above the parallel threshold the fanned-out encode must agree
        // bitwise with the serial path (forced via the nested guard) and
        // with the fused qdq
        let mut rng = Rng::new(11);
        let data = Dist::standard(Family::StudentT, 6.0)
            .sample_vec(&mut rng, 1 << 17);
        let q = block_absmax_int4();
        let enc = q.encode(&data, 0);
        let serial = crate::util::pool::par_map(&[0, 1], |i, _| {
            if i == 0 {
                Some(q.encode(&data, 0))
            } else {
                None
            }
        })
        .swap_remove(0)
        .unwrap();
        assert_eq!(enc.indices, serial.indices);
        assert_eq!(enc.scales, serial.scales);
        assert_eq!(q.decode(&enc), q.qdq(&data, 0));
    }

    #[test]
    fn qdq_error_bounded_for_absmax() {
        // absmax + round-away: scaled data in [-1, 1]; error per element is
        // at most half the largest codepoint gap times the scale
        check("absmax-error-bound", 60, |g: &mut Gen| {
            let n = 64 * (1 + g.rng.below(8));
            let data = g.heavy_tailed_vec(n);
            let q = Quantiser::new(
                Granularity::Block(64),
                Statistic::Absmax,
                DEFAULT_SCALE,
                int_codebook(4, Variant::Symmetric),
            );
            let recon = q.qdq(&data, 0);
            for (start, len) in scale_groups(n, Granularity::Block(64), 0) {
                let block = &data[start..start + len];
                let s = crate::formats::float::round_to_bf16(
                    block.iter().fold(0f32, |m, &x| m.max(x.abs())),
                    true,
                );
                if s == 0.0 {
                    continue;
                }
                // symmetric INT4 gap = 2/15
                let bound = s * (1.0 / 15.0) + 1e-6;
                for (i, &x) in block.iter().enumerate() {
                    let err = (recon[start + i] - x).abs();
                    assert!(
                        err <= bound * 1.001,
                        "err {err} > bound {bound} (x={x}, s={s})"
                    );
                }
            }
        });
    }

    #[test]
    fn bits_accounting() {
        let q = block_absmax_int4();
        // 4 bits element + 16/64 scale
        assert!((q.bits_per_element(6400, 0) - 4.25).abs() < 1e-12);
        let qs = Quantiser::new(
            Granularity::Block(64),
            Statistic::Signmax,
            DEFAULT_SCALE,
            int_codebook(4, Variant::Signmax),
        );
        assert!((qs.bits_per_element(6400, 0) - 4.25 - 1.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn cbrt_beats_int_on_normal_rms() {
        // fig. 18's headline: non-uniform √[3]p beats INT for Normal data
        let mut rng = Rng::new(2);
        let data = Dist::standard(Family::Normal, 0.0)
            .sample_vec(&mut rng, 1 << 16);
        let q_cbrt = Quantiser::new(
            Granularity::Tensor,
            Statistic::Rms,
            ScaleFormat::F32,
            cbrt_rms(Family::Normal, 0.0, 4, Variant::Symmetric, CBRT_ALPHA),
        );
        let q_int = Quantiser::new(
            Granularity::Tensor,
            Statistic::Rms,
            ScaleFormat::F32,
            int_codebook(4, Variant::Symmetric),
        );
        // INT with RMS scaling needs a range multiplier to cover the tails;
        // moment matching for INT sets data RMS to (2^(b-1)-1)/sqrt(3)·gap...
        // use the paper's uniform-RMS convention: multiplier = sqrt(3)
        let q_int = q_int.with_multiplier(3f64.sqrt());
        let r_cbrt = relative_rms_error(&data, &q_cbrt.qdq(&data, 0));
        let r_int = relative_rms_error(&data, &q_int.qdq(&data, 0));
        assert!(
            r_cbrt < r_int,
            "cbrt {r_cbrt} should beat int {r_int} on normal data"
        );
    }

    #[test]
    fn block_absmax_cbrt_beats_tensor_rms_for_student_t() {
        // fig. 4 right panel, the paper's central surprise: block absmax
        // outperforms tensor-RMS optimal formats on heavy-tailed iid data
        let mut rng = Rng::new(3);
        let nu = 5.0;
        let data = Dist::standard(Family::StudentT, nu)
            .sample_vec(&mut rng, 1 << 16);
        let q_block = Quantiser::new(
            Granularity::Block(128),
            Statistic::Absmax,
            DEFAULT_SCALE,
            cbrt_absmax(Family::StudentT, nu, 4, 128, Variant::Symmetric, CBRT_ALPHA),
        );
        let q_rms = Quantiser::new(
            Granularity::Tensor,
            Statistic::Rms,
            ScaleFormat::F32,
            cbrt_rms(Family::StudentT, nu, 4, Variant::Symmetric, CBRT_ALPHA),
        );
        let r_block = relative_rms_error(&data, &q_block.qdq(&data, 0));
        let r_rms = relative_rms_error(&data, &q_rms.qdq(&data, 0));
        assert!(
            r_block < r_rms,
            "block absmax {r_block} should beat tensor RMS {r_rms}"
        );
    }

    #[test]
    fn signmax_statistic_normalises_max_to_plus_one() {
        let mut rng = Rng::new(4);
        let data = Dist::standard(Family::Normal, 0.0).sample_vec(&mut rng, 256);
        let q = Quantiser::new(
            Granularity::Block(64),
            Statistic::Signmax,
            ScaleFormat::F32,
            int_codebook(4, Variant::Signmax),
        );
        let recon = q.qdq(&data, 0);
        // every block max must be reconstructed exactly (codepoint +1)
        for (start, len) in scale_groups(256, Granularity::Block(64), 0) {
            let block = &data[start..start + len];
            let mut max_i = 0;
            for (i, &x) in block.iter().enumerate() {
                if x.abs() > block[max_i].abs() {
                    max_i = i;
                }
            }
            assert_eq!(
                recon[start + max_i], block[max_i],
                "block max must be exact under signmax"
            );
        }
    }

    #[test]
    fn channel_scaling_uses_channel_len() {
        let data: Vec<f32> = (0..64)
            .map(|i| if i < 32 { 0.01 } else { 100.0 } * ((i % 7) as f32 - 3.0))
            .collect();
        let q = Quantiser::new(
            Granularity::Channel,
            Statistic::Absmax,
            ScaleFormat::F32,
            int_codebook(4, Variant::Asymmetric),
        );
        let recon = q.qdq(&data, 32);
        let r = relative_rms_error(&data, &recon);
        // per-channel scales should handle the 10^4 dynamic range easily
        assert!(r < 0.1, "r = {r}");
        // tensor scaling drowns the small channel
        let qt = Quantiser::new(
            Granularity::Tensor,
            Statistic::Absmax,
            ScaleFormat::F32,
            int_codebook(4, Variant::Asymmetric),
        );
        let rt = relative_rms_error(&data, &qt.qdq(&data, 0));
        assert!(r < rt);
    }

    #[test]
    fn zero_tensor_is_fixed_point() {
        let data = vec![0f32; 256];
        let q = block_absmax_int4();
        assert_eq!(q.qdq(&data, 0), data);
    }

    #[test]
    fn encode_with_stats_matches_decode_and_histogram() {
        let mut rng = Rng::new(21);
        let data = Dist::standard(Family::Laplace, 0.0).sample_vec(&mut rng, 4096);
        let q = block_absmax_int4();
        let (enc, stats) = q.encode_with_stats(&data, 0);
        // histogram covers every element and matches the indices
        assert_eq!(stats.counts.len(), q.codebook.len());
        assert_eq!(
            stats.counts.iter().sum::<u64>() as usize,
            data.len()
        );
        let mut want = vec![0u64; q.codebook.len()];
        for &i in &enc.indices {
            want[i as usize] += 1;
        }
        assert_eq!(stats.counts, want);
        // fused squared error equals the decode-based one
        let recon = q.decode(&enc);
        let direct = crate::util::stats::sq_err(&data, &recon);
        assert!(
            (stats.sq_err - direct).abs() <= 1e-9 * direct.max(1.0),
            "fused {} vs direct {direct}",
            stats.sq_err
        );
        // encode() is the same kernel minus the stats
        let plain = q.encode(&data, 0);
        assert_eq!(plain.indices, enc.indices);
        assert_eq!(plain.scales, enc.scales);
    }

    #[test]
    fn encode_with_stats_parallel_partials_merge_in_order() {
        let mut rng = Rng::new(22);
        let data = Dist::standard(Family::StudentT, 6.0)
            .sample_vec(&mut rng, 1 << 17);
        let q = block_absmax_int4();
        let (enc, stats) = q.encode_with_stats(&data, 0);
        // forced-serial run (nested guard) must agree on everything except
        // possibly the f64 summation grouping of sq_err
        let (enc_s, stats_s) = crate::util::pool::par_map(&[0, 1], |i, _| {
            (i == 0).then(|| q.encode_with_stats(&data, 0))
        })
        .swap_remove(0)
        .unwrap();
        assert_eq!(enc.indices, enc_s.indices);
        assert_eq!(enc.scales, enc_s.scales);
        assert_eq!(stats.counts, stats_s.counts);
        assert!(
            (stats.sq_err - stats_s.sq_err).abs()
                <= 1e-9 * stats_s.sq_err.max(1.0)
        );
    }

    #[test]
    fn degenerate_scales_snap_to_one() {
        // NaN block: RMS statistic would be NaN — guard must neutralise it
        let q = Quantiser::new(
            Granularity::Block(64),
            Statistic::Rms,
            ScaleFormat::F32,
            int_codebook(4, Variant::Asymmetric),
        );
        let mut data = vec![f32::NAN; 64];
        data.extend(std::iter::repeat(0.5).take(64));
        let enc = q.encode(&data, 0);
        assert_eq!(enc.scales[0], 1.0, "NaN scale must snap to 1");
        assert!(enc.scales[1].is_finite() && enc.scales[1] > 0.0);
        // negative multiplier flips an absmax scale negative — also caught
        let qneg = Quantiser::new(
            Granularity::Block(64),
            Statistic::Absmax,
            ScaleFormat::F32,
            int_codebook(4, Variant::Asymmetric),
        )
        .with_multiplier(-2.0);
        let data = vec![0.25f32; 64];
        let enc = qneg.encode(&data, 0);
        assert_eq!(enc.scales[0], 1.0, "negative non-signmax scale snaps");
        // signmax scales legitimately carry the max's sign — preserved
        let qs = Quantiser::new(
            Granularity::Block(4),
            Statistic::Signmax,
            ScaleFormat::F32,
            int_codebook(4, Variant::Signmax),
        );
        let enc = qs.encode(&[0.1, -3.0, 0.2, 1.0], 0);
        assert_eq!(enc.scales[0], -3.0);
    }

    #[test]
    fn multiplier_trades_clipping_against_resolution() {
        // INT-with-RMS-scaling error is U-shaped in the quantiser range
        // multiplier (clipping ↔ resolution, fig. 23's premise): a
        // mid-range multiplier must beat both extremes.
        let mut rng = Rng::new(5);
        let data = Dist::standard(Family::Normal, 0.0).sample_vec(&mut rng, 4096);
        let base = Quantiser::new(
            Granularity::Tensor,
            Statistic::Rms,
            ScaleFormat::F32,
            int_codebook(4, Variant::Symmetric),
        );
        let r = |m: f64| {
            relative_rms_error(
                &data,
                &base.clone().with_multiplier(m).qdq(&data, 0),
            )
        };
        let (narrow, mid, wide) = (r(1.0), r(2.5), r(8.0));
        assert!(mid < narrow, "mid {mid} vs narrow {narrow}");
        assert!(mid < wide, "mid {mid} vs wide {wide}");
    }
}
