//! Range Asymmetric Numeral System (rANS) entropy coder — the second
//! practical compressor (fig. 24 compares practical coders against the
//! Shannon limit; rANS gets closer than Huffman on skewed distributions
//! because it is not integer-bit constrained).
//!
//! 32-bit state, 8-bit renormalisation, 12-bit quantised frequencies.
//! Symbols are encoded in reverse so decode is forward.
//!
//! Serving path: [`rans_encode_interleaved`] / [`rans_decode_interleaved`]
//! carry symbol `i` in state `i mod K`, K states renormalising round-robin
//! into ONE shared byte stream (the decoder's reads replay the encoder's
//! writes exactly in reverse, so no per-lane framing is needed — only the
//! lane count in the container header).  K independent decode chains hide
//! the div-free state-update latency behind each other.  `K == 1` emits a
//! bit-identical payload to the single-stream [`rans_encode`] oracle.

const PROB_BITS: u32 = 12;
const PROB_SCALE: u32 = 1 << PROB_BITS;
const RANS_LOW: u32 = 1 << 23;

/// The most *distinct seen* symbols a [`RansModel`] can represent: every
/// seen symbol keeps freq ≥ 1 out of 2^12 total slots, so an alphabet
/// with more seen symbols than slots cannot normalise (the builder
/// asserts).  Callers with unbounded alphabets (e.g. the artifact
/// writer's grid path) must check against this before choosing rANS.
pub const RANS_MAX_SYMBOLS: usize = PROB_SCALE as usize;

/// Frequency table quantised to 2^12, with cumulative offsets.
#[derive(Clone, Debug)]
pub struct RansModel {
    pub freq: Vec<u32>,
    pub cum: Vec<u32>,
    /// symbol lookup per slot (2^12 entries)
    slot_to_symbol: Vec<u16>,
}

impl RansModel {
    /// Quantise counts to a 2^12 total; every seen symbol keeps freq >= 1.
    pub fn from_counts(counts: &[u64]) -> RansModel {
        let n = counts.len();
        let total: u64 = counts.iter().sum();
        assert!(total > 0, "empty model");
        // initial proportional shares (floor), min 1 for non-zero counts
        let mut freq: Vec<u32> = counts
            .iter()
            .map(|&c| {
                if c == 0 {
                    0
                } else {
                    (((c as u128) * PROB_SCALE as u128 / total as u128)
                        as u32)
                        .max(1)
                }
            })
            .collect();
        // adjust to exactly PROB_SCALE by nudging the largest entries
        let mut sum: i64 = freq.iter().map(|&f| f as i64).sum();
        while sum != PROB_SCALE as i64 {
            let delta: i64 = if sum > PROB_SCALE as i64 { -1 } else { 1 };
            // pick the symbol with the largest freq (>1 when shrinking)
            let mut best = usize::MAX;
            for i in 0..n {
                if freq[i] == 0 {
                    continue;
                }
                if delta < 0 && freq[i] <= 1 {
                    continue;
                }
                if best == usize::MAX || freq[i] > freq[best] {
                    best = i;
                }
            }
            assert!(best != usize::MAX, "cannot normalise model");
            freq[best] = (freq[best] as i64 + delta) as u32;
            sum += delta;
        }
        let mut cum = vec![0u32; n + 1];
        for i in 0..n {
            cum[i + 1] = cum[i] + freq[i];
        }
        let mut slot_to_symbol = vec![0u16; PROB_SCALE as usize];
        for s in 0..n {
            for slot in cum[s]..cum[s + 1] {
                slot_to_symbol[slot as usize] = s as u16;
            }
        }
        RansModel {
            freq,
            cum,
            slot_to_symbol,
        }
    }
}

/// Encode a symbol stream; returns the compressed bytes.
pub fn rans_encode(model: &RansModel, symbols: &[u16]) -> Vec<u8> {
    let mut out: Vec<u8> = Vec::with_capacity(symbols.len());
    let mut state: u32 = RANS_LOW;
    // encode in reverse so the decoder emits forward
    for &s in symbols.iter().rev() {
        let f = model.freq[s as usize];
        assert!(f > 0, "symbol {s} not in model");
        let c = model.cum[s as usize];
        // renormalise: keep state < (RANS_LOW >> PROB_BITS << 8) * f
        let x_max = ((RANS_LOW >> PROB_BITS) << 8) * f;
        while state >= x_max {
            out.push((state & 0xFF) as u8);
            state >>= 8;
        }
        state = (state / f) * PROB_SCALE + (state % f) + c;
    }
    // flush 4 state bytes
    for _ in 0..4 {
        out.push((state & 0xFF) as u8);
        state >>= 8;
    }
    out.reverse();
    out
}

/// Encode with K interleaved rANS states into a lane-count-prefixed
/// container: `[K: u8][shared byte stream]`.  Symbol `i` updates state
/// `i mod K`; states renormalise round-robin into one stream, encoded in
/// reverse so the decoder runs forward.  `lanes == 1` reproduces the
/// [`rans_encode`] payload byte for byte.
pub fn rans_encode_interleaved(
    model: &RansModel,
    symbols: &[u16],
    lanes: usize,
) -> Vec<u8> {
    super::assert_lane_count(lanes);
    let mut out: Vec<u8> =
        Vec::with_capacity(symbols.len() + 4 * lanes + 1);
    let mut states = vec![RANS_LOW; lanes];
    for (i, &s) in symbols.iter().enumerate().rev() {
        let f = model.freq[s as usize];
        assert!(f > 0, "symbol {s} not in model");
        let c = model.cum[s as usize];
        let x_max = ((RANS_LOW >> PROB_BITS) << 8) * f;
        let state = &mut states[i % lanes];
        while *state >= x_max {
            out.push((*state & 0xFF) as u8);
            *state >>= 8;
        }
        *state = (*state / f) * PROB_SCALE + (*state % f) + c;
    }
    // flush lane K-1 first so lane 0's state bytes — then the header — are
    // at the front once the stream is reversed
    for k in (0..lanes).rev() {
        let mut st = states[k];
        for _ in 0..4 {
            out.push((st & 0xFF) as u8);
            st >>= 8;
        }
    }
    out.push(lanes as u8);
    out.reverse();
    out
}

/// Parse the `[K: u8][4 flushed state bytes]×K` container head; returns
/// `(lanes, initial states, stream cursor)`.  Panics on a container too
/// short to hold the header and the K flushed states.
fn parse_lane_header(data: &[u8]) -> (usize, Vec<u32>, usize) {
    assert!(!data.is_empty(), "interleaved container: missing header");
    let lanes = data[0] as usize;
    assert!(lanes >= 1, "interleaved container: zero lanes");
    assert!(
        data.len() >= 1 + 4 * lanes,
        "interleaved container: torn state flush ({} of {} bytes)",
        data.len(),
        1 + 4 * lanes
    );
    let mut pos = 1usize;
    let mut states = vec![0u32; lanes];
    for st in states.iter_mut() {
        for _ in 0..4 {
            *st = (*st << 8) | data[pos] as u32;
            pos += 1;
        }
    }
    (lanes, states, pos)
}

/// Decode `count` symbols from a [`rans_encode_interleaved`] container,
/// running the K states round-robin over the shared stream.  Decoding a
/// prefix (`count` below what was encoded) yields exactly the first
/// `count` symbols.  Panics on a container too short to hold the header
/// and the K flushed states.  Dispatches on the active ISA — see
/// [`rans_decode_interleaved_with`] for the contract.
pub fn rans_decode_interleaved(
    model: &RansModel,
    data: &[u8],
    count: usize,
) -> Vec<u16> {
    rans_decode_interleaved_with(
        model,
        data,
        count,
        crate::util::simd::active(),
    )
}

/// [`rans_decode_interleaved`] with an explicit ISA, for the forced-ISA
/// parity tests and benches.  `Isa::Scalar` runs the original per-symbol
/// loop verbatim (the oracle); AVX2 with K=8 (or NEON with K=4) runs
/// whole rounds with vectorised slot extraction, symbol/frequency
/// gathers and state updates.  Renormalisation stays a per-lane byte
/// feed in lane order — the K lanes share ONE stream whose byte order is
/// the encoder's reversed writes, so consumption is inherently
/// sequential — which is exactly why every path is bit- and
/// position-identical by construction.  Any (ISA, K) pair without a
/// vector kernel decodes on the scalar path.
pub fn rans_decode_interleaved_with(
    model: &RansModel,
    data: &[u8],
    count: usize,
    isa: crate::util::simd::Isa,
) -> Vec<u16> {
    let (lanes, mut states, mut pos) = parse_lane_header(data);
    let mut out = Vec::with_capacity(count);
    let start = decode_rounds_simd(
        model, &mut states, data, &mut pos, &mut out, count, isa,
    );
    for i in start..count {
        let state = &mut states[i % lanes];
        let slot = *state & (PROB_SCALE - 1);
        let s = model.slot_to_symbol[slot as usize];
        out.push(s);
        let f = model.freq[s as usize];
        let c = model.cum[s as usize];
        *state = f * (*state >> PROB_BITS) + slot - c;
        while *state < RANS_LOW && pos < data.len() {
            *state = (*state << 8) | data[pos] as u32;
            pos += 1;
        }
    }
    out
}

/// Run as many whole K-symbol rounds as the ISA's vector width allows;
/// returns how many symbols were emitted (0 when no vector kernel
/// matches, leaving everything to the scalar loop).  The state update
/// `f·(state >> 12) + slot − cum` uses wrapping vector arithmetic, which
/// is exact: for ANY u32 state and any normalised model, `f ≤ 2^12`,
/// `state >> 12 ≤ 2^20 − 1` and `slot − cum ≤ f − 1`, so the result is
/// at most `2^12·(2^20−1) + 2^12 − 1 = 2^32 − 1` — overflow is
/// impossible, corrupt input included (the checked decoder's
/// `checked_mul` guard is provably unreachable for the same reason).
#[allow(unused_variables, unused_imports)]
fn decode_rounds_simd(
    model: &RansModel,
    states: &mut [u32],
    data: &[u8],
    pos: &mut usize,
    out: &mut Vec<u16>,
    count: usize,
    isa: crate::util::simd::Isa,
) -> usize {
    use crate::util::simd::Isa;
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if states.len() == 8 && count >= 8 => {
            let rounds = count / 8;
            // u32 copy of the slot→symbol table: AVX2 gathers 32-bit
            // elements only (16 KiB, amortised over ≥ 8·rounds symbols)
            let sym32: Vec<u32> =
                model.slot_to_symbol.iter().map(|&s| s as u32).collect();
            let mut st = [0u32; 8];
            st.copy_from_slice(states);
            // SAFETY: Isa::Avx2 only resolves on hosts whose CPUID
            // reports AVX2 (util::simd::active/supported).
            unsafe {
                avx2_decode_rounds(
                    model, &sym32, &mut st, data, pos, out, rounds,
                );
            }
            states.copy_from_slice(&st);
            rounds * 8
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if states.len() == 4 && count >= 4 => {
            let rounds = count / 4;
            let mut st = [0u32; 4];
            st.copy_from_slice(states);
            // SAFETY: NEON is baseline on every aarch64 target.
            unsafe {
                neon_decode_rounds(model, &mut st, data, pos, out, rounds);
            }
            states.copy_from_slice(&st);
            rounds * 4
        }
        _ => 0,
    }
}

/// One AVX2 vector of 8 interleaved states: per round, slot extraction
/// (AND), symbol/freq/cum table gathers and the state update run as
/// 8-lane vector ops; the renormalisation byte feed then runs lane
/// 0..7 in order from the shared stream (see
/// [`rans_decode_interleaved_with`] — sequential by format design).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_decode_rounds(
    model: &RansModel,
    sym32: &[u32],
    states: &mut [u32; 8],
    data: &[u8],
    pos: &mut usize,
    out: &mut Vec<u16>,
    rounds: usize,
) {
    use core::arch::x86_64::*;
    let mask = _mm256_set1_epi32((PROB_SCALE - 1) as i32);
    let mut st = _mm256_loadu_si256(states.as_ptr() as *const __m256i);
    let mut stbuf = [0u32; 8];
    let mut symbuf = [0u32; 8];
    for _ in 0..rounds {
        let slots = _mm256_and_si256(st, mask);
        // slots < 2^12 = sym32.len(); gathered symbols index freq/cum
        // in range by model construction (cum has freq.len()+1 entries)
        let syms = _mm256_i32gather_epi32::<4>(
            sym32.as_ptr() as *const i32,
            slots,
        );
        let freqs = _mm256_i32gather_epi32::<4>(
            model.freq.as_ptr() as *const i32,
            syms,
        );
        let cums = _mm256_i32gather_epi32::<4>(
            model.cum.as_ptr() as *const i32,
            syms,
        );
        // state' = f·(state >> PROB_BITS) + slot − cum; wrapping vector
        // ops are exact — overflow is impossible (see decode_rounds_simd)
        let upd = _mm256_add_epi32(
            _mm256_mullo_epi32(freqs, _mm256_srli_epi32::<12>(st)),
            _mm256_sub_epi32(slots, cums),
        );
        _mm256_storeu_si256(symbuf.as_mut_ptr() as *mut __m256i, syms);
        for &s in &symbuf {
            out.push(s as u16);
        }
        _mm256_storeu_si256(stbuf.as_mut_ptr() as *mut __m256i, upd);
        for s in stbuf.iter_mut() {
            while *s < RANS_LOW && *pos < data.len() {
                *s = (*s << 8) | data[*pos] as u32;
                *pos += 1;
            }
        }
        st = _mm256_loadu_si256(stbuf.as_ptr() as *const __m256i);
    }
    _mm256_storeu_si256(states.as_mut_ptr() as *mut __m256i, st);
}

/// One NEON vector of 4 interleaved states: slot extraction and the
/// state update are 4-lane vector ops; NEON has no hardware gather, so
/// the table lookups stay scalar, and renormalisation feeds lanes
/// 0..3 in order like the oracle.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_decode_rounds(
    model: &RansModel,
    states: &mut [u32; 4],
    data: &[u8],
    pos: &mut usize,
    out: &mut Vec<u16>,
    rounds: usize,
) {
    use core::arch::aarch64::*;
    let mask = vdupq_n_u32(PROB_SCALE - 1);
    let mut st = vld1q_u32(states.as_ptr());
    let mut slotbuf = [0u32; 4];
    let mut fbuf = [0u32; 4];
    let mut cbuf = [0u32; 4];
    let mut stbuf = [0u32; 4];
    for _ in 0..rounds {
        let slots = vandq_u32(st, mask);
        vst1q_u32(slotbuf.as_mut_ptr(), slots);
        for k in 0..4 {
            let s = model.slot_to_symbol[slotbuf[k] as usize];
            out.push(s);
            fbuf[k] = model.freq[s as usize];
            cbuf[k] = model.cum[s as usize];
        }
        let upd = vaddq_u32(
            vmulq_u32(vld1q_u32(fbuf.as_ptr()), vshrq_n_u32::<12>(st)),
            vsubq_u32(slots, vld1q_u32(cbuf.as_ptr())),
        );
        vst1q_u32(stbuf.as_mut_ptr(), upd);
        for s in stbuf.iter_mut() {
            while *s < RANS_LOW && *pos < data.len() {
                *s = (*s << 8) | data[*pos] as u32;
                *pos += 1;
            }
        }
        st = vld1q_u32(stbuf.as_ptr());
    }
    vst1q_u32(states.as_mut_ptr(), st);
}

/// Decode exactly `count` symbols and verify stream integrity end to end:
/// after the final symbol, every lane state must return to the encoder's
/// initial `RANS_LOW` and every stream byte must be consumed.  The
/// unchecked decoder yields garbage without complaint when the trailing
/// bytes are damaged or `count` disagrees with what was encoded; serving
/// paths (the `OWQ1` artifact reader) use this variant so such damage
/// surfaces as an error instead of silently wrong indices.  Asserts on a
/// torn header exactly like [`rans_decode_interleaved`] — callers contain
/// panics at the artifact boundary.
pub fn rans_decode_interleaved_checked(
    model: &RansModel,
    data: &[u8],
    count: usize,
) -> Result<Vec<u16>, String> {
    rans_decode_interleaved_checked_with(
        model,
        data,
        count,
        crate::util::simd::active(),
    )
}

/// [`rans_decode_interleaved_checked`] with an explicit ISA (forced-ISA
/// parity tests).  The vector fast path is safe here too: its wrapping
/// state update cannot overflow for any input (see
/// [`decode_rounds_simd`]), so the scalar loop's `checked_mul` guard —
/// kept verbatim below as the oracle — can never observe a failure the
/// vector path would miss, and the final-state/full-consumption checks
/// run identically on both.
pub fn rans_decode_interleaved_checked_with(
    model: &RansModel,
    data: &[u8],
    count: usize,
    isa: crate::util::simd::Isa,
) -> Result<Vec<u16>, String> {
    let (lanes, mut states, mut pos) = parse_lane_header(data);
    let mut out = Vec::with_capacity(count);
    let start = decode_rounds_simd(
        model, &mut states, data, &mut pos, &mut out, count, isa,
    );
    for i in start..count {
        let state = &mut states[i % lanes];
        let slot = *state & (PROB_SCALE - 1);
        let s = model.slot_to_symbol[slot as usize];
        out.push(s);
        let f = model.freq[s as usize];
        let c = model.cum[s as usize];
        *state = f
            .checked_mul(*state >> PROB_BITS)
            .and_then(|x| x.checked_add(slot - c))
            .ok_or_else(|| {
                format!("rANS lane {} state overflow (corrupt stream)", i % lanes)
            })?;
        while *state < RANS_LOW && pos < data.len() {
            *state = (*state << 8) | data[pos] as u32;
            pos += 1;
        }
    }
    if pos != data.len() {
        return Err(format!(
            "rANS stream under-consumed: {pos} of {} bytes after {count} \
             symbols (payload encodes more than expected)",
            data.len()
        ));
    }
    for (k, st) in states.iter().enumerate() {
        if *st != RANS_LOW {
            return Err(format!(
                "rANS lane {k} final state {st:#x} != {RANS_LOW:#x} \
                 (corrupt or mis-counted stream)"
            ));
        }
    }
    Ok(out)
}

/// Decode `count` symbols.
pub fn rans_decode(model: &RansModel, data: &[u8], count: usize) -> Vec<u16> {
    let mut pos = 0usize;
    let mut state: u32 = 0;
    for _ in 0..4 {
        state = (state << 8) | data[pos] as u32;
        pos += 1;
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let slot = state & (PROB_SCALE - 1);
        let s = model.slot_to_symbol[slot as usize];
        out.push(s);
        let f = model.freq[s as usize];
        let c = model.cum[s as usize];
        state = f * (state >> PROB_BITS) + slot - c;
        while state < RANS_LOW && pos < data.len() {
            state = (state << 8) | data[pos] as u32;
            pos += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::entropy_bits;
    use crate::util::rng::Rng;
    use crate::util::testing::{check, Gen};

    fn random_stream(
        counts: &[u64],
        len: usize,
        rng: &mut Rng,
    ) -> Vec<u16> {
        let w: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        (0..len).map(|_| rng.categorical(&w) as u16).collect()
    }

    #[test]
    fn model_normalises_exactly() {
        let m = RansModel::from_counts(&[3, 0, 1, 1000, 7]);
        assert_eq!(m.freq.iter().sum::<u32>(), PROB_SCALE);
        assert_eq!(m.freq[1], 0);
        assert!(m.freq.iter().enumerate().all(|(i, &f)| f >= 1 || i == 1));
    }

    #[test]
    fn roundtrip() {
        let counts = [100u64, 37, 4, 1, 220];
        let model = RansModel::from_counts(&counts);
        let mut rng = Rng::new(1);
        let stream = random_stream(&counts, 10_000, &mut rng);
        let enc = rans_encode(&model, &stream);
        let dec = rans_decode(&model, &enc, stream.len());
        assert_eq!(dec, stream);
    }

    #[test]
    fn checked_decode_agrees_and_rejects_damage() {
        let counts = [90u64, 31, 6, 2, 140, 11];
        let model = RansModel::from_counts(&counts);
        let mut rng = Rng::new(9);
        let stream = random_stream(&counts, 4_000, &mut rng);
        for lanes in [1usize, 3, 8] {
            let enc = rans_encode_interleaved(&model, &stream, lanes);
            // intact: agrees with the unchecked decoder, byte for byte
            let ok =
                rans_decode_interleaved_checked(&model, &enc, stream.len())
                    .unwrap();
            assert_eq!(
                ok,
                rans_decode_interleaved(&model, &enc, stream.len())
            );
            assert_eq!(ok, stream);
            // mis-counted: asking for fewer symbols than encoded must
            // error (the unchecked decoder would happily return a prefix)
            let short = rans_decode_interleaved_checked(
                &model,
                &enc,
                stream.len() - 1,
            );
            assert!(short.is_err(), "lanes {lanes}: undercount accepted");
            // trailing truncation: drop the final stream byte
            let torn = &enc[..enc.len() - 1];
            let r = rans_decode_interleaved_checked(
                &model,
                torn,
                stream.len(),
            );
            assert!(r.is_err(), "lanes {lanes}: torn tail accepted");
        }
    }

    #[test]
    fn compression_near_entropy() {
        // on a very skewed distribution rANS should land within ~2% of H
        let counts = [10_000u64, 500, 100, 20, 5, 1];
        let model = RansModel::from_counts(&counts);
        let mut rng = Rng::new(2);
        let stream = random_stream(&counts, 100_000, &mut rng);
        let mut sc = vec![0u64; counts.len()];
        for &s in &stream {
            sc[s as usize] += 1;
        }
        let h = entropy_bits(&sc);
        let enc = rans_encode(&model, &stream);
        let rate = enc.len() as f64 * 8.0 / stream.len() as f64;
        assert!(
            rate < h * 1.03 + 0.05,
            "rate {rate} vs entropy {h}"
        );
    }

    #[test]
    fn roundtrip_property() {
        check("rans-roundtrip", 30, |g: &mut Gen| {
            let n_symbols = 2 + g.rng.below(40);
            let counts: Vec<u64> = (0..n_symbols)
                .map(|_| g.rng.below(1000) as u64 + 1)
                .collect();
            let model = RansModel::from_counts(&counts);
            let len = 1 + g.rng.below(2000);
            let stream = random_stream(&counts, len, &mut g.rng);
            let enc = rans_encode(&model, &stream);
            assert_eq!(rans_decode(&model, &enc, len), stream);
        });
    }

    #[test]
    fn empty_stream() {
        let model = RansModel::from_counts(&[1, 1]);
        let enc = rans_encode(&model, &[]);
        assert_eq!(rans_decode(&model, &enc, 0), Vec::<u16>::new());
    }

    #[test]
    fn interleaved_roundtrips_and_single_lane_is_bit_identical() {
        let counts = [100u64, 37, 4, 1, 220];
        let model = RansModel::from_counts(&counts);
        let mut rng = Rng::new(9);
        let stream = random_stream(&counts, 5000, &mut rng);
        let oracle = rans_encode(&model, &stream);
        for lanes in [1usize, 2, 4, 8] {
            let container =
                rans_encode_interleaved(&model, &stream, lanes);
            assert_eq!(container[0] as usize, lanes);
            assert_eq!(
                rans_decode_interleaved(&model, &container, stream.len()),
                stream,
                "lanes={lanes}"
            );
            // prefix decode yields exactly the head of the stream
            let short = stream.len() / 3;
            assert_eq!(
                rans_decode_interleaved(&model, &container, short),
                stream[..short],
                "lanes={lanes} short"
            );
        }
        // K=1 wraps the oracle payload byte for byte
        let one = rans_encode_interleaved(&model, &stream, 1);
        assert_eq!(&one[1..], &oracle[..]);
    }

    #[test]
    fn interleaved_empty_and_torn() {
        let model = RansModel::from_counts(&[3, 1]);
        let enc = rans_encode_interleaved(&model, &[], 4);
        assert_eq!(enc.len(), 1 + 16, "4 flushed states + header");
        assert_eq!(
            rans_decode_interleaved(&model, &enc, 0),
            Vec::<u16>::new()
        );
        for cut in [0usize, 1, 9] {
            let torn = enc[..cut].to_vec();
            let r = std::panic::catch_unwind(|| {
                rans_decode_interleaved(&model, &torn, 0)
            });
            assert!(r.is_err(), "cut at {cut} must panic");
        }
    }
}
