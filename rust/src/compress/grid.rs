//! The entropy-constrained uniform-grid quantiser (§2.3, appendix B.3): the
//! RMS-optimal quantiser under an entropy constraint is a uniform lattice
//! whose resolution δ trades error against compressed size.  The practical
//! recipe (B.1): pick δ, count bucket populations, entropy-code; wrap in a
//! search over δ to hit a target bits/element.

use crate::compress::{entropy_bits, smoothed_probs};
use crate::dist::fit::golden_section;

/// A uniform grid quantiser: codepoints { δ·k : k ∈ ℤ }, clamped to
/// ±`max_buckets/2` buckets to bound table sizes (clamping error is
/// negligible for the δ regimes the search visits).
#[derive(Clone, Copy, Debug)]
pub struct UniformGrid {
    pub delta: f64,
    pub max_buckets: usize,
}

impl UniformGrid {
    pub fn new(delta: f64) -> UniformGrid {
        UniformGrid {
            delta,
            max_buckets: 1 << 16,
        }
    }

    #[inline]
    fn half(&self) -> i64 {
        (self.max_buckets / 2) as i64
    }

    /// Bucket index of x (offset so indices are non-negative).
    #[inline]
    pub fn quantise(&self, x: f32) -> u16 {
        let k = (x as f64 / self.delta).round() as i64;
        (k.clamp(-self.half(), self.half() - 1) + self.half()) as u16
    }

    #[inline]
    pub fn dequantise(&self, idx: u16) -> f32 {
        ((idx as i64 - self.half()) as f64 * self.delta) as f32
    }

    #[inline]
    pub fn qdq(&self, x: f32) -> f32 {
        self.dequantise(self.quantise(x))
    }

    /// Quantise a slice, returning (indices, squared error).
    pub fn encode(&self, data: &[f32]) -> (Vec<u16>, f64) {
        let mut sq = 0.0f64;
        let idx = data
            .iter()
            .map(|&x| {
                let i = self.quantise(x);
                let d = x as f64 - self.dequantise(i) as f64;
                sq += d * d;
                i
            })
            .collect();
        (idx, sq)
    }

    /// Histogram over occupied buckets, re-indexed densely.
    /// Returns (dense counts, dense symbol per element).
    /// Flat-indexed tables (not a HashMap) — this sits inside the δ
    /// search loop of `grid_for_target_bits` (see EXPERIMENTS.md §Perf).
    ///
    /// The slot table is u32 with a `u32::MAX` sentinel: a u16 sentinel
    /// would collide with dense slot 65535 at full occupancy (all 2^16
    /// buckets seen), re-assigning that bucket a fresh — and silently
    /// truncated — slot on every occurrence.  With `max_buckets = 2^16`
    /// the largest possible dense slot is 65535, so every assigned slot
    /// still fits the u16 symbols the entropy coders consume.
    pub fn dense_histogram(&self, indices: &[u16]) -> (Vec<u64>, Vec<u16>) {
        let mut slot_of = vec![u32::MAX; self.max_buckets];
        let mut counts: Vec<u64> = Vec::new();
        // assign dense slots in first-occurrence order to stay
        // deterministic w.r.t. the previous implementation's semantics
        let mut dense = Vec::with_capacity(indices.len());
        for &i in indices {
            let slot = &mut slot_of[i as usize];
            if *slot == u32::MAX {
                *slot = counts.len() as u32;
                counts.push(0);
            }
            counts[*slot as usize] += 1;
            dense.push(*slot as u16);
        }
        (counts, dense)
    }

    /// Fast path for the δ search: bucket-count histogram only (no dense
    /// remap, no per-element output).
    pub fn count_histogram(&self, data: &[f32]) -> (Vec<u64>, f64) {
        let mut counts = Vec::new();
        let sq = self.occupied_histogram_into(data, &mut counts);
        (counts, sq)
    }

    /// [`UniformGrid::occupied_histogram_ranged`] with the data extremes
    /// computed inline — for one-shot callers.  δ searches should compute
    /// [`data_extremes`] once and call the ranged form per probe, since
    /// the extremes do not depend on δ.
    pub fn occupied_histogram_into(
        &self,
        data: &[f32],
        counts: &mut Vec<u64>,
    ) -> f64 {
        let (xmin, xmax) = data_extremes(data);
        self.occupied_histogram_ranged(data, counts, xmin, xmax)
    }

    /// The fused histogram kernel: quantise, reconstruct and count in a
    /// single walk, into a window covering only the *occupied* bucket
    /// range (the full 2^16 table made every δ probe allocate and zero
    /// 512 KiB, which dominated small sweeps).  `counts` is reused caller
    /// storage and `(xmin, xmax)` the precomputed [`data_extremes`];
    /// zeros outside the window contribute nothing to the entropy, so
    /// `entropy_bits(counts)` is unchanged.  Returns the squared error,
    /// bit-identical to the unfused quantise→dequantise accumulation.
    pub fn occupied_histogram_ranged(
        &self,
        data: &[f32],
        counts: &mut Vec<u64>,
        xmin: f32,
        xmax: f32,
    ) -> f64 {
        let half = self.half();
        // bucket bounds from the data extremes (quantise is monotone, so
        // these bracket every finite element; NaN ignored by min/max and
        // clamped into the window below, matching its old bucket-0 fate
        // closely enough for an entropy model)
        let (kmin, kmax) = if xmin <= xmax {
            (
                ((xmin as f64 / self.delta).round() as i64)
                    .clamp(-half, half - 1),
                ((xmax as f64 / self.delta).round() as i64)
                    .clamp(-half, half - 1),
            )
        } else {
            (0, 0) // empty or all-NaN input: single degenerate bucket
        };
        let width = (kmax - kmin + 1) as usize;
        counts.clear();
        counts.resize(width, 0);
        let mut sq = 0f64;
        for &x in data {
            let k = ((x as f64 / self.delta).round() as i64)
                .clamp(-half, half - 1)
                .clamp(kmin, kmax);
            counts[(k - kmin) as usize] += 1;
            // reconstruct through f32 exactly as dequantise() does
            let recon = (k as f64 * self.delta) as f32;
            let d = x as f64 - recon as f64;
            sq += d * d;
        }
        sq
    }
}

/// Min/max of a tensor (NaN-ignoring) — the δ-independent input to the
/// occupied-bucket window, computed once per tensor and shared across all
/// probes of a δ search.  Returns `(+inf, -inf)` for empty/all-NaN data.
pub fn data_extremes(data: &[f32]) -> (f32, f32) {
    let (mut xmin, mut xmax) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in data {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
    }
    (xmin, xmax)
}

/// Result of compressing a tensor with a uniform grid + ideal entropy coder.
#[derive(Clone, Copy, Debug)]
pub struct GridResult {
    pub delta: f64,
    /// Shannon-limit bits/element (+1-smoothed sample model, §C).
    pub bits_per_element: f64,
    pub sq_err: f64,
}

/// Evaluate one δ under the Shannon-limit model.
pub fn evaluate_grid(data: &[f32], delta: f64) -> GridResult {
    let mut scratch = Vec::new();
    let (xmin, xmax) = data_extremes(data);
    evaluate_grid_scratch(data, delta, &mut scratch, xmin, xmax)
}

/// [`evaluate_grid`] with caller-owned histogram storage and precomputed
/// extremes — the δ search probes dozens of resolutions and reuses one
/// buffer and one min/max pass across all of them.
fn evaluate_grid_scratch(
    data: &[f32],
    delta: f64,
    scratch: &mut Vec<u64>,
    xmin: f32,
    xmax: f32,
) -> GridResult {
    let grid = UniformGrid::new(delta);
    let sq_err = grid.occupied_histogram_ranged(data, scratch, xmin, xmax);
    GridResult {
        delta,
        bits_per_element: entropy_bits(scratch),
        sq_err,
    }
}

/// Evaluate one δ but model probabilities from a *different* sample
/// (§C: "a sampling-based method to calculate the model p^Q with a fresh
/// set of samples"), charging the cross-entropy rate.
pub fn evaluate_grid_with_model(
    data: &[f32],
    model_data: &[f32],
    delta: f64,
) -> GridResult {
    let grid = UniformGrid::new(delta);
    let (indices, sq_err) = grid.encode(data);
    let (model_idx, _) = grid.encode(model_data);
    // shared dense mapping: build from the union
    let mut union = model_idx.clone();
    union.extend_from_slice(&indices);
    let (_, dense_union) = grid.dense_histogram(&union);
    let n_model = model_idx.len();
    let n_slots = *dense_union.iter().max().unwrap_or(&0) as usize + 1;
    let mut model_counts = vec![0u64; n_slots];
    for &s in &dense_union[..n_model] {
        model_counts[s as usize] += 1;
    }
    let probs = smoothed_probs(&model_counts);
    let bits: f64 = dense_union[n_model..]
        .iter()
        .map(|&s| -probs[s as usize].log2())
        .sum();
    GridResult {
        delta,
        bits_per_element: bits / data.len() as f64,
        sq_err,
    }
}

/// Search δ so the Shannon-limit rate hits `target_bits` per element.
/// Probe evaluations are memoised by the δ bit pattern (golden-section
/// revisits its bracket ends and the final winner) and share one
/// histogram scratch buffer, so each distinct δ costs exactly one fused
/// pass over the data.
pub fn grid_for_target_bits(data: &[f32], target_bits: f64) -> GridResult {
    use std::cell::RefCell;
    use std::collections::HashMap;
    let rms = crate::util::stats::rms(data).max(1e-12);
    // High-rate heuristic: H ≈ h(p) - log2 δ ⇒ δ ≈ rms · 2^-b · c.
    let centre = rms * 2f64.powf(-target_bits) * 3.5;
    let (lo, hi) = (centre.ln() - 2.5, centre.ln() + 2.5);
    let (xmin, xmax) = data_extremes(data); // one min/max pass, all probes
    let state: RefCell<(HashMap<u64, GridResult>, Vec<u64>)> =
        RefCell::new((HashMap::new(), Vec::new()));
    let eval = |ldelta: f64| -> GridResult {
        let key = ldelta.to_bits();
        let mut guard = state.borrow_mut();
        if let Some(r) = guard.0.get(&key) {
            return *r;
        }
        let (memo, scratch) = &mut *guard;
        let r = evaluate_grid_scratch(
            data,
            ldelta.exp(),
            scratch,
            xmin,
            xmax,
        );
        memo.insert(key, r);
        r
    };
    let objective = |ldelta: f64| {
        (eval(ldelta).bits_per_element - target_bits).powi(2)
    };
    let (best, _) = golden_section(lo, hi, 30, &objective);
    eval(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Family};
    use crate::util::rng::Rng;
    use crate::util::stats::relative_rms_error;

    #[test]
    fn qdq_error_bounded_by_half_delta() {
        let grid = UniformGrid::new(0.25);
        for i in -100..100 {
            let x = i as f32 * 0.037;
            assert!((grid.qdq(x) - x).abs() <= 0.1251);
        }
    }

    #[test]
    fn target_bits_search_converges() {
        let mut rng = Rng::new(1);
        let data = Dist::standard(Family::Normal, 0.0)
            .sample_vec(&mut rng, 1 << 16);
        for target in [2.0, 3.0, 4.0, 5.0] {
            let r = grid_for_target_bits(&data, target);
            assert!(
                (r.bits_per_element - target).abs() < 0.05,
                "target {target}: got {}",
                r.bits_per_element
            );
        }
    }

    #[test]
    fn grid_beats_fixed_length_at_equal_bits() {
        // §2.3's punchline: uniform grid + entropy coding beats the optimal
        // fixed-length (cbrt) code at the same bits/element.
        let mut rng = Rng::new(2);
        let data = Dist::standard(Family::Normal, 0.0)
            .sample_vec(&mut rng, 1 << 16);
        let r = grid_for_target_bits(&data, 4.0);
        let grid_rmse = (r.sq_err / data.len() as f64).sqrt();
        // optimal fixed-length 4-bit
        let cb = crate::formats::cbrt::cbrt_rms(
            Family::Normal, 0.0, 4,
            crate::formats::Variant::Symmetric, 1.0 / 3.0,
        );
        let recon: Vec<f32> = data.iter().map(|&x| cb.qdq(x)).collect();
        let fixed_r = relative_rms_error(&data, &recon);
        assert!(
            grid_rmse < fixed_r,
            "grid {grid_rmse} should beat fixed {fixed_r} at 4 bits"
        );
    }

    #[test]
    fn fresh_sample_model_costs_little() {
        let mut rng = Rng::new(3);
        let d = Dist::standard(Family::StudentT, 5.0);
        let data = d.sample_vec(&mut rng, 1 << 15);
        let model = d.sample_vec(&mut rng, 1 << 15);
        let ideal = evaluate_grid(&data, 0.1);
        let sampled = evaluate_grid_with_model(&data, &model, 0.1);
        assert!(sampled.bits_per_element >= ideal.bits_per_element - 0.02);
        assert!(
            sampled.bits_per_element < ideal.bits_per_element + 0.15,
            "sampled {} vs ideal {}",
            sampled.bits_per_element,
            ideal.bits_per_element
        );
    }

    #[test]
    fn occupied_histogram_matches_naive_reference() {
        let mut rng = Rng::new(9);
        let data = Dist::standard(Family::StudentT, 5.0)
            .sample_vec(&mut rng, 4096);
        for delta in [0.01, 0.1, 1.0] {
            let grid = UniformGrid::new(delta);
            let mut counts = Vec::new();
            let sq = grid.occupied_histogram_into(&data, &mut counts);
            // naive reference: full-table quantise→dequantise accumulation
            let mut full = vec![0u64; grid.max_buckets];
            let mut want_sq = 0f64;
            for &x in &data {
                let i = grid.quantise(x);
                full[i as usize] += 1;
                let d = x as f64 - grid.dequantise(i) as f64;
                want_sq += d * d;
            }
            assert_eq!(sq, want_sq, "sq must be bit-identical at δ={delta}");
            assert_eq!(
                crate::compress::entropy_bits(&counts),
                crate::compress::entropy_bits(&full),
                "windowing must not change the entropy at δ={delta}"
            );
            // the window holds exactly the occupied buckets, in order
            let nonzero: Vec<u64> =
                full.iter().copied().filter(|&c| c > 0).collect();
            let windowed: Vec<u64> =
                counts.iter().copied().filter(|&c| c > 0).collect();
            assert_eq!(nonzero, windowed);
        }
    }

    #[test]
    fn dense_histogram_full_occupancy_has_no_sentinel_collision() {
        // regression: with all 2^16 buckets occupied, the old u16 slot
        // table's `u16::MAX` sentinel collided with dense slot 65535, so
        // that bucket was re-assigned a fresh (truncated) slot on every
        // occurrence and the counts table grew without bound
        let grid = UniformGrid::new(1.0);
        let mut idx: Vec<u16> = (0..=u16::MAX).collect();
        idx.extend(0..=u16::MAX); // second pass must *reuse* every slot
        let (counts, dense) = grid.dense_histogram(&idx);
        assert_eq!(counts.len(), 1 << 16);
        assert!(counts.iter().all(|&c| c == 2));
        let n = 1usize << 16;
        for i in 0..n {
            // first-occurrence order ⇒ slot i is bucket i here, and the
            // second occurrence maps to the same slot
            assert_eq!(dense[i] as usize, i);
            assert_eq!(dense[n + i] as usize, i);
        }
    }

    #[test]
    fn dense_histogram_consistency() {
        let grid = UniformGrid::new(0.5);
        let data = [0.0f32, 0.4, 1.0, -1.0, 0.1, 1.1];
        let (idx, _) = grid.encode(&data);
        let (counts, dense) = grid.dense_histogram(&idx);
        assert_eq!(counts.iter().sum::<u64>() as usize, data.len());
        assert_eq!(dense.len(), data.len());
        // same raw index ⇒ same dense symbol
        for i in 0..data.len() {
            for j in 0..data.len() {
                assert_eq!(idx[i] == idx[j], dense[i] == dense[j]);
            }
        }
    }
}
