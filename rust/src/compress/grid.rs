//! The entropy-constrained uniform-grid quantiser (§2.3, appendix B.3): the
//! RMS-optimal quantiser under an entropy constraint is a uniform lattice
//! whose resolution δ trades error against compressed size.  The practical
//! recipe (B.1): pick δ, count bucket populations, entropy-code; wrap in a
//! search over δ to hit a target bits/element.

use crate::compress::{entropy_bits, smoothed_probs};
use crate::dist::fit::golden_section;

/// A uniform grid quantiser: codepoints { δ·k : k ∈ ℤ }, clamped to
/// ±`max_buckets/2` buckets to bound table sizes (clamping error is
/// negligible for the δ regimes the search visits).
#[derive(Clone, Copy, Debug)]
pub struct UniformGrid {
    pub delta: f64,
    pub max_buckets: usize,
}

impl UniformGrid {
    pub fn new(delta: f64) -> UniformGrid {
        UniformGrid {
            delta,
            max_buckets: 1 << 16,
        }
    }

    #[inline]
    fn half(&self) -> i64 {
        (self.max_buckets / 2) as i64
    }

    /// Bucket index of x (offset so indices are non-negative).
    #[inline]
    pub fn quantise(&self, x: f32) -> u16 {
        let k = (x as f64 / self.delta).round() as i64;
        (k.clamp(-self.half(), self.half() - 1) + self.half()) as u16
    }

    #[inline]
    pub fn dequantise(&self, idx: u16) -> f32 {
        ((idx as i64 - self.half()) as f64 * self.delta) as f32
    }

    #[inline]
    pub fn qdq(&self, x: f32) -> f32 {
        self.dequantise(self.quantise(x))
    }

    /// Quantise a slice, returning (indices, squared error).
    pub fn encode(&self, data: &[f32]) -> (Vec<u16>, f64) {
        let mut sq = 0.0f64;
        let idx = data
            .iter()
            .map(|&x| {
                let i = self.quantise(x);
                let d = x as f64 - self.dequantise(i) as f64;
                sq += d * d;
                i
            })
            .collect();
        (idx, sq)
    }

    /// Histogram over occupied buckets, re-indexed densely.
    /// Returns (dense counts, dense symbol per element).
    /// Flat u16-indexed tables (not a HashMap) — this sits inside the δ
    /// search loop of `grid_for_target_bits` (see EXPERIMENTS.md §Perf).
    pub fn dense_histogram(&self, indices: &[u16]) -> (Vec<u64>, Vec<u16>) {
        let mut raw_counts = vec![0u64; self.max_buckets];
        for &i in indices {
            raw_counts[i as usize] += 1;
        }
        let mut slot_of = vec![u16::MAX; self.max_buckets];
        let mut counts: Vec<u64> = Vec::new();
        // assign dense slots in first-occurrence order to stay
        // deterministic w.r.t. the previous implementation's semantics
        let mut dense = Vec::with_capacity(indices.len());
        for &i in indices {
            let slot = &mut slot_of[i as usize];
            if *slot == u16::MAX {
                *slot = counts.len() as u16;
                counts.push(0);
            }
            counts[*slot as usize] += 1;
            dense.push(*slot);
        }
        (counts, dense)
    }

    /// Fast path for the δ search: bucket-count histogram only (no dense
    /// remap, no per-element output).
    pub fn count_histogram(&self, data: &[f32]) -> (Vec<u64>, f64) {
        let mut counts = vec![0u64; self.max_buckets];
        let mut sq = 0f64;
        for &x in data {
            let i = self.quantise(x);
            counts[i as usize] += 1;
            let d = x as f64 - self.dequantise(i) as f64;
            sq += d * d;
        }
        (counts, sq)
    }
}

/// Result of compressing a tensor with a uniform grid + ideal entropy coder.
#[derive(Clone, Copy, Debug)]
pub struct GridResult {
    pub delta: f64,
    /// Shannon-limit bits/element (+1-smoothed sample model, §C).
    pub bits_per_element: f64,
    pub sq_err: f64,
}

/// Evaluate one δ under the Shannon-limit model.
pub fn evaluate_grid(data: &[f32], delta: f64) -> GridResult {
    let grid = UniformGrid::new(delta);
    let (counts, sq_err) = grid.count_histogram(data);
    GridResult {
        delta,
        bits_per_element: entropy_bits(&counts),
        sq_err,
    }
}

/// Evaluate one δ but model probabilities from a *different* sample
/// (§C: "a sampling-based method to calculate the model p^Q with a fresh
/// set of samples"), charging the cross-entropy rate.
pub fn evaluate_grid_with_model(
    data: &[f32],
    model_data: &[f32],
    delta: f64,
) -> GridResult {
    let grid = UniformGrid::new(delta);
    let (indices, sq_err) = grid.encode(data);
    let (model_idx, _) = grid.encode(model_data);
    // shared dense mapping: build from the union
    let mut union = model_idx.clone();
    union.extend_from_slice(&indices);
    let (_, dense_union) = grid.dense_histogram(&union);
    let n_model = model_idx.len();
    let n_slots = *dense_union.iter().max().unwrap_or(&0) as usize + 1;
    let mut model_counts = vec![0u64; n_slots];
    for &s in &dense_union[..n_model] {
        model_counts[s as usize] += 1;
    }
    let probs = smoothed_probs(&model_counts);
    let bits: f64 = dense_union[n_model..]
        .iter()
        .map(|&s| -probs[s as usize].log2())
        .sum();
    GridResult {
        delta,
        bits_per_element: bits / data.len() as f64,
        sq_err,
    }
}

/// Search δ so the Shannon-limit rate hits `target_bits` per element.
pub fn grid_for_target_bits(data: &[f32], target_bits: f64) -> GridResult {
    let rms = crate::util::stats::rms(data).max(1e-12);
    // High-rate heuristic: H ≈ h(p) - log2 δ ⇒ δ ≈ rms · 2^-b · c.
    let centre = rms * 2f64.powf(-target_bits) * 3.5;
    let (lo, hi) = (centre.ln() - 2.5, centre.ln() + 2.5);
    let objective = |ldelta: f64| {
        let r = evaluate_grid(data, ldelta.exp());
        (r.bits_per_element - target_bits).powi(2)
    };
    let (best, _) = golden_section(lo, hi, 30, &objective);
    evaluate_grid(data, best.exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Family};
    use crate::util::rng::Rng;
    use crate::util::stats::relative_rms_error;

    #[test]
    fn qdq_error_bounded_by_half_delta() {
        let grid = UniformGrid::new(0.25);
        for i in -100..100 {
            let x = i as f32 * 0.037;
            assert!((grid.qdq(x) - x).abs() <= 0.1251);
        }
    }

    #[test]
    fn target_bits_search_converges() {
        let mut rng = Rng::new(1);
        let data = Dist::standard(Family::Normal, 0.0)
            .sample_vec(&mut rng, 1 << 16);
        for target in [2.0, 3.0, 4.0, 5.0] {
            let r = grid_for_target_bits(&data, target);
            assert!(
                (r.bits_per_element - target).abs() < 0.05,
                "target {target}: got {}",
                r.bits_per_element
            );
        }
    }

    #[test]
    fn grid_beats_fixed_length_at_equal_bits() {
        // §2.3's punchline: uniform grid + entropy coding beats the optimal
        // fixed-length (cbrt) code at the same bits/element.
        let mut rng = Rng::new(2);
        let data = Dist::standard(Family::Normal, 0.0)
            .sample_vec(&mut rng, 1 << 16);
        let r = grid_for_target_bits(&data, 4.0);
        let grid_rmse = (r.sq_err / data.len() as f64).sqrt();
        // optimal fixed-length 4-bit
        let cb = crate::formats::cbrt::cbrt_rms(
            Family::Normal, 0.0, 4,
            crate::formats::Variant::Symmetric, 1.0 / 3.0,
        );
        let recon: Vec<f32> = data.iter().map(|&x| cb.qdq(x)).collect();
        let fixed_r = relative_rms_error(&data, &recon);
        assert!(
            grid_rmse < fixed_r,
            "grid {grid_rmse} should beat fixed {fixed_r} at 4 bits"
        );
    }

    #[test]
    fn fresh_sample_model_costs_little() {
        let mut rng = Rng::new(3);
        let d = Dist::standard(Family::StudentT, 5.0);
        let data = d.sample_vec(&mut rng, 1 << 15);
        let model = d.sample_vec(&mut rng, 1 << 15);
        let ideal = evaluate_grid(&data, 0.1);
        let sampled = evaluate_grid_with_model(&data, &model, 0.1);
        assert!(sampled.bits_per_element >= ideal.bits_per_element - 0.02);
        assert!(
            sampled.bits_per_element < ideal.bits_per_element + 0.15,
            "sampled {} vs ideal {}",
            sampled.bits_per_element,
            ideal.bits_per_element
        );
    }

    #[test]
    fn dense_histogram_consistency() {
        let grid = UniformGrid::new(0.5);
        let data = [0.0f32, 0.4, 1.0, -1.0, 0.1, 1.1];
        let (idx, _) = grid.encode(&data);
        let (counts, dense) = grid.dense_histogram(&idx);
        assert_eq!(counts.iter().sum::<u64>() as usize, data.len());
        assert_eq!(dense.len(), data.len());
        // same raw index ⇒ same dense symbol
        for i in 0..data.len() {
            for j in 0..data.len() {
                assert_eq!(idx[i] == idx[j], dense[i] == dense[j]);
            }
        }
    }
}
