//! Memoised entropy-coder table construction, keyed by the exact count
//! histogram.  Canonical-Huffman code building is O(K log K) and a rANS
//! model materialises a 2^12-slot symbol table; the figure batteries and
//! repeated sweep points rebuild them for *identical* histograms (same
//! codebook, same data seed), so construction is cached process-wide.
//!
//! Keys are the full `Vec<u64>` count vector — exact, collision-free and
//! cheap next to table construction.  The cache is a leak guard, not an
//! LRU: it resets when [`MAX_ENTRIES`] distinct histograms accumulate.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::compress::huffman::HuffmanCode;
use crate::compress::rans::RansModel;

/// Distinct histograms cached per coder before the cache resets.
pub const MAX_ENTRIES: usize = 512;

type Cache<T> = OnceLock<Mutex<HashMap<Vec<u64>, Arc<T>>>>;

static HUFFMAN: Cache<HuffmanCode> = OnceLock::new();
static RANS: Cache<RansModel> = OnceLock::new();

fn cached<T>(
    cache: &'static Cache<T>,
    counts: &[u64],
    build: impl FnOnce(&[u64]) -> T,
) -> Arc<T> {
    let map = cache.get_or_init(|| Mutex::new(HashMap::new()));
    {
        let guard = map.lock().unwrap();
        if let Some(hit) = guard.get(counts) {
            return Arc::clone(hit);
        }
    }
    // build outside the lock: construction dominates, and a duplicate
    // build on a race is harmless — entry() keeps the first-inserted
    // table and the loser's freshly built Arc is simply dropped
    let built = Arc::new(build(counts));
    let mut guard = map.lock().unwrap();
    if guard.len() >= MAX_ENTRIES {
        guard.clear();
    }
    Arc::clone(guard.entry(counts.to_vec()).or_insert(built))
}

/// Memoised [`HuffmanCode::from_counts`].
pub fn huffman_for(counts: &[u64]) -> Arc<HuffmanCode> {
    cached(&HUFFMAN, counts, HuffmanCode::from_counts)
}

/// Memoised [`RansModel::from_counts`].
pub fn rans_for(counts: &[u64]) -> Arc<RansModel> {
    cached(&RANS, counts, RansModel::from_counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_histograms_share_one_table() {
        let counts = vec![7u64, 900, 13, 41, 0, 5];
        let a = huffman_for(&counts);
        let b = huffman_for(&counts);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must be a cache hit");
        assert_eq!(a.lengths, HuffmanCode::from_counts(&counts).lengths);
        let ra = rans_for(&counts);
        let rb = rans_for(&counts);
        assert!(Arc::ptr_eq(&ra, &rb));
        assert_eq!(ra.freq, RansModel::from_counts(&counts).freq);
    }

    #[test]
    fn different_histograms_get_different_tables() {
        let a = huffman_for(&[1, 2, 3]);
        let b = huffman_for(&[3, 2, 1]);
        assert!(!Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn cached_tables_round_trip() {
        let counts = vec![100u64, 50, 25, 25];
        let symbols: Vec<u16> = (0..200u16).map(|i| i % 4).collect();
        let huff = huffman_for(&counts);
        let (bytes, _) = huff.encode(&symbols);
        assert_eq!(huff.decode(&bytes, symbols.len()), symbols);
        let model = rans_for(&counts);
        let enc = crate::compress::rans::rans_encode(&model, &symbols);
        assert_eq!(
            crate::compress::rans::rans_decode(&model, &enc, symbols.len()),
            symbols
        );
    }
}
