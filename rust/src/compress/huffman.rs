//! Canonical Huffman coding (Huffman 1952), the practical entropy coder the
//! paper shows reaches near-optimal compression (figs. 8, 24).
//!
//! * Code construction: package-merge-free classic two-queue algorithm over
//!   sorted counts (O(n log n)), then canonicalisation (codes assigned in
//!   (length, symbol) order) so the decoder needs only the length table.
//! * Encode/decode: a plain bit-packed stream; [`HuffmanCode::decode`]
//!   walks a flat first-code table (per-length offsets) bit by bit — kept
//!   verbatim as the bit-exact oracle.
//! * Serving path: [`HuffmanDecoder`] resolves codes of ≤ [`TABLE_BITS`]
//!   bits with ONE probe of a flattened `2^L`-entry table (symbol + length
//!   per slot; longer codes take the canonical walk), and
//!   [`HuffmanCode::encode_interleaved`] /
//!   [`HuffmanCode::decode_interleaved`] split the symbol stream
//!   round-robin across K independent lanes so the decoder keeps K
//!   dependency chains in flight (container layout in `EXPERIMENTS.md`
//!   §Interleaved).

/// A canonical Huffman code over `n` symbols.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// Code length per symbol (0 = symbol never occurs).
    pub lengths: Vec<u8>,
    /// Canonical codeword per symbol (valid when length > 0).
    pub codes: Vec<u32>,
}

impl HuffmanCode {
    /// Build from symbol counts. Zero-count symbols get no code.
    pub fn from_counts(counts: &[u64]) -> HuffmanCode {
        let n = counts.len();
        assert!(n >= 1);
        let active: Vec<usize> =
            (0..n).filter(|&i| counts[i] > 0).collect();
        let mut lengths = vec![0u8; n];
        match active.len() {
            0 => {}
            1 => lengths[active[0]] = 1,
            _ => {
                // two-queue Huffman over sorted leaf weights
                let mut leaves: Vec<(u64, usize)> =
                    active.iter().map(|&i| (counts[i], i)).collect();
                leaves.sort();
                // node: (weight, id); children map for internal nodes
                let mut children: Vec<(i64, i64)> = Vec::new();
                let mut q1: std::collections::VecDeque<(u64, i64)> = leaves
                    .iter()
                    .map(|&(w, i)| (w, i as i64))
                    .collect();
                let mut q2: std::collections::VecDeque<(u64, i64)> =
                    std::collections::VecDeque::new();
                let pop_min =
                    |q1: &mut std::collections::VecDeque<(u64, i64)>,
                     q2: &mut std::collections::VecDeque<(u64, i64)>| {
                        match (q1.front(), q2.front()) {
                            (Some(&a), Some(&b)) => {
                                if a.0 <= b.0 {
                                    q1.pop_front().unwrap()
                                } else {
                                    q2.pop_front().unwrap()
                                }
                            }
                            (Some(_), None) => q1.pop_front().unwrap(),
                            (None, Some(_)) => q2.pop_front().unwrap(),
                            (None, None) => unreachable!(),
                        }
                    };
                while q1.len() + q2.len() > 1 {
                    let a = pop_min(&mut q1, &mut q2);
                    let b = pop_min(&mut q1, &mut q2);
                    let id = !(children.len() as i64); // negative ids
                    children.push((a.1, b.1));
                    q2.push_back((a.0 + b.0, id));
                }
                // depth-first depth assignment
                let root = pop_min(&mut q1, &mut q2).1;
                let mut stack = vec![(root, 0u8)];
                while let Some((node, depth)) = stack.pop() {
                    if node >= 0 {
                        lengths[node as usize] = depth.max(1);
                    } else {
                        let (l, r) = children[(!node) as usize];
                        stack.push((l, depth + 1));
                        stack.push((r, depth + 1));
                    }
                }
            }
        }
        let codes = canonical_codes(&lengths);
        HuffmanCode { lengths, codes }
    }

    /// Mean code length (bits/symbol) under the given counts.
    pub fn mean_bits(&self, counts: &[u64]) -> f64 {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let bits: f64 = counts
            .iter()
            .zip(&self.lengths)
            .map(|(&c, &l)| c as f64 * l as f64)
            .sum();
        bits / total as f64
    }

    /// Encode a symbol stream to a bit-packed vector; returns (bytes, bit
    /// count).
    pub fn encode(&self, symbols: &[u16]) -> (Vec<u8>, u64) {
        let mut out = Vec::with_capacity(symbols.len() / 2);
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut total: u64 = 0;
        for &s in symbols {
            let len = self.lengths[s as usize] as u32;
            assert!(len > 0, "symbol {s} has no code");
            // emit the canonical code MSB-first: reverse its bits so the
            // LSB-first packer puts the MSB on the wire first
            let code = reverse_bits(self.codes[s as usize], len) as u64;
            acc |= code << nbits;
            nbits += len;
            total += len as u64;
            while nbits >= 8 {
                out.push((acc & 0xFF) as u8);
                acc >>= 8;
                nbits -= 8;
            }
        }
        if nbits > 0 {
            out.push((acc & 0xFF) as u8);
        }
        (out, total)
    }

    /// Build the table-driven serving decoder for this code.
    pub fn decoder(&self) -> HuffmanDecoder {
        HuffmanDecoder::new(self)
    }

    /// Encode into a K-lane interleaved container: symbol `i` goes to lane
    /// `i mod K`, each lane is an independent bit stream, and the header
    /// records the lane count and the *exact bit length* of every lane
    /// (byte lengths follow as ⌈bits/8⌉) so the decoder can run all K
    /// lanes concurrently and detect over-reads bit-exactly.  `lanes == 1`
    /// wraps the plain single-stream encoding.
    pub fn encode_interleaved(
        &self,
        symbols: &[u16],
        lanes: usize,
    ) -> Vec<u8> {
        super::assert_lane_count(lanes);
        let mut lane_syms: Vec<Vec<u16>> = (0..lanes)
            .map(|_| Vec::with_capacity(symbols.len() / lanes + 1))
            .collect();
        for (i, &s) in symbols.iter().enumerate() {
            lane_syms[i % lanes].push(s);
        }
        let payloads: Vec<(Vec<u8>, u64)> =
            lane_syms.iter().map(|ls| self.encode(ls)).collect();
        let mut out = Vec::with_capacity(
            1 + 4 * lanes
                + payloads.iter().map(|(p, _)| p.len()).sum::<usize>(),
        );
        out.push(lanes as u8);
        for (_, bits) in &payloads {
            assert!(*bits <= u32::MAX as u64, "lane stream too long");
            out.extend_from_slice(&(*bits as u32).to_le_bytes());
        }
        for (p, _) in &payloads {
            out.extend_from_slice(p);
        }
        out
    }

    /// Decode `count` symbols from an [`HuffmanCode::encode_interleaved`]
    /// container, table-driven, interleaving the K lanes round-robin (the
    /// serving decode path).  Decoding a prefix (`count` smaller than what
    /// was encoded) yields exactly the first `count` symbols; asking for
    /// more panics like the oracle.  Panics on a torn container (header or
    /// payloads shorter than declared).  Builds the decoder tables on each
    /// call — serving loops decoding many containers under one code should
    /// build [`HuffmanCode::decoder`] once and use
    /// [`HuffmanDecoder::decode_interleaved`].
    pub fn decode_interleaved(
        &self,
        data: &[u8],
        count: usize,
    ) -> Vec<u16> {
        self.decoder().decode_interleaved(data, count)
    }

    /// Decode `count` symbols.
    pub fn decode(&self, data: &[u8], count: usize) -> Vec<u16> {
        // canonical decode tables: for each length, (first_code, first_index)
        let max_len = *self.lengths.iter().max().unwrap_or(&0) as usize;
        // symbols sorted by (length, symbol)
        let mut order: Vec<u16> = (0..self.lengths.len() as u16)
            .filter(|&s| self.lengths[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (self.lengths[s as usize], s));
        let mut first_code = vec![0u32; max_len + 2];
        let mut first_idx = vec![0usize; max_len + 2];
        {
            let mut code = 0u32;
            let mut idx = 0usize;
            for len in 1..=max_len {
                first_code[len] = code;
                first_idx[len] = idx;
                while idx < order.len()
                    && self.lengths[order[idx] as usize] as usize == len
                {
                    code += 1;
                    idx += 1;
                }
                code <<= 1;
            }
        }
        let mut out = Vec::with_capacity(count);
        let mut bitpos = 0usize;
        for _ in 0..count {
            // canonical codes are MSB-first in (length, rank) order, but we
            // packed LSB-first per codeword; read bits one at a time
            let mut code = 0u32;
            let mut len = 0usize;
            loop {
                let byte = data[bitpos >> 3];
                let bit = (byte >> (bitpos & 7)) & 1;
                bitpos += 1;
                code = (code << 1) | bit as u32;
                len += 1;
                debug_assert!(len <= max_len, "corrupt stream");
                // candidate: rank within this length
                let rank = code.wrapping_sub(first_code[len]);
                let start = first_idx[len];
                let within = code >= first_code[len]
                    && (rank as usize) < order.len() - start
                    && self.lengths[order[start + rank as usize] as usize]
                        as usize
                        == len;
                if within {
                    out.push(order[start + rank as usize]);
                    break;
                }
            }
        }
        out
    }
}

/// Bits resolved by one flattened-table probe; codes longer than this take
/// the canonical per-length walk (rare by construction: a symbol needs
/// probability < 2^-12 to earn a longer code).
pub const TABLE_BITS: usize = 12;

/// Lane-container header: `[K: u8][bits_0..bits_{K-1}: u32 LE]` then the
/// K payloads (⌈bits/8⌉ bytes each) back to back.  Returns the lane
/// payload slices with their exact bit lengths.  Panics — rather than
/// reading out of bounds — when the container is torn.
fn parse_lane_container(data: &[u8]) -> (usize, Vec<(&[u8], usize)>) {
    assert!(!data.is_empty(), "interleaved container: missing header");
    let lanes = data[0] as usize;
    assert!(lanes >= 1, "interleaved container: zero lanes");
    let mut offset = 1 + 4 * lanes;
    assert!(
        data.len() >= offset,
        "interleaved container: torn header ({} of {offset} bytes)",
        data.len()
    );
    let mut streams = Vec::with_capacity(lanes);
    for k in 0..lanes {
        let at = 1 + 4 * k;
        let bits = u32::from_le_bytes([
            data[at],
            data[at + 1],
            data[at + 2],
            data[at + 3],
        ]) as usize;
        let len = bits.div_ceil(8);
        assert!(
            data.len() >= offset + len,
            "interleaved container: torn lane {k} ({} of {} bytes)",
            data.len(),
            offset + len
        );
        streams.push((&data[offset..offset + len], bits));
        offset += len;
    }
    (lanes, streams)
}

/// One lane's bit cursor over a stream of exactly `bits` meaningful bits
/// (the header records them; the final byte may carry zero padding).
/// Reads are LSB-first within each byte (matching the encoder's packer).
/// Peeks past the end read zero — harmless for valid streams, whose every
/// codeword is fully contained — but *consuming* bits past `bits` panics
/// ([`LaneReader::consume`] / [`LaneReader::take1`]), so asking for more
/// symbols than were encoded errors out bit-exactly (the zero padding is
/// never decodable as phantom symbols).
struct LaneReader<'a> {
    data: &'a [u8],
    bitpos: usize,
    bits: usize,
}

impl<'a> LaneReader<'a> {
    fn new(data: &'a [u8], bits: usize) -> LaneReader<'a> {
        debug_assert!(bits <= data.len() * 8);
        LaneReader {
            data,
            bitpos: 0,
            bits,
        }
    }

    /// Peek `nbits` (≤ 16) without advancing.
    #[inline]
    fn peek(&self, nbits: usize) -> usize {
        debug_assert!(nbits <= 16);
        let byte = self.bitpos >> 3;
        let shift = self.bitpos & 7;
        let mut acc = 0u32;
        for k in 0..3 {
            if let Some(&b) = self.data.get(byte + k) {
                acc |= (b as u32) << (8 * k);
            }
        }
        ((acc >> shift) as usize) & ((1usize << nbits) - 1)
    }

    /// Advance past `nbits` just peeked; panics if that crosses the
    /// stream's encoded bit count (a codeword never does in a valid
    /// stream).
    #[inline]
    fn consume(&mut self, nbits: usize) {
        self.bitpos += nbits;
        assert!(
            self.bitpos <= self.bits,
            "Huffman lane over-read: more symbols requested than encoded"
        );
    }

    /// Read one bit and advance; panics past the encoded bit count.
    #[inline]
    fn take1(&mut self) -> u32 {
        assert!(
            self.bitpos < self.bits,
            "Huffman lane over-read: more symbols requested than encoded"
        );
        let b = self.data[self.bitpos >> 3];
        let bit = (b >> (self.bitpos & 7)) & 1;
        self.bitpos += 1;
        bit as u32
    }
}

/// Table-driven canonical decoder: a flattened `2^L`-entry table maps any
/// L-bit stream window straight to (symbol, code length) for codes of
/// ≤ L = [`TABLE_BITS`] bits — one probe instead of the oracle's per-bit
/// walk — with the canonical first-code/rank fallback for longer codes.
/// Build once per code ([`HuffmanCode::decoder`]) and reuse across every
/// container and lane encoded under that code.
pub struct HuffmanDecoder {
    table_sym: Vec<u16>,
    /// Matched code length per table slot; 0 = no code of ≤ L bits matches
    /// (over-long or invalid prefix → fallback walk).
    table_len: Vec<u8>,
    table_bits: usize,
    first_code: Vec<u32>,
    first_idx: Vec<usize>,
    count_at: Vec<u32>,
    order: Vec<u16>,
    max_len: usize,
}

impl HuffmanDecoder {
    fn new(code: &HuffmanCode) -> HuffmanDecoder {
        let max_len = *code.lengths.iter().max().unwrap_or(&0) as usize;
        let mut order: Vec<u16> = (0..code.lengths.len() as u16)
            .filter(|&s| code.lengths[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (code.lengths[s as usize], s));
        let mut first_code = vec![0u32; max_len + 2];
        let mut first_idx = vec![0usize; max_len + 2];
        let mut count_at = vec![0u32; max_len + 2];
        {
            let mut c = 0u32;
            let mut idx = 0usize;
            for len in 1..=max_len {
                first_code[len] = c;
                first_idx[len] = idx;
                while idx < order.len()
                    && code.lengths[order[idx] as usize] as usize == len
                {
                    c += 1;
                    idx += 1;
                }
                count_at[len] = (idx - first_idx[len]) as u32;
                c <<= 1;
            }
        }
        let table_bits = max_len.min(TABLE_BITS);
        let size = 1usize << table_bits;
        let mut table_sym = vec![0u16; size];
        let mut table_len = vec![0u8; size];
        for (s, &l) in code.lengths.iter().enumerate() {
            let l = l as usize;
            if l == 0 || l > table_bits {
                continue;
            }
            // stream order is the codeword's bits MSB-first, read LSB-first
            // from the packed bytes — i.e. the reversed canonical code is
            // the low-l-bit pattern every matching window shares
            let prefix = reverse_bits(code.codes[s], l as u32) as usize;
            for hi in 0..(1usize << (table_bits - l)) {
                let slot = (hi << l) | prefix;
                table_sym[slot] = s as u16;
                table_len[slot] = l as u8;
            }
        }
        HuffmanDecoder {
            table_sym,
            table_len,
            table_bits,
            first_code,
            first_idx,
            count_at,
            order,
            max_len,
        }
    }

    /// Decode `count` symbols from an [`HuffmanCode::encode_interleaved`]
    /// container with these prebuilt tables — the entry point for serving
    /// loops that decode many containers under one code (semantics as in
    /// [`HuffmanCode::decode_interleaved`], which delegates here).
    pub fn decode_interleaved(
        &self,
        data: &[u8],
        count: usize,
    ) -> Vec<u16> {
        let (lanes, streams) = parse_lane_container(data);
        let mut readers: Vec<LaneReader> = streams
            .iter()
            .map(|&(s, bits)| LaneReader::new(s, bits))
            .collect();
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            out.push(self.decode_one(&mut readers[i % lanes]));
        }
        out
    }

    /// Decode exactly `count` symbols and verify the container is fully
    /// consumed: every lane's cursor must land exactly on its recorded
    /// bit count.  Leftover bits mean the payload encodes more symbols
    /// than the caller expects — damage a prefix decode would silently
    /// ignore; this surfaces it as an error instead of wrong data.
    /// Serving paths (the `OWQ1` artifact reader) use this variant;
    /// panics on torn containers are unchanged and contained at the
    /// artifact boundary.
    pub fn decode_interleaved_checked(
        &self,
        data: &[u8],
        count: usize,
    ) -> Result<Vec<u16>, String> {
        let (lanes, streams) = parse_lane_container(data);
        let mut readers: Vec<LaneReader> = streams
            .iter()
            .map(|&(s, bits)| LaneReader::new(s, bits))
            .collect();
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            out.push(self.decode_one(&mut readers[i % lanes]));
        }
        for (k, r) in readers.iter().enumerate() {
            if r.bitpos != r.bits {
                return Err(format!(
                    "Huffman lane {k} under-consumed: {} of {} bits after \
                     {count} symbols (payload encodes more than expected)",
                    r.bitpos, r.bits
                ));
            }
        }
        Ok(out)
    }

    /// Decode one symbol from a lane: one table probe for codes of
    /// ≤ `table_bits` bits, canonical walk otherwise.  Panics (max-length
    /// assert) on a prefix no codeword matches — a corrupt/torn stream.
    #[inline]
    fn decode_one(&self, r: &mut LaneReader) -> u16 {
        let probe = r.peek(self.table_bits);
        let len = self.table_len[probe] as usize;
        if len != 0 {
            r.consume(len);
            return self.table_sym[probe];
        }
        // over-long code: the table covers every code of ≤ table_bits
        // bits, so only lengths beyond it can still match
        let mut code = 0u32;
        let mut l = 0usize;
        loop {
            code = (code << 1) | r.take1();
            l += 1;
            assert!(l <= self.max_len, "corrupt or torn Huffman stream");
            if l <= self.table_bits {
                continue;
            }
            let rank = code.wrapping_sub(self.first_code[l]);
            if code >= self.first_code[l] && rank < self.count_at[l] {
                return self.order[self.first_idx[l] + rank as usize];
            }
        }
    }
}

#[inline]
fn reverse_bits(code: u32, len: u32) -> u32 {
    code.reverse_bits() >> (32 - len)
}

/// Canonical code assignment from lengths: codes in (length, symbol) order.
fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let mut order: Vec<u16> = (0..lengths.len() as u16)
        .filter(|&s| lengths[s as usize] > 0)
        .collect();
    order.sort_by_key(|&s| (lengths[s as usize], s));
    let mut codes = vec![0u32; lengths.len()];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &s in &order {
        let len = lengths[s as usize];
        code <<= (len - prev_len) as u32;
        codes[s as usize] = code;
        code += 1;
        prev_len = len;
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::entropy_bits;
    use crate::util::rng::Rng;
    use crate::util::testing::{check, Gen};

    fn stream_from_counts(counts: &[u64], rng: &mut Rng) -> Vec<u16> {
        let mut symbols = Vec::new();
        for (s, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                symbols.push(s as u16);
            }
        }
        rng.shuffle(&mut symbols);
        symbols
    }

    #[test]
    fn roundtrip_simple() {
        let counts = [10u64, 5, 2, 1];
        let code = HuffmanCode::from_counts(&counts);
        let mut rng = Rng::new(1);
        let symbols = stream_from_counts(&counts, &mut rng);
        let (bytes, _) = code.encode(&symbols);
        let decoded = code.decode(&bytes, symbols.len());
        assert_eq!(decoded, symbols);
    }

    #[test]
    fn checked_interleaved_decode_agrees_and_rejects_undercount() {
        let counts = [40u64, 13, 2, 1, 80, 9, 0, 5];
        let code = HuffmanCode::from_counts(&counts);
        let dec = code.decoder();
        let mut rng = Rng::new(3);
        let symbols = stream_from_counts(&counts, &mut rng);
        for lanes in [1usize, 2, 5] {
            let container = code.encode_interleaved(&symbols, lanes);
            let ok = dec
                .decode_interleaved_checked(&container, symbols.len())
                .unwrap();
            assert_eq!(
                ok,
                dec.decode_interleaved(&container, symbols.len())
            );
            assert_eq!(ok, symbols);
            // fewer symbols than encoded leaves unconsumed bits — the
            // checked decoder must refuse where a prefix decode succeeds
            let short = dec.decode_interleaved_checked(
                &container,
                symbols.len() - 1,
            );
            assert!(short.is_err(), "lanes {lanes}: undercount accepted");
        }
    }

    #[test]
    fn kraft_inequality_and_prefix_free() {
        let counts = [7u64, 1, 1, 3, 9, 2, 4, 4, 0, 30];
        let code = HuffmanCode::from_counts(&counts);
        let kraft: f64 = code
            .lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft {kraft}");
        // prefix-freeness: no canonical code is a prefix of another
        let active: Vec<usize> = (0..counts.len())
            .filter(|&i| code.lengths[i] > 0)
            .collect();
        for &a in &active {
            for &b in &active {
                if a == b {
                    continue;
                }
                let (la, lb) =
                    (code.lengths[a] as u32, code.lengths[b] as u32);
                if la <= lb {
                    assert_ne!(
                        code.codes[a],
                        code.codes[b] >> (lb - la),
                        "code {a} prefixes {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn within_one_bit_of_entropy() {
        // Huffman's classic guarantee: H <= mean bits < H + 1
        let counts = [1000u64, 500, 250, 125, 125, 60, 30, 10];
        let code = HuffmanCode::from_counts(&counts);
        let h = entropy_bits(&counts);
        let mean = code.mean_bits(&counts);
        assert!(mean >= h - 1e-9, "{mean} < {h}");
        assert!(mean < h + 1.0, "{mean} >= {h} + 1");
    }

    #[test]
    fn single_symbol_alphabet() {
        let counts = [42u64];
        let code = HuffmanCode::from_counts(&counts);
        let symbols = vec![0u16; 10];
        let (bytes, bits) = code.encode(&symbols);
        assert_eq!(bits, 10);
        assert_eq!(code.decode(&bytes, 10), symbols);
    }

    #[test]
    fn skewed_distribution_roundtrip_property() {
        check("huffman-roundtrip", 40, |g: &mut Gen| {
            let n_symbols = 2 + g.rng.below(30);
            let counts: Vec<u64> = (0..n_symbols)
                .map(|_| {
                    if g.rng.f64() < 0.2 {
                        0
                    } else {
                        (g.rng.f64_open().powi(-2) as u64).min(10_000) + 1
                    }
                })
                .collect();
            if counts.iter().all(|&c| c == 0) {
                return;
            }
            let code = HuffmanCode::from_counts(&counts);
            let mut stream = stream_from_counts(&counts, &mut g.rng);
            stream.truncate(500);
            let (bytes, _) = code.encode(&stream);
            assert_eq!(code.decode(&bytes, stream.len()), stream);
        });
    }

    #[test]
    fn table_decoder_matches_oracle_across_lane_counts() {
        let counts = [900u64, 400, 220, 90, 40, 17, 6, 2, 1, 1];
        let code = HuffmanCode::from_counts(&counts);
        let mut rng = Rng::new(7);
        let symbols = stream_from_counts(&counts, &mut rng);
        let (bytes, _) = code.encode(&symbols);
        let oracle = code.decode(&bytes, symbols.len());
        assert_eq!(oracle, symbols);
        for lanes in [1usize, 2, 4, 8] {
            let container = code.encode_interleaved(&symbols, lanes);
            assert_eq!(
                code.decode_interleaved(&container, symbols.len()),
                oracle,
                "lanes={lanes}"
            );
            // prefix decode: the first count' symbols come out identically
            let short = symbols.len() / 3;
            assert_eq!(
                code.decode_interleaved(&container, short),
                symbols[..short],
                "lanes={lanes} short"
            );
        }
    }

    #[test]
    fn over_long_codes_take_the_fallback_walk() {
        // near-Fibonacci counts force code lengths beyond TABLE_BITS so
        // the flattened table cannot hold them all
        let mut counts = vec![0u64; 20];
        let (mut a, mut b) = (1u64, 1u64);
        for c in counts.iter_mut() {
            *c = a;
            let next = a + b;
            b = a;
            a = next;
        }
        let code = HuffmanCode::from_counts(&counts);
        let max_len = *code.lengths.iter().max().unwrap() as usize;
        assert!(
            max_len > super::TABLE_BITS,
            "want over-long codes, got max {max_len}"
        );
        let symbols: Vec<u16> =
            (0..counts.len() as u16).chain((0..10).map(|_| 0)).collect();
        let container = code.encode_interleaved(&symbols, 3);
        assert_eq!(
            code.decode_interleaved(&container, symbols.len()),
            symbols
        );
    }

    #[test]
    fn torn_containers_panic_cleanly() {
        let counts = [10u64, 5, 2, 1];
        let code = HuffmanCode::from_counts(&counts);
        let symbols = vec![0u16, 1, 2, 3, 0, 1, 0];
        let container = code.encode_interleaved(&symbols, 2);
        for cut in [0usize, 1, 5, container.len() - 1] {
            let torn = &container[..cut];
            let r = std::panic::catch_unwind(|| {
                code.decode_interleaved(torn, symbols.len())
            });
            assert!(r.is_err(), "cut at {cut} must panic, not misread");
        }
        // asking for more symbols than were encoded must panic (lane
        // over-read), not fabricate symbols from the zero padding — the
        // header's exact bit counts make even a +1 over-count detectable
        for extra in [1usize, 100] {
            let r = std::panic::catch_unwind(|| {
                code.decode_interleaved(&container, symbols.len() + extra)
            });
            assert!(r.is_err(), "over-count (+{extra}) decode must panic");
        }
    }

    #[test]
    fn near_optimal_on_quantised_normal() {
        // fig. 24 analogue: elementwise Huffman within ~2% of entropy for a
        // 6-bit uniform grid over Normal samples
        let mut rng = Rng::new(3);
        let grid: Vec<u16> = (0..200_000)
            .map(|_| {
                let x = rng.normal();
                ((x * 8.0).round().clamp(-31.0, 31.0) + 32.0) as u16
            })
            .collect();
        let mut counts = vec![0u64; 64];
        for &s in &grid {
            counts[s as usize] += 1;
        }
        let code = HuffmanCode::from_counts(&counts);
        let h = entropy_bits(&counts);
        let mean = code.mean_bits(&counts);
        assert!(mean < h * 1.02 + 0.03, "mean {mean} vs entropy {h}");
        // and the actual encoded size matches mean_bits
        let (_, bits) = code.encode(&grid);
        assert!(
            ((bits as f64 / grid.len() as f64) - mean).abs() < 1e-9
        );
    }
}
