//! Canonical Huffman coding (Huffman 1952), the practical entropy coder the
//! paper shows reaches near-optimal compression (figs. 8, 24).
//!
//! * Code construction: package-merge-free classic two-queue algorithm over
//!   sorted counts (O(n log n)), then canonicalisation (codes assigned in
//!   (length, symbol) order) so the decoder needs only the length table.
//! * Encode/decode: a plain bit-packed stream; decoding walks a flat
//!   first-code table (per-length offsets), O(1) table memory.

/// A canonical Huffman code over `n` symbols.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// Code length per symbol (0 = symbol never occurs).
    pub lengths: Vec<u8>,
    /// Canonical codeword per symbol (valid when length > 0).
    pub codes: Vec<u32>,
}

impl HuffmanCode {
    /// Build from symbol counts. Zero-count symbols get no code.
    pub fn from_counts(counts: &[u64]) -> HuffmanCode {
        let n = counts.len();
        assert!(n >= 1);
        let active: Vec<usize> =
            (0..n).filter(|&i| counts[i] > 0).collect();
        let mut lengths = vec![0u8; n];
        match active.len() {
            0 => {}
            1 => lengths[active[0]] = 1,
            _ => {
                // two-queue Huffman over sorted leaf weights
                let mut leaves: Vec<(u64, usize)> =
                    active.iter().map(|&i| (counts[i], i)).collect();
                leaves.sort();
                // node: (weight, id); children map for internal nodes
                let mut children: Vec<(i64, i64)> = Vec::new();
                let mut q1: std::collections::VecDeque<(u64, i64)> = leaves
                    .iter()
                    .map(|&(w, i)| (w, i as i64))
                    .collect();
                let mut q2: std::collections::VecDeque<(u64, i64)> =
                    std::collections::VecDeque::new();
                let pop_min =
                    |q1: &mut std::collections::VecDeque<(u64, i64)>,
                     q2: &mut std::collections::VecDeque<(u64, i64)>| {
                        match (q1.front(), q2.front()) {
                            (Some(&a), Some(&b)) => {
                                if a.0 <= b.0 {
                                    q1.pop_front().unwrap()
                                } else {
                                    q2.pop_front().unwrap()
                                }
                            }
                            (Some(_), None) => q1.pop_front().unwrap(),
                            (None, Some(_)) => q2.pop_front().unwrap(),
                            (None, None) => unreachable!(),
                        }
                    };
                while q1.len() + q2.len() > 1 {
                    let a = pop_min(&mut q1, &mut q2);
                    let b = pop_min(&mut q1, &mut q2);
                    let id = !(children.len() as i64); // negative ids
                    children.push((a.1, b.1));
                    q2.push_back((a.0 + b.0, id));
                }
                // depth-first depth assignment
                let root = pop_min(&mut q1, &mut q2).1;
                let mut stack = vec![(root, 0u8)];
                while let Some((node, depth)) = stack.pop() {
                    if node >= 0 {
                        lengths[node as usize] = depth.max(1);
                    } else {
                        let (l, r) = children[(!node) as usize];
                        stack.push((l, depth + 1));
                        stack.push((r, depth + 1));
                    }
                }
            }
        }
        let codes = canonical_codes(&lengths);
        HuffmanCode { lengths, codes }
    }

    /// Mean code length (bits/symbol) under the given counts.
    pub fn mean_bits(&self, counts: &[u64]) -> f64 {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let bits: f64 = counts
            .iter()
            .zip(&self.lengths)
            .map(|(&c, &l)| c as f64 * l as f64)
            .sum();
        bits / total as f64
    }

    /// Encode a symbol stream to a bit-packed vector; returns (bytes, bit
    /// count).
    pub fn encode(&self, symbols: &[u16]) -> (Vec<u8>, u64) {
        let mut out = Vec::with_capacity(symbols.len() / 2);
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        let mut total: u64 = 0;
        for &s in symbols {
            let len = self.lengths[s as usize] as u32;
            assert!(len > 0, "symbol {s} has no code");
            // emit the canonical code MSB-first: reverse its bits so the
            // LSB-first packer puts the MSB on the wire first
            let code = reverse_bits(self.codes[s as usize], len) as u64;
            acc |= code << nbits;
            nbits += len;
            total += len as u64;
            while nbits >= 8 {
                out.push((acc & 0xFF) as u8);
                acc >>= 8;
                nbits -= 8;
            }
        }
        if nbits > 0 {
            out.push((acc & 0xFF) as u8);
        }
        (out, total)
    }

    /// Decode `count` symbols.
    pub fn decode(&self, data: &[u8], count: usize) -> Vec<u16> {
        // canonical decode tables: for each length, (first_code, first_index)
        let max_len = *self.lengths.iter().max().unwrap_or(&0) as usize;
        // symbols sorted by (length, symbol)
        let mut order: Vec<u16> = (0..self.lengths.len() as u16)
            .filter(|&s| self.lengths[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (self.lengths[s as usize], s));
        let mut first_code = vec![0u32; max_len + 2];
        let mut first_idx = vec![0usize; max_len + 2];
        {
            let mut code = 0u32;
            let mut idx = 0usize;
            for len in 1..=max_len {
                first_code[len] = code;
                first_idx[len] = idx;
                while idx < order.len()
                    && self.lengths[order[idx] as usize] as usize == len
                {
                    code += 1;
                    idx += 1;
                }
                code <<= 1;
            }
        }
        let mut out = Vec::with_capacity(count);
        let mut bitpos = 0usize;
        for _ in 0..count {
            // canonical codes are MSB-first in (length, rank) order, but we
            // packed LSB-first per codeword; read bits one at a time
            let mut code = 0u32;
            let mut len = 0usize;
            loop {
                let byte = data[bitpos >> 3];
                let bit = (byte >> (bitpos & 7)) & 1;
                bitpos += 1;
                code = (code << 1) | bit as u32;
                len += 1;
                debug_assert!(len <= max_len, "corrupt stream");
                // candidate: rank within this length
                let rank = code.wrapping_sub(first_code[len]);
                let start = first_idx[len];
                let within = code >= first_code[len]
                    && (rank as usize) < order.len() - start
                    && self.lengths[order[start + rank as usize] as usize]
                        as usize
                        == len;
                if within {
                    out.push(order[start + rank as usize]);
                    break;
                }
            }
        }
        out
    }
}

#[inline]
fn reverse_bits(code: u32, len: u32) -> u32 {
    code.reverse_bits() >> (32 - len)
}

/// Canonical code assignment from lengths: codes in (length, symbol) order.
fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let mut order: Vec<u16> = (0..lengths.len() as u16)
        .filter(|&s| lengths[s as usize] > 0)
        .collect();
    order.sort_by_key(|&s| (lengths[s as usize], s));
    let mut codes = vec![0u32; lengths.len()];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &s in &order {
        let len = lengths[s as usize];
        code <<= (len - prev_len) as u32;
        codes[s as usize] = code;
        code += 1;
        prev_len = len;
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::entropy_bits;
    use crate::util::rng::Rng;
    use crate::util::testing::{check, Gen};

    fn stream_from_counts(counts: &[u64], rng: &mut Rng) -> Vec<u16> {
        let mut symbols = Vec::new();
        for (s, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                symbols.push(s as u16);
            }
        }
        rng.shuffle(&mut symbols);
        symbols
    }

    #[test]
    fn roundtrip_simple() {
        let counts = [10u64, 5, 2, 1];
        let code = HuffmanCode::from_counts(&counts);
        let mut rng = Rng::new(1);
        let symbols = stream_from_counts(&counts, &mut rng);
        let (bytes, _) = code.encode(&symbols);
        let decoded = code.decode(&bytes, symbols.len());
        assert_eq!(decoded, symbols);
    }

    #[test]
    fn kraft_inequality_and_prefix_free() {
        let counts = [7u64, 1, 1, 3, 9, 2, 4, 4, 0, 30];
        let code = HuffmanCode::from_counts(&counts);
        let kraft: f64 = code
            .lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft {kraft}");
        // prefix-freeness: no canonical code is a prefix of another
        let active: Vec<usize> = (0..counts.len())
            .filter(|&i| code.lengths[i] > 0)
            .collect();
        for &a in &active {
            for &b in &active {
                if a == b {
                    continue;
                }
                let (la, lb) =
                    (code.lengths[a] as u32, code.lengths[b] as u32);
                if la <= lb {
                    assert_ne!(
                        code.codes[a],
                        code.codes[b] >> (lb - la),
                        "code {a} prefixes {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn within_one_bit_of_entropy() {
        // Huffman's classic guarantee: H <= mean bits < H + 1
        let counts = [1000u64, 500, 250, 125, 125, 60, 30, 10];
        let code = HuffmanCode::from_counts(&counts);
        let h = entropy_bits(&counts);
        let mean = code.mean_bits(&counts);
        assert!(mean >= h - 1e-9, "{mean} < {h}");
        assert!(mean < h + 1.0, "{mean} >= {h} + 1");
    }

    #[test]
    fn single_symbol_alphabet() {
        let counts = [42u64];
        let code = HuffmanCode::from_counts(&counts);
        let symbols = vec![0u16; 10];
        let (bytes, bits) = code.encode(&symbols);
        assert_eq!(bits, 10);
        assert_eq!(code.decode(&bytes, 10), symbols);
    }

    #[test]
    fn skewed_distribution_roundtrip_property() {
        check("huffman-roundtrip", 40, |g: &mut Gen| {
            let n_symbols = 2 + g.rng.below(30);
            let counts: Vec<u64> = (0..n_symbols)
                .map(|_| {
                    if g.rng.f64() < 0.2 {
                        0
                    } else {
                        (g.rng.f64_open().powi(-2) as u64).min(10_000) + 1
                    }
                })
                .collect();
            if counts.iter().all(|&c| c == 0) {
                return;
            }
            let code = HuffmanCode::from_counts(&counts);
            let mut stream = stream_from_counts(&counts, &mut g.rng);
            stream.truncate(500);
            let (bytes, _) = code.encode(&stream);
            assert_eq!(code.decode(&bytes, stream.len()), stream);
        });
    }

    #[test]
    fn near_optimal_on_quantised_normal() {
        // fig. 24 analogue: elementwise Huffman within ~2% of entropy for a
        // 6-bit uniform grid over Normal samples
        let mut rng = Rng::new(3);
        let grid: Vec<u16> = (0..200_000)
            .map(|_| {
                let x = rng.normal();
                ((x * 8.0).round().clamp(-31.0, 31.0) + 32.0) as u16
            })
            .collect();
        let mut counts = vec![0u64; 64];
        for &s in &grid {
            counts[s as usize] += 1;
        }
        let code = HuffmanCode::from_counts(&counts);
        let h = entropy_bits(&counts);
        let mean = code.mean_bits(&counts);
        assert!(mean < h * 1.02 + 0.03, "mean {mean} vs entropy {h}");
        // and the actual encoded size matches mean_bits
        let (_, bits) = code.encode(&grid);
        assert!(
            ((bits as f64 / grid.len() as f64) - mean).abs() < 1e-9
        );
    }
}
