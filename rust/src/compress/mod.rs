//! Lossless compression of quantised data (§2.3): the Shannon-limit entropy
//! model, a canonical Huffman coder, a range-Asymmetric-Numeral-System
//! coder, and the entropy-constrained uniform-grid quantiser that is optimal
//! when followed by a lossless compressor (appendix B.3).
//!
//! Both practical coders carry a serving-scale decode path alongside the
//! single-stream oracle: K-way lane-interleaved containers
//! ([`huffman::HuffmanCode::encode_interleaved`] with a flattened
//! table-driven decoder, [`rans::rans_encode_interleaved`] with K
//! round-robin states over one shared stream).  Lane counts live in the
//! container header; K = 1 stays bit-compatible with the oracle coders
//! (`EXPERIMENTS.md` §Interleaved).  These interleaved streams are also
//! the durable on-disk form: the `OWQ1` artifact store
//! ([`crate::artifact`]) persists each tensor's index payload as one such
//! container next to the count histogram it was modelled on.
//!
//! # Panic contract (fault model)
//!
//! The coders here assume writer-produced input: torn containers and
//! invalid prefixes **panic** (deliberately — these paths stay lean and
//! bit-exact against the oracles).  Robustness lives one layer up: the
//! artifact reader verifies per-section checksums *before* any coder sees
//! the bytes, runs every decode under `catch_unwind` so a coder panic
//! surfaces as a typed `Corrupt` error, and uses the `*_checked` decode
//! variants ([`huffman::HuffmanDecoder::decode_interleaved_checked`],
//! [`rans::rans_decode_interleaved_checked`]) that verify the stream is
//! exactly consumed — damage that evades a checksum can therefore never
//! yield silently wrong indices or abort a serving thread.

pub mod grid;
pub mod huffman;
pub mod rans;
pub mod tables;

/// Most lanes an interleaved container can carry — shared by the Huffman
/// and rANS containers so a stream produced under one coder's limit is
/// always within the other's (the count is a header byte; 0 is reserved
/// as invalid).
pub const MAX_LANES: usize = 255;

/// Validate an interleaved lane count against [`MAX_LANES`].
pub(crate) fn assert_lane_count(lanes: usize) {
    assert!(
        (1..=MAX_LANES).contains(&lanes),
        "lane count {lanes} outside 1..={MAX_LANES}"
    );
}

/// Shannon entropy (bits/symbol) of a count histogram.
pub fn entropy_bits(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let n = total as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Cross-entropy (bits/symbol) of data with histogram `counts` coded under a
/// (+1-smoothed) model built from `model_counts` — the achievable rate with
/// a stale/sampled model, as in §C's sampling-based `p^Q`.
pub fn cross_entropy_bits(counts: &[u64], model_counts: &[u64]) -> f64 {
    assert_eq!(counts.len(), model_counts.len());
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let n = total as f64;
    let model = smoothed_probs(model_counts);
    counts
        .iter()
        .zip(&model)
        .filter(|(&c, _)| c > 0)
        .map(|(&c, &q)| {
            let p = c as f64 / n;
            -p * q.log2()
        })
        .sum()
}

/// +1-smoothed probability model from counts (§C "use +1 smoothing of the
/// counts to avoid zeros").
pub fn smoothed_probs(counts: &[u64]) -> Vec<f64> {
    let total: u64 = counts.iter().sum();
    let denom = total as f64 + counts.len() as f64;
    counts
        .iter()
        .map(|&c| (c + 1) as f64 / denom)
        .collect()
}

/// Information content Σ -log2 p(symbol) of a symbol stream under a model
/// (§2.3: I(q) = Σ -log2 p^Q(q_i)); assumes an optimal compressor at the
/// Shannon limit.
pub fn information_content(symbols: &[u16], probs: &[f64]) -> f64 {
    symbols
        .iter()
        .map(|&s| -probs[s as usize].log2())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_basics() {
        assert_eq!(entropy_bits(&[0, 0, 0]), 0.0);
        assert_eq!(entropy_bits(&[5, 0, 0]), 0.0);
        assert!((entropy_bits(&[1, 1]) - 1.0).abs() < 1e-12);
        assert!((entropy_bits(&[1, 1, 1, 1]) - 2.0).abs() < 1e-12);
        // H(0.9, 0.1) ≈ 0.469
        assert!((entropy_bits(&[9, 1]) - 0.4689955935892812).abs() < 1e-12);
    }

    #[test]
    fn cross_entropy_ge_entropy() {
        let counts = [50u64, 30, 15, 5];
        let model = [25u64, 25, 25, 25];
        let h = entropy_bits(&counts);
        let ce = cross_entropy_bits(&counts, &model);
        assert!(ce >= h - 1e-9, "ce {ce} < h {h}");
        // matching model gets close to entropy (smoothing costs a little)
        let ce_self = cross_entropy_bits(&counts, &counts);
        assert!(ce_self < h + 0.1);
    }

    #[test]
    fn smoothing_has_no_zeros() {
        let p = smoothed_probs(&[0, 10, 0]);
        assert!(p.iter().all(|&x| x > 0.0));
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn information_content_matches_entropy_in_expectation() {
        let counts = [100u64, 50, 25, 25];
        let probs = smoothed_probs(&counts);
        let mut symbols = Vec::new();
        for (s, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                symbols.push(s as u16);
            }
        }
        let bits = information_content(&symbols, &probs);
        let h = entropy_bits(&counts);
        assert!((bits / symbols.len() as f64 - h).abs() < 0.05);
    }
}
