//! Simulated-data analyses (§3 and appendix C): iid draws from Normal /
//! Laplace / Student-t, evaluated as R (RMS error / data RMS), usually
//! reported as R·2^b so error/bits trade-off lines flatten.

use anyhow::{bail, Result};

use crate::compress::grid::grid_for_target_bits;
use crate::compress::rans::{
    rans_decode, rans_decode_interleaved, rans_encode,
    rans_encode_interleaved,
};
use crate::compress::{entropy_bits, information_content, smoothed_probs};
use crate::coordinator::config::{Element, Scheme};
use crate::coordinator::{fmt, Report};
use crate::dist::{Dist, Family, Truncated};
use crate::alloc::frac;
use crate::eval::pipeline::{qdq_tensor, qdq_tensor_mixed};
use crate::eval::RunOpts;
use crate::formats::cbrt::{cbrt_absmax, cbrt_rms, CBRT_ALPHA};
use crate::formats::lloyd::{LloydInit, LloydMax};
use crate::formats::Variant;
use crate::util::pool::par_map;
use crate::util::rng::Rng;
use crate::util::stats::relative_rms_error;

pub const NU: f64 = 5.0; // Student-t degrees of freedom used across §3

fn families() -> Vec<(&'static str, Dist)> {
    vec![
        ("normal", Dist::standard(Family::Normal, 0.0)),
        ("laplace", Dist::standard(Family::Laplace, 0.0)),
        ("student_t5", Dist::standard(Family::StudentT, NU)),
    ]
}

fn sample(d: &Dist, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    d.sample_vec(&mut rng, n)
}

/// R for a spec string applied to iid data (shared with examples/benches).
pub fn r_of(spec: &str, data: &[f32]) -> Result<f64> {
    let scheme = Scheme::parse(spec)?;
    let out = qdq_tensor(&scheme, data, &[data.len()], None, &[], 11)?;
    Ok(relative_rms_error(data, &out.recon))
}

/// One measured point of a simulated-data sweep.
#[derive(Clone, Copy, Debug)]
pub struct SimPoint {
    /// honest bits/element (element + scale overhead, entropy when
    /// compressed)
    pub bits: f64,
    /// relative RMS error
    pub r: f64,
    /// the paper's flattened trade-off statistic R·2^b
    pub r2b: f64,
}

/// Fewest samples a sweep point will draw (R over a handful of values is
/// noise; the sweep engine uses the same floor when keying resume rows).
pub const MIN_SWEEP_SAMPLES: usize = 256;

/// The CPU-side unit of work of `owf sweep`: draw `samples` iid values
/// (seeded per point), quantise under `spec`, report (bits, R, R·2^b).
/// The data distribution matches the scheme's cbrt family when it names
/// one; everything else is evaluated on Student-t5, the paper's stand-in
/// for LLM weight tails.
pub fn sweep_point(
    spec: &str,
    samples: usize,
    seed: u64,
) -> Result<SimPoint> {
    // `frac@<bits>:...` is the fractional allocator's sweep point, not
    // a fixed format — intercept before the scheme grammar sees it
    if let Some(rest) = spec.strip_prefix("frac@") {
        return frac_sweep_point(rest, samples, seed);
    }
    let scheme = Scheme::parse(spec)?;
    let d = sweep_dist(&scheme);
    let mut rng =
        Rng::new(0x5EED ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    let data = d.sample_vec(&mut rng, samples.max(MIN_SWEEP_SAMPLES));
    let out = qdq_tensor(&scheme, &data, &[data.len()], None, &[], seed)?;
    let r = relative_rms_error(&data, &out.recon);
    Ok(SimPoint {
        bits: out.bits,
        r,
        r2b: r * 2f64.powf(out.bits),
    })
}

/// One fractional-allocator sweep point: `frac@<bits>:<granularity>-
/// <statistic>[:<flags>]`.  Measures the int@2..8 candidate curve for
/// the tail spec on the sampled data, water-fills the (possibly
/// fractional) budget over its lower convex hull and realises the
/// chosen block-level mix through the mixed pipeline — so the
/// allocator's rate–distortion curve sweeps directly against the
/// fixed-format curves on identical data.
fn frac_sweep_point(
    rest: &str,
    samples: usize,
    seed: u64,
) -> Result<SimPoint> {
    let Some((bits_str, tail)) = rest.split_once(':') else {
        bail!(
            "frac spec needs \
             frac@<bits>:<granularity>-<statistic>[:<flags>], \
             got frac@{rest:?}"
        );
    };
    let target: f64 = bits_str
        .parse()
        .map_err(|e| anyhow::anyhow!("frac budget {bits_str:?}: {e}"))?;
    // the candidate family is the int lattice over the tail's layout;
    // the @4 here is a placeholder the candidates overwrite
    let base = Scheme::parse(&format!("int@4:{tail}"))?;
    frac::validate_base(&base)?;

    let d = sweep_dist(&base);
    let mut rng =
        Rng::new(0x5EED ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    let data = d.sample_vec(&mut rng, samples.max(MIN_SWEEP_SAMPLES));
    let shape = [data.len()];

    let points = frac::measure_points(&base, &data, &shape, None, &[], seed)?;
    let curves = vec![frac::TensorCurve::new(
        "sweep",
        data.len(),
        1.0,
        points,
    )];
    let alloc = frac::waterfill(&curves, target);
    let choice = &alloc.choices[0];
    let candidates = frac::candidate_schemes(&base);

    let pure = |idx: usize| {
        qdq_tensor(&candidates[idx], &data, &shape, None, &[], seed)
    };
    let out = if choice.is_pure() {
        pure(choice.lo)?
    } else {
        let lens: Vec<usize> = crate::scaling::scale_groups(
            data.len(),
            base.granularity,
            0,
        )
        .iter()
        .map(|&(_, len)| len)
        .collect();
        let hi_elems =
            (choice.hi_weight * data.len() as f64).round() as usize;
        let assign = frac::assign_blocks(seed, &lens, hi_elems);
        if assign.iter().all(|&a| a == 0) {
            pure(choice.lo)?
        } else if assign.iter().all(|&a| a == 1) {
            pure(choice.hi)?
        } else {
            qdq_tensor_mixed(
                &[
                    candidates[choice.lo].clone(),
                    candidates[choice.hi].clone(),
                ],
                &assign,
                &data,
                &shape,
                None,
                &[],
                seed,
            )?
        }
    };
    let r = relative_rms_error(&data, &out.recon);
    Ok(SimPoint {
        bits: out.bits,
        r,
        r2b: r * 2f64.powf(out.bits),
    })
}

/// The data distribution a sweep evaluates a scheme against.
fn sweep_dist(scheme: &Scheme) -> Dist {
    match &scheme.element {
        Element::Cbrt { family, nu } => match family {
            Family::StudentT if *nu > 2.0 => {
                Dist::standard(Family::StudentT, *nu)
            }
            Family::StudentT => Dist::standard(Family::StudentT, NU),
            other => Dist::standard(*other, 0.0),
        },
        _ => Dist::standard(Family::StudentT, NU),
    }
}

// ---------------------------------------------------------------------------

/// fig. 2 — 4-bit quantisation curves: √[3]p vs Lloyd-Max, RMS and absmax
/// scaling; the legend's relative-error pairs.
pub fn fig2_curves(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig2",
        "4-bit cbrt vs Lloyd-Max (R for matching data)",
        &["dist", "scaling", "R cbrt", "R lloyd", "lloyd/cbrt"],
    );
    let n = opts.samples.min(1 << 20);
    for (name, d) in families() {
        let fam = d.family();
        for scaling in ["rms", "absmax"] {
            let data = sample(&d, n, 0xF162);
            let (r_c, r_l) = if scaling == "rms" {
                let cb = cbrt_rms(fam, NU, 4, Variant::Symmetric, CBRT_ALPHA);
                let lm = LloydMax::new(4, LloydInit::KmeansPp).fit(&data, &[]);
                (
                    relative_rms_error(&data, &qdq_all(&cb, &data)),
                    relative_rms_error(&data, &qdq_all(&lm, &data)),
                )
            } else {
                // absmax: work in block-scaled space
                let block = 64;
                let scaled = block_scale_absmax(&data, block);
                let cb = cbrt_absmax(
                    fam, NU, 4, block, Variant::Symmetric, CBRT_ALPHA,
                );
                let lm =
                    LloydMax::new(4, LloydInit::Uniform).fit(&scaled, &[]);
                (
                    relative_rms_error(&scaled, &qdq_all(&cb, &scaled)),
                    relative_rms_error(&scaled, &qdq_all(&lm, &scaled)),
                )
            };
            rep.row(vec![
                name.into(),
                scaling.into(),
                fmt(r_c),
                fmt(r_l),
                fmt(r_l / r_c),
            ]);
        }
    }
    rep.note("paper fig. 2: cbrt ≈ Lloyd-Max (ratio ≈ 1) for both scalings");
    Ok(rep)
}

fn qdq_all(cb: &crate::formats::Codebook, data: &[f32]) -> Vec<f32> {
    // batch entry point: one LUT dispatch per tensor, not per element
    let mut out = data.to_vec();
    cb.qdq_slice(&mut out);
    out
}

fn block_scale_absmax(data: &[f32], block: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(data.len());
    for chunk in data.chunks(block) {
        let s = chunk.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-30);
        out.extend(chunk.iter().map(|&x| x / s));
    }
    out
}

/// fig. 3 — 3-bit codepoint geometries across scaling/variants.
pub fn fig3_codepoints() -> Result<Report> {
    let mut rep = Report::new(
        "fig3",
        "3-bit cbrt-Normal codepoints by scaling x variant (B=64)",
        &["scaling", "variant", "has 0", "codepoints"],
    );
    let rows: Vec<(&str, Variant)> = vec![
        ("rms", Variant::Symmetric),
        ("rms", Variant::Asymmetric),
        ("absmax", Variant::Symmetric),
        ("absmax", Variant::Asymmetric),
        ("signmax", Variant::Signmax),
    ];
    for (scaling, variant) in rows {
        let cb = match scaling {
            "rms" => cbrt_rms(Family::Normal, 0.0, 3, variant, CBRT_ALPHA),
            _ => cbrt_absmax(
                Family::Normal, 0.0, 3, 64, variant, CBRT_ALPHA,
            ),
        };
        rep.row(vec![
            scaling.into(),
            variant.name().into(),
            format!("{}", cb.has_zero()),
            cb.points()
                .iter()
                .map(|p| format!("{p:.3}"))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
    }
    Ok(rep)
}

/// fig. 4 — the error/size trade-off: tensor-RMS vs block-absmax optimal
/// quantisers, with and without lossless compression.
pub fn fig4_sim_tradeoff(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig4",
        "R·2^b: block absmax beats tensor RMS until compression (iid data)",
        &["dist", "b", "rms", "absmax-b128", "rms+comp", "absmax+comp"],
    );
    let n = opts.samples;
    let fam_of = |d: &Dist| d.family();
    let jobs: Vec<(String, Dist, u32)> = families()
        .into_iter()
        .flat_map(|(name, d)| {
            (2..=6).map(move |b| (name.to_string(), d, b))
        })
        .collect();
    let results = par_map(&jobs, |_, (name, d, b)| {
        let fam = fam_of(d);
        let fam_str = match fam {
            Family::Normal => "cbrt-normal",
            Family::Laplace => "cbrt-laplace",
            _ => "cbrt-t5",
        };
        let data = sample(d, n, 0xF164 ^ *b as u64);
        let specs = [
            format!("{fam_str}@{b}:tensor-rms"),
            format!("{fam_str}@{b}:block128-absmax"),
            format!("{fam_str}@{b}:tensor-rms:compress"),
            format!("{fam_str}@{b}:block128-absmax:compress"),
        ];
        let mut cells = vec![name.clone(), b.to_string()];
        for spec in &specs {
            let scheme = Scheme::parse(spec).unwrap();
            let out =
                qdq_tensor(&scheme, &data, &[data.len()], None, &[], 1)
                    .unwrap();
            let r = relative_rms_error(&data, &out.recon);
            cells.push(format!(
                "{} (b={})",
                fmt(r * 2f64.powf(out.bits)),
                fmt(out.bits)
            ));
        }
        cells
    });
    for cells in results {
        rep.row(cells);
    }
    rep.note("paper fig. 4: absmax < rms uncompressed; rms+comp best overall");
    Ok(rep)
}

/// fig. 14 — expected block absmax: table-4 approximations vs Monte-Carlo.
pub fn fig14_absmax_approx(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig14",
        "E[absmax] approximation vs simulation (scale s=1)",
        &["dist", "B", "approx", "simulated", "rel err"],
    );
    let trials = (opts.samples / 256).clamp(1000, 20_000);
    for (name, base) in [
        ("normal", Dist::normal(1.0)),
        ("laplace", Dist::laplace(1.0)),
        ("student_t5", Dist::student_t(NU, 1.0)),
        ("student_t10", Dist::student_t(10.0, 1.0)),
    ] {
        for block in [16usize, 64, 256, 1024] {
            let approx = base.expected_absmax(block);
            let mut rng = Rng::new(0xF14 ^ block as u64);
            let mut acc = 0.0;
            for _ in 0..trials {
                let mut m = 0f64;
                for _ in 0..block {
                    m = m.max(base.sample(&mut rng).abs());
                }
                acc += m;
            }
            let mc = acc / trials as f64;
            rep.row(vec![
                name.into(),
                block.to_string(),
                fmt(approx),
                fmt(mc),
                fmt((approx - mc).abs() / mc),
            ]);
        }
    }
    rep.note("paper fig. 14: good fit for B ≥ 16, converging with B");
    Ok(rep)
}

/// fig. 15 — the absmax mixture model: the non-maxima marginal matches a
/// truncated distribution (KS distance vs a mismatched control).
pub fn fig15_mixture(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig15",
        "block-scaled non-maxima vs truncated-D mixture model (KS distance)",
        &["dist", "scaling", "KS(truncated model)", "KS(plain D control)"],
    );
    let block = 64;
    let n_blocks = (opts.samples / block).min(20_000);
    for (name, d) in families() {
        for scaling in ["absmax", "signmax"] {
            let mut rng = Rng::new(0xF15);
            let mut nonmax = Vec::new();
            for _ in 0..n_blocks {
                let mut blk: Vec<f64> =
                    (0..block).map(|_| d.sample(&mut rng)).collect();
                let (mut mi, mut mv) = (0usize, 0f64);
                for (i, &x) in blk.iter().enumerate() {
                    if x.abs() > mv.abs() {
                        mv = x;
                        mi = i;
                    }
                }
                let s = if scaling == "absmax" { mv.abs() } else { mv };
                blk.remove(mi);
                nonmax.extend(blk.iter().map(|&x| x / s));
            }
            // model: D scaled so E[absmax]=1, truncated at ±1
            let scaled = d.with_absmax(block, 1.0);
            let trunc = Truncated::new(scaled, -1.0, 1.0);
            let ks_model = ks_distance(&nonmax, |x| trunc.cdf(x));
            let ks_control = ks_distance(&nonmax, |x| d.cdf(x));
            rep.row(vec![
                name.into(),
                scaling.into(),
                fmt(ks_model),
                fmt(ks_control),
            ]);
        }
    }
    rep.note("paper fig. 15: truncated model fits (small KS), plain D does not");
    Ok(rep)
}

fn ks_distance(samples: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let mut s: Vec<f64> = samples.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let n = s.len() as f64;
    let mut ks = 0f64;
    for (i, &x) in s.iter().enumerate() {
        let e = (i + 1) as f64 / n;
        ks = ks.max((cdf(x) - e).abs()).max((cdf(x) - i as f64 / n).abs());
    }
    ks
}

/// fig. 16 — cube-root vs proportional (quantile) vs Lloyd-Max on Normal.
pub fn fig16_cbrt_rule(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig16",
        "4-bit quantisers for standard Normal: R comparison",
        &["quantiser", "R"],
    );
    let d = Dist::standard(Family::Normal, 0.0);
    let data = sample(&d, opts.samples.min(1 << 20), 0xF16);
    let cbrt = cbrt_rms(Family::Normal, 0.0, 4, Variant::Symmetric, CBRT_ALPHA);
    let quantile =
        cbrt_rms(Family::Normal, 0.0, 4, Variant::Symmetric, 1.0);
    let lloyd = LloydMax::new(4, LloydInit::KmeansPp).fit(&data, &[]);
    for (name, cb) in [
        ("cbrt (α=1/3)", &cbrt),
        ("proportional (α=1)", &quantile),
        ("lloyd-max", &lloyd),
    ] {
        rep.row(vec![
            name.into(),
            fmt(relative_rms_error(&data, &qdq_all(cb, &data))),
        ]);
    }
    rep.note("paper fig. 16: cbrt ≈ lloyd, both beat proportional");
    Ok(rep)
}

/// fig. 18 — extant vs optimal 4-bit element formats across block sizes.
pub fn fig18_element_formats(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig18",
        "4-bit element formats, R·2^b vs block size (absmax scaling)",
        &["dist", "B", "cbrt", "nf4", "sf4", "af4", "int-asym",
          "int-signmax", "e2m1", "e3m0"],
    );
    let n = opts.samples;
    let jobs: Vec<(String, Dist, usize)> = families()
        .into_iter()
        .flat_map(|(name, d)| {
            [32usize, 64, 128, 256]
                .into_iter()
                .map(move |b| (name.to_string(), d, b))
        })
        .collect();
    let rows = par_map(&jobs, |_, (name, d, block)| {
        let data = sample(d, n, 0xF18);
        let fam_str = match d.family() {
            Family::Normal => "cbrt-normal",
            Family::Laplace => "cbrt-laplace",
            _ => "cbrt-t5",
        };
        let specs = [
            format!("{fam_str}@4:block{block}-absmax"),
            format!("nf@4:block{block}-absmax"),
            format!("sf5@4:block{block}-absmax"),
            format!("af4@4:block{block}-absmax"),
            format!("int@4:block{block}-absmax:asym"),
            format!("int@4:block{block}-signmax"),
            format!("e2m1@4:block{block}-absmax"),
            format!("e3m0@4:block{block}-absmax"),
        ];
        let mut cells = vec![name.clone(), block.to_string()];
        for spec in &specs {
            let scheme = Scheme::parse(spec).unwrap();
            let out = qdq_tensor(&scheme, &data, &[data.len()], None, &[], 2)
                .unwrap();
            let r = relative_rms_error(&data, &out.recon);
            cells.push(fmt(r * 2f64.powf(out.bits)));
        }
        cells
    });
    for r in rows {
        rep.row(r);
    }
    rep.note("paper fig. 18: cbrt marginally beats NF4/SF4; E2M1 best FP; signmax rescues INT");
    Ok(rep)
}

/// fig. 19 — floating-point exponent-bits sweep vs total width.
pub fn fig19_exponent(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig19",
        "EkMm formats: R·2^b by exponent bits and total width (Student-t5, absmax B=64)",
        &["b", "e1", "e2", "e3", "e4", "e5"],
    );
    let d = Dist::standard(Family::StudentT, NU);
    let data = sample(&d, opts.samples, 0xF19);
    for total in [4u32, 5, 6, 7] {
        let mut cells = vec![total.to_string()];
        for e in 1..=5u32 {
            if e + 1 >= total {
                cells.push("-".into());
                continue;
            }
            let m = total - 1 - e;
            let spec = format!("e{e}m{m}@{total}:block64-absmax");
            let scheme = Scheme::parse(&spec)?;
            let out =
                qdq_tensor(&scheme, &data, &[data.len()], None, &[], 3)?;
            let r = relative_rms_error(&data, &out.recon);
            cells.push(fmt(r * 2f64.powf(out.bits)));
        }
        rep.row(cells);
    }
    rep.note("paper fig. 19: optimal exponent count stays put as b grows");
    Ok(rep)
}

/// fig. 20/21 — scale format & block size sweeps.
pub fn fig20_scale_mantissa(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig20",
        "scale mantissa bits at b≈4 (Student-t5, block absmax, B=64)",
        &["scale fmt", "scale bits", "b total", "R·2^b (int)", "R·2^b (cbrt)"],
    );
    let d = Dist::standard(Family::StudentT, NU);
    let data = sample(&d, opts.samples, 0xF20);
    // keep total b ≈ 4.25 by fixing the element width and letting the
    // scale overhead vary (the paper adjusts element width; with a 4-bit
    // LUT granularity we hold the element fixed and report the true total)
    for (name, fmt_s) in [
        ("e8m0", crate::scaling::ScaleFormat::E8M0 { away: true }),
        ("e5m2", crate::scaling::ScaleFormat::Float { exp: 5, man: 2, away: true }),
        ("e6m5", crate::scaling::ScaleFormat::Float { exp: 6, man: 5, away: true }),
        ("bf16 (e8m7)", crate::scaling::ScaleFormat::Bf16 { away: true }),
        ("f32", crate::scaling::ScaleFormat::F32),
    ] {
        let mut cells = vec![name.to_string(), fmt(fmt_s.bits())];
        let mut first = true;
        let mut bits_total = 0.0;
        let mut vals = Vec::new();
        for elem in ["int", "cbrt-t5"] {
            let mut scheme =
                Scheme::parse(&format!("{elem}@4:block64-absmax"))?;
            scheme = scheme.with_scale_format(fmt_s);
            let out =
                qdq_tensor(&scheme, &data, &[data.len()], None, &[], 4)?;
            let r = relative_rms_error(&data, &out.recon);
            if first {
                bits_total = out.bits;
                first = false;
            }
            vals.push(fmt(r * 2f64.powf(out.bits)));
        }
        cells.insert(2, fmt(bits_total));
        cells.extend(vals);
        rep.row(cells);
    }
    rep.note("paper fig. 20: 4-10 scale mantissa bits beat E8M0, int benefits most");
    Ok(rep)
}

/// fig. 21 — block size sweep (bf16 vs e8m0 scale).
pub fn fig21_block_size(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig21",
        "absmax block-size sweep, R·2^b (4-bit cbrt elements)",
        &["dist", "B", "bf16 scale", "e8m0 scale"],
    );
    let n = opts.samples;
    let jobs: Vec<(String, Dist, usize)> = families()
        .into_iter()
        .flat_map(|(name, d)| {
            [16usize, 32, 64, 128, 256, 512, 1024]
                .into_iter()
                .map(move |b| (name.to_string(), d, b))
        })
        .collect();
    let rows = par_map(&jobs, |_, (name, d, block)| {
        let data = sample(d, n, 0xF21);
        let fam_str = match d.family() {
            Family::Normal => "cbrt-normal",
            Family::Laplace => "cbrt-laplace",
            _ => "cbrt-t5",
        };
        let mut cells = vec![name.clone(), block.to_string()];
        for scale in [
            crate::scaling::DEFAULT_SCALE,
            crate::scaling::ScaleFormat::E8M0 { away: true },
        ] {
            let scheme =
                Scheme::parse(&format!("{fam_str}@4:block{block}-absmax"))
                    .unwrap()
                    .with_scale_format(scale);
            let out = qdq_tensor(&scheme, &data, &[data.len()], None, &[], 5)
                .unwrap();
            let r = relative_rms_error(&data, &out.recon);
            cells.push(fmt(r * 2f64.powf(out.bits)));
        }
        cells
    });
    for r in rows {
        rep.row(r);
    }
    rep.note("paper fig. 21: optimum near B=64-256, bf16 beats e8m0");
    Ok(rep)
}

/// fig. 22 — the p^α exponent sweep: α = 1/3 is the optimum.
pub fn fig22_alpha(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig22",
        "p^α rule sweep (4-bit, matching quantiser per dist): R·2^b",
        &["alpha", "normal rms", "t5 rms", "normal absmax64", "t5 absmax64"],
    );
    let n = opts.samples.min(1 << 20);
    let d_n = Dist::standard(Family::Normal, 0.0);
    let d_t = Dist::standard(Family::StudentT, NU);
    let data_n = sample(&d_n, n, 0xF22);
    let data_t = sample(&d_t, n, 0xF23);
    // α must satisfy α(ν+1) > 1 for the Student-t transform (ν=5 ⇒ α>1/6)
    for alpha in [0.2, 1.0 / 3.0, 0.5, 0.7, 1.0] {
        let mut cells = vec![format!("{alpha:.3}")];
        for (fam, nu, data) in [
            (Family::Normal, 0.0, &data_n),
            (Family::StudentT, NU, &data_t),
        ] {
            let cb = cbrt_rms(fam, nu, 4, Variant::Symmetric, alpha);
            let r = relative_rms_error(data, &qdq_all(&cb, data));
            cells.push(fmt(r * 16.0));
        }
        for (fam, nu, data) in [
            (Family::Normal, 0.0, &data_n),
            (Family::StudentT, NU, &data_t),
        ] {
            let scaled = block_scale_absmax(data, 64);
            let cb =
                cbrt_absmax(fam, nu, 4, 64, Variant::Symmetric, alpha);
            let r = relative_rms_error(&scaled, &qdq_all(&cb, &scaled));
            cells.push(fmt(r * 16.0));
        }
        rep.row(cells);
    }
    rep.note("paper fig. 22: α = 1/3 minimises R for both scalings");
    Ok(rep)
}

/// fig. 23 — quantiser scale/shape search for Student-t data.
pub fn fig23_scale_search(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig23",
        "5-bit quantiser-scale search on Student-t5 data (RMS scaling)",
        &["quantiser", "best multiplier", "R at best", "R at mult=1"],
    );
    let d = Dist::standard(Family::StudentT, NU);
    let data = sample(&d, opts.samples.min(1 << 19), 0xF23);
    for quant in ["cbrt-normal", "cbrt-laplace", "cbrt-t5", "int"] {
        let base = format!("{quant}@5:tensor-rms");
        let searched = Scheme::parse(&format!("{base}:search"))?;
        let plain = Scheme::parse(&base)?;
        // recover the searched multiplier by re-running the search
        let out_s = qdq_tensor(&searched, &data, &[data.len()], None, &[], 6)?;
        let out_p = qdq_tensor(&plain, &data, &[data.len()], None, &[], 6)?;
        let r_s = relative_rms_error(&data, &out_s.recon);
        let r_p = relative_rms_error(&data, &out_p.recon);
        // explicit grid search for the reported multiplier
        let (best_m, _) = crate::dist::fit::grid_then_golden(
            &crate::dist::fit::scale_search_grid(),
            |m| {
                let q = Scheme::parse(&base)
                    .unwrap()
                    .with_multiplier(m);
                let o = qdq_tensor(&q, &data, &[data.len()], None, &[], 6)
                    .unwrap();
                o.sq_err
            },
        );
        rep.row(vec![quant.into(), fmt(best_m), fmt(r_s), fmt(r_p)]);
    }
    rep.note("paper fig. 23: matching quantiser needs mult≈1; mismatched ones need search");
    Ok(rep)
}

/// fig. 24 — practical compressors vs the Shannon limit.
pub fn fig24_compressors(opts: &RunOpts) -> Result<Report> {
    let mut rep = Report::new(
        "fig24",
        "practical coders vs Shannon limit (cbrt-t5 elements, RMS scaling)",
        &["b", "shannon", "huffman", "rans", "huff overhead %"],
    );
    let d = Dist::standard(Family::StudentT, NU);
    let data = sample(&d, opts.samples.min(1 << 20), 0xF24);
    for b in [3u32, 4, 5, 6] {
        let cb = cbrt_rms(Family::StudentT, NU, b, Variant::Symmetric, CBRT_ALPHA);
        let mut symbols: Vec<u16> = Vec::new();
        cb.quantise_slice(&data, &mut symbols);
        let mut counts = vec![0u64; cb.len()];
        for &s in &symbols {
            counts[s as usize] += 1;
        }
        let h = entropy_bits(&counts);
        // memoised table construction: repeat invocations of the battery
        // (report runs, tests) reuse the cached code for this histogram
        let huff = crate::compress::tables::huffman_for(&counts);
        let (hbytes, _) = huff.encode(&symbols);
        let h_rate = hbytes.len() as f64 * 8.0 / symbols.len() as f64;
        let model = crate::compress::tables::rans_for(&counts);
        let renc = rans_encode(&model, &symbols);
        // verify losslessness in passing
        assert_eq!(
            rans_decode(&model, &renc, symbols.len())[..100],
            symbols[..100]
        );
        // and that the K-lane interleaved serving decoders agree with
        // the single-lane oracles on a probe slice of the same stream —
        // K picked from the active ISA's vector width, as at pack time
        let lanes = crate::util::simd::preferred_lanes();
        let probe = symbols.len().min(10_000);
        let ri = rans_encode_interleaved(&model, &symbols[..probe], lanes);
        assert_eq!(
            rans_decode_interleaved(&model, &ri, probe),
            symbols[..probe]
        );
        let hi = huff.encode_interleaved(&symbols[..probe], lanes);
        assert_eq!(huff.decode_interleaved(&hi, probe), symbols[..probe]);
        let r_rate = renc.len() as f64 * 8.0 / symbols.len() as f64;
        // information content under the smoothed sample model
        let probs = smoothed_probs(&counts);
        let _ic = information_content(&symbols[..1000], &probs);
        rep.row(vec![
            b.to_string(),
            fmt(h),
            fmt(h_rate),
            fmt(r_rate),
            fmt((h_rate / h - 1.0) * 100.0),
        ]);
    }
    rep.note("paper fig. 24: elementwise Huffman is near-optimal; (bzip2 → rANS substitution)");
    Ok(rep)
}

// used by fig4/figs via grid target search — re-exported for examples
pub fn grid_rate_error(data: &[f32], bits: f64) -> (f64, f64) {
    let r = grid_for_target_bits(data, bits);
    (
        r.bits_per_element,
        (r.sq_err
            / data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>())
        .sqrt(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> RunOpts {
        RunOpts {
            samples: 1 << 14,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_point_is_deterministic_and_sane() {
        let a = sweep_point("cbrt-t5@4:block64-absmax", 1 << 14, 3).unwrap();
        let b = sweep_point("cbrt-t5@4:block64-absmax", 1 << 14, 3).unwrap();
        assert_eq!(a.bits, b.bits);
        assert_eq!(a.r, b.r);
        // 4-bit elements + bf16/64 scales
        assert!((a.bits - 4.25).abs() < 1e-9, "{}", a.bits);
        assert!(a.r > 0.0 && a.r < 0.2, "{}", a.r);
        assert!((a.r2b - a.r * 2f64.powf(a.bits)).abs() < 1e-12);
        // different seed ⇒ different draw
        let c = sweep_point("cbrt-t5@4:block64-absmax", 1 << 14, 4).unwrap();
        assert_ne!(a.r, c.r);
        // more bits ⇒ lower error
        let hi = sweep_point("cbrt-t5@6:block64-absmax", 1 << 14, 3).unwrap();
        assert!(hi.r < a.r);
    }

    #[test]
    fn fig2_ratio_near_one() {
        let rep = fig2_curves(&quick_opts()).unwrap();
        assert_eq!(rep.rows.len(), 6);
        for row in &rep.rows {
            let ratio: f64 = row[4].parse().unwrap();
            assert!(
                (0.8..1.3).contains(&ratio),
                "lloyd/cbrt ratio {ratio} out of family ({row:?})"
            );
        }
    }

    #[test]
    fn fig4_shape_holds() {
        // the paper's central simulated result, at reduced sample count:
        // absmax-b128 beats tensor-rms uncompressed on heavy tails, and
        // rms+compress beats absmax+compress
        let rep = fig4_sim_tradeoff(&RunOpts {
            samples: 1 << 16,
            ..Default::default()
        })
        .unwrap();
        let parse = |cell: &str| -> f64 {
            cell.split_whitespace().next().unwrap().parse().unwrap()
        };
        let mut checked = 0;
        for row in &rep.rows {
            if row[0] == "student_t5" && row[1] == "4" {
                let rms = parse(&row[2]);
                let absmax = parse(&row[3]);
                let rms_c = parse(&row[4]);
                let absmax_c = parse(&row[5]);
                assert!(absmax < rms, "absmax {absmax} vs rms {rms}");
                assert!(rms_c <= absmax_c * 1.05, "{rms_c} vs {absmax_c}");
                checked += 1;
            }
        }
        assert_eq!(checked, 1);
    }

    #[test]
    fn fig22_alpha_third_wins() {
        let rep = fig22_alpha(&quick_opts()).unwrap();
        // for the normal-rms column, α=1/3 row must be the minimum
        let col = 1;
        let vals: Vec<f64> = rep
            .rows
            .iter()
            .map(|r| r[col].parse().unwrap())
            .collect();
        let third_idx = rep
            .rows
            .iter()
            .position(|r| r[0] == "0.333")
            .unwrap();
        let alpha_third = vals[third_idx];
        for (i, v) in vals.iter().enumerate() {
            assert!(
                alpha_third <= v * 1.02,
                "alpha=1/3 ({alpha_third}) beaten at row {i} ({v})"
            );
        }
    }

    #[test]
    fn fig24_huffman_close() {
        let rep = fig24_compressors(&quick_opts()).unwrap();
        for row in &rep.rows {
            let overhead: f64 = row[4].parse().unwrap();
            assert!(overhead < 5.0, "huffman overhead {overhead}%");
        }
    }
}
