//! LLM direct-cast evaluations (§4): quantise microllama checkpoints under
//! a [`Scheme`], run teacher-forced logits through PJRT and score top-k KL
//! against the bf16/f32 reference — the machinery behind figs. 1, 5, 6, 8,
//! 11-13, 17, 25-35 and table 5.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::alloc::{
    flat_allocation, heuristic_allocation, predicted_kl, round_allocation,
    variable_allocation, AllocScheme, Allocation, TensorInfo,
};
use crate::coordinator::config::Scheme;
use crate::coordinator::{fmt, Report};
use crate::eval::pipeline::qdq_tensor;
use crate::eval::RunOpts;
use crate::fisher::FisherEstimate;
use crate::kl::{cross_entropy_batch, topk_kl_batch, KlSummary};
use crate::runtime::model::{Checkpoint, ModelRunner, TokenSplit};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::stats;

pub const TOP_K: usize = 64;

/// Shared evaluation environment: runtime + per-size caches.
pub struct Env {
    pub rt: Runtime,
    pub opts: RunOpts,
    checkpoints: HashMap<String, Checkpoint>,
    ref_logits: HashMap<String, Vec<f32>>,
    eval_tokens: HashMap<String, TokenSplit>,
    fisher: HashMap<String, FisherEstimate>,
    /// QAT-trained master parameters, keyed by scheme tag (see eval::qat).
    pub qat_cache: HashMap<String, HashMap<String, Vec<f32>>>,
}

/// One measured point on a trade-off curve.
#[derive(Clone, Copy, Debug)]
pub struct DcPoint {
    pub bits: f64,
    pub kl: KlSummary,
    /// change in cross entropy vs the reference (nats/token)
    pub delta_ce: f64,
    /// parameter-space relative RMS error
    pub r: f64,
}

impl Env {
    pub fn open(opts: RunOpts) -> Result<Env> {
        Ok(Env {
            rt: Runtime::open_default()?,
            opts,
            checkpoints: HashMap::new(),
            ref_logits: HashMap::new(),
            eval_tokens: HashMap::new(),
            fisher: HashMap::new(),
            qat_cache: HashMap::new(),
        })
    }

    pub fn checkpoint(&mut self, size: &str) -> Result<&Checkpoint> {
        if !self.checkpoints.contains_key(size) {
            let ck = Checkpoint::load(&self.rt, size)?;
            self.checkpoints.insert(size.to_string(), ck);
        }
        Ok(&self.checkpoints[size])
    }

    pub fn tokens(&mut self, size: &str, split: &str) -> Result<&TokenSplit> {
        let key = format!("{size}:{split}");
        if !self.eval_tokens.contains_key(&key) {
            let t = TokenSplit::load(&self.rt, size, split)?;
            self.eval_tokens.insert(key.clone(), t);
        }
        Ok(&self.eval_tokens[&key])
    }

    fn eval_token_buf(&mut self, size: &str) -> Result<Vec<i32>> {
        let n = self.opts.eval_seqs;
        Ok(self.tokens(size, "eval")?.take(n).to_vec())
    }

    /// Reference logits over the eval subset (cached per size).
    pub fn ref_logits(&mut self, size: &str) -> Result<&[f32]> {
        if !self.ref_logits.contains_key(size) {
            let ck = self.checkpoint(size)?;
            let config = ck.config.clone();
            let params = ck.params();
            let toks = self.eval_token_buf(size)?;
            let runner = ModelRunner::new(&self.rt, size, config)?;
            let logits = runner.logits(&params, &toks)?;
            self.ref_logits.insert(size.to_string(), logits);
        }
        Ok(&self.ref_logits[size])
    }

    /// Fisher estimate (cached in memory and on disk next to artifacts).
    pub fn fisher(&mut self, size: &str) -> Result<&FisherEstimate> {
        if !self.fisher.contains_key(size) {
            let path = self.rt.data_path(&format!("fisher_{size}.owt"));
            let est = if path.exists() {
                FisherEstimate::load(&path)?
            } else {
                let ck = self.checkpoint(size)?;
                let params = ck.params();
                let toks = TokenSplit::load(&self.rt, size, "fisher")?;
                let est = FisherEstimate::estimate(
                    &self.rt, size, &params, &toks, 4, 1234, false,
                )?;
                est.save(&path)?;
                est
            };
            self.fisher.insert(size.to_string(), est);
        }
        Ok(&self.fisher[size])
    }

    /// Quantise a full checkpoint. Returns (params, avg bits, param-space R).
    /// `bits_override` maps tensor name → bit width (variable allocation);
    /// `use_fisher` enables Fisher-weighted selection/search inside the
    /// pipeline.
    pub fn quantise(
        &mut self,
        size: &str,
        scheme: &Scheme,
        bits_override: Option<&HashMap<String, f64>>,
        use_fisher: bool,
    ) -> Result<(HashMap<String, Vec<f32>>, f64, f64)> {
        let fisher: Option<HashMap<String, Vec<f32>>> = if use_fisher {
            Some(self.fisher(size)?.diag.clone())
        } else {
            None
        };
        let ck = self.checkpoint(size)?;
        let mut params = HashMap::new();
        let mut total_bits = 0f64;
        let mut total_elems = 0usize;
        let mut sq = 0f64;
        let mut norm = 0f64;
        for t in &ck.store.tensors {
            let data = t.as_f32();
            let mut s = scheme.clone();
            if let Some(map) = bits_override {
                if let Some(&b) = map.get(&t.name) {
                    s.bits = b;
                }
            }
            let empty: Vec<f32> = Vec::new();
            let fvec: &[f32] = fisher
                .as_ref()
                .and_then(|f| f.get(&t.name))
                .unwrap_or(&empty);
            let out = qdq_tensor(
                &s,
                &data,
                &t.shape,
                t.channel_axis,
                fvec,
                0xC0DE ^ t.numel() as u64,
            )?;
            total_bits += out.bits * t.numel() as f64;
            total_elems += t.numel();
            sq += out.sq_err;
            norm += data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
            params.insert(t.name.clone(), out.recon);
        }
        Ok((
            params,
            total_bits / total_elems as f64,
            (sq / norm.max(1e-30)).sqrt(),
        ))
    }

    /// Evaluate quantised parameters: top-k KL + ΔCE vs the reference.
    pub fn evaluate(
        &mut self,
        size: &str,
        params: &HashMap<String, Vec<f32>>,
    ) -> Result<(KlSummary, f64)> {
        let config = self.checkpoint(size)?.config.clone();
        let toks = self.eval_token_buf(size)?;
        self.ref_logits(size)?; // populate cache
        let runner = ModelRunner::new(&self.rt, size, config.clone())?;
        let test = runner.logits(params, &toks)?;
        let reference = &self.ref_logits[size];
        let kl = topk_kl_batch(reference, &test, config.vocab, TOP_K);
        // next-token ΔCE (teacher forcing: shift targets by one)
        let (ce_ref, ce_test) =
            (ce_of(reference, &toks, &config), ce_of(&test, &toks, &config));
        Ok((kl, ce_test - ce_ref))
    }

    /// One full direct-cast point.
    pub fn direct_cast(
        &mut self,
        size: &str,
        scheme: &Scheme,
        bits_override: Option<&HashMap<String, f64>>,
        use_fisher: bool,
    ) -> Result<DcPoint> {
        let (params, bits, r) =
            self.quantise(size, scheme, bits_override, use_fisher)?;
        let (kl, delta_ce) = self.evaluate(size, &params)?;
        Ok(DcPoint {
            bits,
            kl,
            delta_ce,
            r,
        })
    }

    /// The PJRT-side unit of work of `owf sweep`: one direct-cast point as
    /// a JSONL metrics fragment (the engine adds the identity columns).
    pub fn sweep_row(
        &mut self,
        size: &str,
        scheme: &Scheme,
    ) -> Result<Json> {
        let p = self.direct_cast(size, scheme, None, false)?;
        Ok(Json::obj()
            .push("bits", p.bits)
            .push("kl", p.kl.mean)
            .push("kl_sem", p.kl.sem)
            .push("delta_ce", p.delta_ce)
            .push("r", p.r))
    }

    /// Per-tensor [`TensorInfo`] for the allocator.
    pub fn tensor_infos(&mut self, size: &str) -> Result<Vec<TensorInfo>> {
        let means = self.fisher(size)?.tensor_means();
        let ck = self.checkpoint(size)?;
        Ok(ck
            .store
            .tensors
            .iter()
            .map(|t| TensorInfo {
                name: t.name.clone(),
                numel: t.numel(),
                rms: stats::rms(&t.as_f32()),
                fisher_mean: *means.get(&t.name).unwrap_or(&1e-12),
            })
            .collect())
    }
}

/// Next-token cross entropy of flat logits against the token buffer.
fn ce_of(
    logits: &[f32],
    tokens: &[i32],
    config: &crate::runtime::model::ModelConfig,
) -> f64 {
    let (seq, vocab) = (config.seq_len, config.vocab);
    let n_seq = tokens.len() / seq;
    let mut rows = Vec::new();
    let mut targets = Vec::new();
    for s in 0..n_seq {
        for t in 0..seq - 1 {
            let base = (s * seq + t) * vocab;
            rows.extend_from_slice(&logits[base..base + vocab]);
            targets.push(tokens[s * seq + t + 1]);
        }
    }
    cross_entropy_batch(&rows, &targets, vocab)
}

/// The headline scheme set of fig. 1 at a given element bit width.
pub fn headline_schemes(b: u32) -> Vec<(String, String)> {
    vec![
        ("Tensor RMS".into(), format!("cbrt-t7@{b}:tensor-rms")),
        (
            "Tensor RMS + Sparse".into(),
            format!("cbrt-t7@{b}:tensor-rms:sparse0.001"),
        ),
        ("Tensor Absmax".into(), format!("cbrt-t7@{b}:tensor-absmax")),
        (
            "Channel Absmax".into(),
            format!("cbrt-t7@{b}:channel-absmax"),
        ),
        (
            "Block Absmax".into(),
            format!("cbrt-t7@{b}:block128-absmax"),
        ),
        (
            "Tensor RMS + Compress".into(),
            format!("grid@{b}:tensor-rms:compress"),
        ),
    ]
}

// ---------------------------------------------------------------------------
// figures
// ---------------------------------------------------------------------------

/// fig. 1 — the headline bits-vs-KL trade-off.
pub fn fig1_tradeoff(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig1",
        &format!("bits vs top-k KL, microllama-{size} (paper: Llama 3.1 8B)"),
        &["format", "b", "KL mean", "±2se", "ΔCE", "R"],
    );
    for b in [3u32, 4, 5] {
        for (label, spec) in headline_schemes(b) {
            let scheme = Scheme::parse(&spec)?;
            let p = env.direct_cast(&size, &scheme, None, false)?;
            rep.row(vec![
                label,
                fmt(p.bits),
                fmt(p.kl.mean),
                fmt(2.0 * p.kl.sem),
                fmt(p.delta_ce),
                fmt(p.r),
            ]);
        }
    }
    rep.note("paper fig. 1: compress < {block,channel} absmax ≈ rms+sparse ≪ tensor fixed-length");
    Ok(rep)
}

/// fig. 5 — effective bits per parameter β_i for three variable-length
/// mechanisms (summary statistics of the β histogram).
pub fn fig5_bits_hist(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig5",
        "effective per-parameter bits β_i (first MLP down-projection)",
        &["scheme", "mean β", "p10", "p90", "max β"],
    );
    let ck = env.checkpoint(&size)?;
    let t = ck
        .store
        .get("layers.0.mlp.down_proj")
        .context("down_proj missing")?;
    let data = t.as_f32();
    // (a) sparse outliers: 4-bit element + exact outliers
    {
        let sp = crate::quant::outliers::SparseOutliers::by_value(1e-3);
        let idx = sp.select(&data, &[]);
        let idx_bits = (data.len() as f64).log2().ceil();
        let mut betas = vec![4.0f64; data.len()];
        for &i in &idx {
            betas[i as usize] = 32.0 + idx_bits;
        }
        push_beta_row(&mut rep, "sparse 0.1% (4b dense)", &betas);
    }
    // (b) block absmax: the bf16 scale is the block max's encoding
    {
        let block = 128usize;
        let mut betas = vec![4.0f64; data.len()];
        for blk in 0..data.len().div_ceil(block) {
            let start = blk * block;
            let end = (start + block).min(data.len());
            let mut mi = start;
            for i in start..end {
                if data[i].abs() > data[mi].abs() {
                    mi = i;
                }
            }
            betas[mi] = 16.0; // the max is carried by the scale
        }
        push_beta_row(&mut rep, "block128 absmax (4b elem)", &betas);
    }
    // (c) compression on a uniform grid: β_i = −log2 p_i
    {
        let r = crate::compress::grid::grid_for_target_bits(&data, 4.0);
        let grid = crate::compress::grid::UniformGrid::new(r.delta);
        let (idx, _) = grid.encode(&data);
        let (counts, dense) = grid.dense_histogram(&idx);
        let probs = crate::compress::smoothed_probs(&counts);
        let betas: Vec<f64> = dense
            .iter()
            .map(|&s| -probs[s as usize].log2())
            .collect();
        push_beta_row(&mut rep, "uniform grid + compress (b≈4)", &betas);
    }
    rep.note("paper fig. 5: all three act as variable-length codes over |θ|");
    Ok(rep)
}

fn push_beta_row(rep: &mut Report, name: &str, betas: &[f64]) {
    rep.row(vec![
        name.into(),
        fmt(stats::mean(betas)),
        fmt(stats::quantile(betas, 0.1)),
        fmt(stats::quantile(betas, 0.9)),
        fmt(betas.iter().fold(0f64, |m, &x| m.max(x))),
    ]);
}

/// fig. 6 — variable bit allocation vs flat, across formats and models.
pub fn fig6_allocation(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig6",
        "Fisher-based variable bit allocation (eq. 5) vs flat",
        &["model", "format", "b", "KL flat", "KL variable", "ratio"],
    );
    for size in ["s", "m"] {
        let infos = env.tensor_infos(size)?;
        for (label, spec) in [
            ("Tensor RMS + Sp", "cbrt-t7@4:tensor-rms:sparse0.001"),
            ("Block Absmax", "cbrt-t7@4:block128-absmax"),
        ] {
            let scheme = Scheme::parse(spec)?;
            let target = 4.0;
            let flat = env.direct_cast(size, &scheme, None, false)?;
            let alloc = variable_allocation(&infos, target);
            let rounded = round_allocation(&infos, &alloc, target);
            let map: HashMap<String, f64> = infos
                .iter()
                .zip(&rounded.bits)
                .map(|(t, &b)| (t.name.clone(), b))
                .collect();
            let var = env.direct_cast(size, &scheme, Some(&map), false)?;
            rep.row(vec![
                size.into(),
                label.into(),
                fmt(rounded.average),
                fmt(flat.kl.mean),
                fmt(var.kl.mean),
                fmt(var.kl.mean / flat.kl.mean.max(1e-12)),
            ]);
        }
    }
    rep.note("paper fig. 6: variable allocation improves most model/format pairs");
    Ok(rep)
}

/// fig. 8 — ρ = KL·2^2b across models and schemes (+ Huffman reality check).
pub fn fig8_rho_grid(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig8",
        "scaled KL ρ = KL·2^2b across models and schemes",
        &["model", "scheme", "b", "rho", "±2se·2^2b"],
    );
    for size in ["s", "m", "l"] {
        for (label, spec) in [
            ("rms", "cbrt-t7@4:tensor-rms"),
            ("rms+sparse", "cbrt-t7@4:tensor-rms:sparse0.001"),
            ("block-absmax", "cbrt-t7@4:block128-absmax"),
            ("rms+compress", "grid@4:tensor-rms:compress"),
        ] {
            let scheme = Scheme::parse(spec)?;
            let p = env.direct_cast(size, &scheme, None, false)?;
            rep.row(vec![
                size.into(),
                label.into(),
                fmt(p.bits),
                fmt(p.kl.rho(p.bits)),
                fmt(2.0 * p.kl.sem * 2f64.powf(2.0 * p.bits)),
            ]);
        }
    }
    rep.note("paper fig. 8: ordering consistent across families & sizes");
    Ok(rep)
}

/// fig. 11 — Fisher predicts the KL of iid per-tensor perturbations.
pub fn fig11_fisher_pred(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig11",
        "per-tensor noise: predicted (eq. 7) vs measured top-k KL (microllama-s)",
        &["tensor", "sigma", "KL predicted", "KL measured"],
    );
    let size = "s";
    env.fisher(size)?;
    let ck = env.checkpoint(size)?;
    let params = ck.params();
    let names: Vec<String> = [
        "embed_tokens",
        "layers.0.self_attn.v_proj",
        "layers.0.self_attn.q_proj",
        "layers.1.mlp.down_proj",
        "lm_head",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let mut rng = crate::util::rng::Rng::new(0xF11);
    for name in &names {
        for sigma in [0.01f32, 0.04] {
            let mut perturbed = params.clone();
            let v = perturbed.get_mut(name).context("tensor")?;
            for x in v.iter_mut() {
                *x += sigma * rng.normal() as f32;
            }
            let predicted =
                env.fisher(size)?.predict_kl(&params, &perturbed);
            let (kl, _) = env.evaluate(size, &perturbed)?;
            rep.row(vec![
                name.clone(),
                fmt(sigma as f64),
                fmt(predicted),
                fmt(kl.mean),
            ]);
        }
    }
    rep.note("paper fig. 11: prediction tracks measurement across tensors/scales");
    Ok(rep)
}

/// fig. 12 — Fisher diagonal: across- vs within-tensor variation.
pub fn fig12_fisher_structure(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig12",
        &format!("Fisher diagonal structure, microllama-{size}"),
        &["tensor", "mean f̄", "log10 within-tensor std"],
    );
    let summaries = env.fisher(&size)?.tensor_summaries();
    let mut means = Vec::new();
    for t in &summaries {
        means.push(t.mean.max(1e-30).log10());
        rep.row(vec![
            t.name.clone(),
            fmt(t.mean),
            fmt(t.log10_within_std),
        ]);
    }
    rep.note(format!(
        "across-tensor log10-std = {} (paper fig. 12: across ≈ within)",
        fmt(stats::std(&means))
    ));
    Ok(rep)
}

/// fig. 13 — fig. 11's prediction across model sizes.
pub fn fig13_fisher_models(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig13",
        "Fisher KL prediction across models (correlation of log KL)",
        &["model", "n points", "pearson(log pred, log meas)"],
    );
    for size in ["s", "m"] {
        env.fisher(size)?;
        let ck = env.checkpoint(size)?;
        let params = ck.params();
        let names: Vec<String> =
            ck.store.names().iter().map(|s| s.to_string()).collect();
        let mut rng = crate::util::rng::Rng::new(0xF13);
        let (mut preds, mut meas) = (Vec::new(), Vec::new());
        for name in names.iter().step_by(3) {
            let mut perturbed = params.clone();
            let v = perturbed.get_mut(name).unwrap();
            for x in v.iter_mut() {
                *x += 0.02 * rng.normal() as f32;
            }
            preds.push(
                env.fisher(size)?
                    .predict_kl(&params, &perturbed)
                    .max(1e-12)
                    .ln(),
            );
            let (kl, _) = env.evaluate(size, &perturbed)?;
            meas.push(kl.mean.max(1e-12).ln());
        }
        rep.row(vec![
            size.into(),
            preds.len().to_string(),
            fmt(stats::pearson(&preds, &meas)),
        ]);
    }
    rep.note("paper fig. 13: clear positive trend (Gemma-like failures absent here)");
    Ok(rep)
}

/// fig. 17 — the per-tensor b*_t profile at b=4.
pub fn fig17_alloc_profile(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig17",
        &format!("variable bit allocation profile, target 4 b/param ({size})"),
        &["tensor", "numel", "rms", "f̄", "b*_t"],
    );
    let infos = env.tensor_infos(&size)?;
    let alloc = variable_allocation(&infos, 4.0);
    for (t, &b) in infos.iter().zip(&alloc.bits) {
        rep.row(vec![
            t.name.clone(),
            t.numel.to_string(),
            fmt(t.rms),
            fmt(t.fisher_mean),
            fmt(b),
        ]);
    }
    rep.note("paper fig. 17: attention k/v projections get extra bits (GQA)");
    Ok(rep)
}

/// fig. 25 — weight statistics: heavy tails across tensors.
pub fn fig25_weight_stats(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig25",
        &format!("|θ|/RMS tail statistics per tensor, microllama-{size}"),
        &["tensor", "kurtosis", "q99.9/rms", "max/rms"],
    );
    let ck = env.checkpoint(&size)?;
    for t in &ck.store.tensors {
        if t.shape.len() < 2 {
            continue;
        }
        let v = t.as_f32();
        let rms = stats::rms(&v).max(1e-30);
        let xs: Vec<f64> =
            v.iter().map(|&x| (x as f64 / rms).abs()).collect();
        let m2 = xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64;
        let m4 = xs.iter().map(|x| x.powi(4)).sum::<f64>() / xs.len() as f64;
        rep.row(vec![
            t.name.clone(),
            fmt(m4 / (m2 * m2)),
            fmt(stats::quantile(&xs, 0.999)),
            fmt(xs.iter().fold(0f64, |m, &x| m.max(x))),
        ]);
    }
    rep.note("paper fig. 25: kurtosis > 3 (Normal) ⇒ heavy, Student-t-like tails");
    Ok(rep)
}

/// fig. 26 — top-k KL correlates with ΔCE.
pub fn fig26_kl_vs_ce(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig26",
        "top-k KL vs ΔCE across a quantisation sweep",
        &["scheme", "b", "KL", "ΔCE"],
    );
    let (mut kls, mut ces) = (Vec::new(), Vec::new());
    for b in [3u32, 4, 5] {
        for spec in [
            format!("cbrt-t7@{b}:block128-absmax"),
            format!("int@{b}:block128-absmax"),
            format!("cbrt-t7@{b}:tensor-rms"),
        ] {
            let p =
                env.direct_cast(&size, &Scheme::parse(&spec)?, None, false)?;
            kls.push(p.kl.mean.max(1e-12).ln());
            ces.push(p.delta_ce.max(1e-12).ln());
            rep.row(vec![
                spec,
                fmt(p.bits),
                fmt(p.kl.mean),
                fmt(p.delta_ce),
            ]);
        }
    }
    rep.note(format!(
        "pearson(log KL, log ΔCE) = {} (paper fig. 26: ≈ 1)",
        fmt(stats::pearson(&kls, &ces))
    ));
    Ok(rep)
}

/// fig. 27 — sampled vs empirical Fisher.
pub fn fig27_fisher_variants(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig27",
        "sampled-label vs empirical Fisher (per-tensor means, microllama-m)",
        &["tensor", "sampled", "empirical", "ratio"],
    );
    let size = "m";
    let ck = env.checkpoint(size)?;
    let params = ck.params();
    let toks = TokenSplit::load(&env.rt, size, "fisher")?;
    let emp = FisherEstimate::estimate(
        &env.rt, size, &params, &toks, 2, 99, true,
    )?;
    let emp_means = emp.tensor_means();
    let sampled = env.fisher(size)?.tensor_means();
    let (mut a, mut b) = (Vec::new(), Vec::new());
    let mut names: Vec<&String> = sampled.keys().collect();
    names.sort();
    for name in names {
        let s = sampled[name];
        let e = *emp_means.get(name).unwrap_or(&0.0);
        a.push(s.max(1e-30).ln());
        b.push(e.max(1e-30).ln());
        rep.row(vec![
            name.clone(),
            fmt(s),
            fmt(e),
            fmt(e / s.max(1e-30)),
        ]);
    }
    rep.note(format!(
        "pearson(log sampled, log empirical) = {} (paper fig. 27: tight, empirical slightly larger)",
        fmt(stats::pearson(&a, &b))
    ));
    Ok(rep)
}

/// fig. 28 — under compression, block scaling / sparsity stop helping.
pub fn fig28_compress_interaction(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig28",
        "scaling × sparsity × compression interaction (ρ at b≈4)",
        &["scheme", "b", "rho"],
    );
    for spec in [
        "cbrt-t7@4:tensor-rms",
        "cbrt-t7@4:tensor-rms:compress",
        "cbrt-t7@4:block128-absmax",
        "cbrt-t7@4:block128-absmax:compress",
        "cbrt-t7@4:tensor-rms:sparse0.001,compress",
        "cbrt-t7@4:channel-rms:compress",
    ] {
        let p = env.direct_cast(&size, &Scheme::parse(spec)?, None, false)?;
        rep.row(vec![spec.into(), fmt(p.bits), fmt(p.kl.rho(p.bits))]);
    }
    rep.note("paper fig. 28: compression absorbs block/sparse gains; channel RMS keeps a small edge");
    Ok(rep)
}

/// fig. 29 — random rotations help fixed-length schemes only.
pub fn fig29_rotations(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig29",
        "random rotations (cbrt-normal elements, b=4)",
        &["scheme", "KL plain", "KL rotated", "rotated/plain"],
    );
    for spec in [
        "cbrt-normal@4:tensor-rms",
        "cbrt-normal@4:tensor-rms:sparse0.001",
        "cbrt-normal@4:block128-absmax",
        "grid@4:tensor-rms:compress",
    ] {
        let plain =
            env.direct_cast(&size, &Scheme::parse(spec)?, None, false)?;
        let rot_scheme = Scheme::parse(spec)?.with_rotate();
        let rotated = env.direct_cast(&size, &rot_scheme, None, false)?;
        rep.row(vec![
            spec.into(),
            fmt(plain.kl.mean),
            fmt(rotated.kl.mean),
            fmt(rotated.kl.mean / plain.kl.mean.max(1e-12)),
        ]);
    }
    rep.note("paper fig. 29: rotations rescue tensor fixed-length, don't help variable-length");
    Ok(rep)
}

/// fig. 30 — allocation from Fisher computed on a *different* domain.
pub fn fig30_cross_domain(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig30",
        "bit allocation evaluated cross-domain (xdom eval split)",
        &["model", "alloc", "KL (xdom)"],
    );
    for size in ["s", "m"] {
        let infos = env.tensor_infos(size)?;
        let scheme = Scheme::parse("cbrt-t7@4:tensor-rms:sparse0.001")?;
        // evaluate on the cross-domain split
        let n_eval = env.opts.eval_seqs;
        let xdom = env.tokens(size, "xdom")?.take(n_eval).to_vec();
        for (name, alloc) in [
            (AllocScheme::Flat, flat_allocation(&infos, 4.0)),
            (AllocScheme::Variable, variable_allocation(&infos, 4.0)),
            (
                AllocScheme::Heuristic,
                heuristic_allocation(
                    &infos,
                    4.0,
                    env.checkpoint(size)?.config.n_layers,
                ),
            ),
        ]
        .map(|(n, a)| (n, round_allocation(&infos, &a, 4.0)))
        {
            let map: HashMap<String, f64> = infos
                .iter()
                .zip(&alloc.bits)
                .map(|(t, &b)| (t.name.clone(), b))
                .collect();
            let (params, _, _) =
                env.quantise(size, &scheme, Some(&map), false)?;
            // cross-domain logits
            let config = env.checkpoint(size)?.config.clone();
            let ref_params = env.checkpoint(size)?.params();
            let runner = ModelRunner::new(&env.rt, size, config.clone())?;
            let ref_logits = runner.logits(&ref_params, &xdom)?;
            let test_logits = runner.logits(&params, &xdom)?;
            let kl =
                topk_kl_batch(&ref_logits, &test_logits, config.vocab, TOP_K);
            rep.row(vec![
                size.into(),
                format!("{name:?}"),
                fmt(kl.mean),
            ]);
        }
    }
    rep.note("paper fig. 30: Fisher generalises across domains; heuristic (+2b ends) is poor");
    Ok(rep)
}

/// fig. 31 — element-format shootout vs the Student-t baseline.
pub fn fig31_element_shootout(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig31",
        "element formats vs cbrt-t (tensor RMS + sparse), mean over b=3..5",
        &["element", "mean KL ratio vs cbrt-t"],
    );
    let mut base_kl = HashMap::new();
    for b in [3u32, 4, 5] {
        let p = env.direct_cast(
            &size,
            &Scheme::parse(&format!("cbrt-t7@{b}:tensor-rms:sparse0.001"))?,
            None,
            false,
        )?;
        base_kl.insert(b, p.kl.mean);
    }
    for elem in ["cbrt-normal", "cbrt-laplace", "nf", "int", "e2m1", "lloyd"] {
        let mut ratios = Vec::new();
        for b in [3u32, 4, 5] {
            if elem == "e2m1" && b != 4 {
                continue; // fixed-width float
            }
            let spec = format!("{elem}@{b}:tensor-rms:sparse0.001");
            let p =
                env.direct_cast(&size, &Scheme::parse(&spec)?, None, false)?;
            ratios.push(p.kl.mean / base_kl[&b].max(1e-12));
        }
        rep.row(vec![elem.into(), fmt(stats::mean(&ratios))]);
    }
    rep.note("paper fig. 31: no element format consistently beats cbrt Student-t");
    Ok(rep)
}

/// fig. 32 — √[3]p vs NF4/SF4 under block absmax.
pub fn fig32_nf4_sf4(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig32",
        "4-bit block-absmax formats vs block size",
        &["element", "B", "b", "rho"],
    );
    for elem in ["cbrt-normal", "cbrt-laplace", "cbrt-t7", "nf", "sf5", "af4"]
    {
        for block in [64usize, 128, 256] {
            let spec = format!("{elem}@4:block{block}-absmax");
            let p =
                env.direct_cast(&size, &Scheme::parse(&spec)?, None, false)?;
            rep.row(vec![
                elem.into(),
                block.to_string(),
                fmt(p.bits),
                fmt(p.kl.rho(p.bits)),
            ]);
        }
    }
    rep.note("paper fig. 32: cbrt-t/laplace best; cbrt-normal ≈ NF4; SF4 behind");
    Ok(rep)
}

/// fig. 33 — LLM block size & scale-mantissa sweep.
pub fn fig33_llm_block(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig33",
        "block-absmax hyperparameters (cbrt-t elements, b≈4)",
        &["B", "scale fmt", "b", "rho"],
    );
    for block in [32usize, 64, 128, 256, 512] {
        let spec = format!("cbrt-t7@4:block{block}-absmax");
        let p = env.direct_cast(&size, &Scheme::parse(&spec)?, None, false)?;
        rep.row(vec![
            block.to_string(),
            "bf16".into(),
            fmt(p.bits),
            fmt(p.kl.rho(p.bits)),
        ]);
    }
    for (name, sf) in [
        ("e8m0", crate::scaling::ScaleFormat::E8M0 { away: true }),
        (
            "e5m4",
            crate::scaling::ScaleFormat::Float { exp: 5, man: 4, away: true },
        ),
        ("bf16", crate::scaling::DEFAULT_SCALE),
    ] {
        let scheme = Scheme::parse("cbrt-t7@4:block128-absmax")?
            .with_scale_format(sf);
        let p = env.direct_cast(&size, &scheme, None, false)?;
        rep.row(vec![
            "128".into(),
            name.into(),
            fmt(p.bits),
            fmt(p.kl.rho(p.bits)),
        ]);
    }
    rep.note("paper fig. 33: B≈128 with a ≥4-mantissa-bit scale wins");
    Ok(rep)
}

/// fig. 34 — signmax vs asymmetric vs symmetric.
pub fn fig34_signmax(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig34",
        "scaling variants, block B=128 (int and cbrt-t elements)",
        &["element", "variant", "b", "b width", "rho"],
    );
    for elem in ["int", "cbrt-t7"] {
        for b in [3u32, 4] {
            for (vname, spec) in [
                ("asym", format!("{elem}@{b}:block128-absmax:asym")),
                ("sym", format!("{elem}@{b}:block128-absmax:sym")),
                ("signmax", format!("{elem}@{b}:block128-signmax")),
            ] {
                let p = env.direct_cast(
                    &size,
                    &Scheme::parse(&spec)?,
                    None,
                    false,
                )?;
                rep.row(vec![
                    elem.into(),
                    vname.into(),
                    b.to_string(),
                    fmt(p.bits),
                    fmt(p.kl.rho(p.bits)),
                ]);
            }
        }
    }
    rep.note("paper fig. 34: signmax consistently best, especially at b=3");
    Ok(rep)
}

/// fig. 35 — moment matching vs scale search vs Fisher-weighted search.
pub fn fig35_scale_fit(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "fig35",
        "scale fitting strategies (cbrt-t elements, b=4)",
        &["scaling", "moment", "search", "fisher-search"],
    );
    for scaling in ["tensor-rms", "block128-absmax"] {
        let base = format!("cbrt-t7@4:{scaling}");
        let moment =
            env.direct_cast(&size, &Scheme::parse(&base)?, None, false)?;
        let search = env.direct_cast(
            &size,
            &Scheme::parse(&format!("{base}:search"))?,
            None,
            false,
        )?;
        let fsearch = env.direct_cast(
            &size,
            &Scheme::parse(&format!("{base}:search"))?,
            None,
            true,
        )?;
        rep.row(vec![
            scaling.into(),
            fmt(moment.kl.mean),
            fmt(search.kl.mean),
            fmt(fsearch.kl.mean),
        ]);
    }
    rep.note("paper fig. 35: search helps RMS scaling; absmax prefers moment matching unless Fisher-weighted");
    Ok(rep)
}

/// table 5 — variation of the allocation terms across tensors.
pub fn tab5_alloc_terms(env: &mut Env) -> Result<Report> {
    let size = env.opts.size.clone();
    let mut rep = Report::new(
        "tab5",
        "std / inter-decile range of eq.-(5) terms across tensors",
        &["term", "std", "q90-q10"],
    );
    let infos = env.tensor_infos(&size)?;
    let half_log_f: Vec<f64> = infos
        .iter()
        .map(|t| 0.5 * t.fisher_mean.max(1e-30).log2())
        .collect();
    let log_rms: Vec<f64> =
        infos.iter().map(|t| t.rms.max(1e-30).log2()).collect();
    for (name, vals) in [("½log2 f̄", &half_log_f), ("log2 rms", &log_rms)] {
        rep.row(vec![
            name.into(),
            fmt(stats::std(vals)),
            fmt(stats::quantile(vals, 0.9) - stats::quantile(vals, 0.1)),
        ]);
    }
    // ε_t variation: estimated from observed R at fixed b per tensor
    let scheme = Scheme::parse("cbrt-t7@4:block128-absmax")?;
    let ck = env.checkpoint(&size)?;
    let mut log_eps = Vec::new();
    for t in &ck.store.tensors {
        if t.shape.len() < 2 {
            continue;
        }
        let data = t.as_f32();
        let out = qdq_tensor(&scheme, &data, &t.shape, t.channel_axis, &[], 9)?;
        let r = (out.sq_err
            / data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>())
        .sqrt();
        // R ≈ ε·2^-b ⇒ log2 ε = log2 R + b
        log_eps.push(r.max(1e-12).log2() + 4.0);
    }
    rep.row(vec![
        "log2 ε".into(),
        fmt(stats::std(&log_eps)),
        fmt(stats::quantile(&log_eps, 0.9) - stats::quantile(&log_eps, 0.1)),
    ]);
    rep.note("paper table 5: ε varies far less than f̄ and RMS ⇒ fold into b⁰");
    Ok(rep)
}

/// Predicted-KL helper shared with examples.
pub fn predicted_kl_for(
    infos: &[TensorInfo],
    alloc: &Allocation,
) -> f64 {
    predicted_kl(infos, alloc)
}
