//! Quantisation-aware training (§D "Quantisation aware training") and the
//! downstream-task proxy battery (figs. 7/9/10, tables 1/2).
//!
//! QAT runs the AOT `qat_step_m_*` artifact: an STE-quantised forward
//! (through the Pallas qdq kernel), full-KL loss against reference logits
//! and a fused Adam update — one PJRT call per step.  The downstream proxy
//! replaces OLMES (unavailable offline) with four synthetic probe tasks
//! scored by the same argmax machinery (DESIGN.md "Substitutions").

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::coordinator::config::Scheme;
use crate::coordinator::{fmt, Report};
use crate::eval::llm::{headline_schemes, Env};
use crate::runtime::model::ModelRunner;
use crate::runtime::OwnedValue;
use crate::util::stats;

/// The QAT variants with exported step artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QatKind {
    BlockAbsmax128,
    TensorRms,
}

impl QatKind {
    fn artifact(&self) -> &'static str {
        match self {
            QatKind::BlockAbsmax128 => "qat_step_m_block128_absmax",
            QatKind::TensorRms => "qat_step_m_tensor_rms",
        }
    }

    /// The matching direct-cast scheme for final evaluation at `b` bits.
    pub fn scheme(&self, b: u32) -> String {
        match self {
            QatKind::BlockAbsmax128 => {
                format!("cbrt-t7@{b}:block128-absmax")
            }
            QatKind::TensorRms => format!("cbrt-t7@{b}:tensor-rms"),
        }
    }
}

/// Pad a codebook to the artifact's 16-slot LUT by duplicating codepoints
/// (nearest-neighbour semantics are unchanged; verified in python tests).
/// The artifact requires sorted slots, so sort unconditionally — callers
/// pass `Codebook::points()` (already sorted) but this is a cold path and
/// the guarantee is worth one ≤16-element sort.
fn pad_codebook(points: &[f32]) -> Vec<f32> {
    let mut out = points.to_vec();
    while out.len() < 16 {
        out.push(*out.last().unwrap());
    }
    out.sort_by(|a, b| a.total_cmp(b));
    out
}

/// Train QAT masters for a scheme; returns the *master* parameters (to be
/// direct-cast with the same scheme for evaluation). Cached per tag in Env.
pub fn qat_train(
    env: &mut Env,
    kind: QatKind,
    bits: u32,
    steps: usize,
) -> Result<HashMap<String, Vec<f32>>> {
    let tag = format!("{kind:?}@{bits}x{steps}");
    if let Some(p) = env.qat_cache.get(&tag) {
        return Ok(p.clone());
    }
    let size = "m";
    let scheme = Scheme::parse(&kind.scheme(bits))?;
    let codebook = pad_codebook(
        scheme
            .build_codebook(128, None, &[])?
            .points(),
    );

    let ck = env.checkpoint(size)?;
    let config = ck.config.clone();
    let mut params = ck.params();
    let mut m: HashMap<String, Vec<f32>> = params
        .iter()
        .map(|(k, v)| (k.clone(), vec![0f32; v.len()]))
        .collect();
    let mut v = m.clone();

    // reference logits over the QAT train pool, computed once
    let train = env.tokens(size, "train")?;
    let pool_tokens: Vec<i32> = train.tokens.clone();
    let pool_seqs = train.n_seq;
    let seq = train.seq_len;
    let ref_params = env.checkpoint(size)?.params();
    let runner = ModelRunner::new(&env.rt, size, config.clone())?;
    let pool_logits = runner.logits(&ref_params, &pool_tokens)?;

    let info = env.rt.artifact(kind.artifact())?.clone();
    let qat_batch = info
        .inputs
        .iter()
        .find(|s| s.dtype == "int32")
        .context("no tokens input")?
        .shape[0];
    let vocab = config.vocab;
    // lr ∝ 2^-b heuristic from table 6
    let lr = 2f32.powi(-(6 + bits as i32));

    let mut loss_first = f64::NAN;
    let mut loss_last = f64::NAN;
    for step in 0..steps {
        let start = (step * qat_batch) % pool_seqs;
        let mut toks = vec![0i32; qat_batch * seq];
        let mut refs = vec![0f32; qat_batch * seq * vocab];
        for row in 0..qat_batch {
            let s = (start + row) % pool_seqs;
            toks[row * seq..(row + 1) * seq]
                .copy_from_slice(&pool_tokens[s * seq..(s + 1) * seq]);
            refs[row * seq * vocab..(row + 1) * seq * vocab]
                .copy_from_slice(
                    &pool_logits[s * seq * vocab..(s + 1) * seq * vocab],
                );
        }
        // marshal inputs in manifest order:
        // arg0.<p> params, arg1.<p> m, arg2.<p> v, arg3 step, arg4 tokens,
        // arg5 ref logits, arg6 codebook, arg7 lr
        let outputs = env.rt.execute_named(kind.artifact(), |spec| {
            if let Some(p) = spec.name.strip_prefix("arg0.") {
                Ok(OwnedValue::F32(params[p].clone()))
            } else if let Some(p) = spec.name.strip_prefix("arg1.") {
                Ok(OwnedValue::F32(m[p].clone()))
            } else if let Some(p) = spec.name.strip_prefix("arg2.") {
                Ok(OwnedValue::F32(v[p].clone()))
            } else if spec.name == "arg3" {
                Ok(OwnedValue::F32(vec![step as f32]))
            } else if spec.dtype == "int32" {
                Ok(OwnedValue::I32(toks.clone()))
            } else if spec.numel() == qat_batch * seq * vocab {
                Ok(OwnedValue::F32(refs.clone()))
            } else if spec.numel() == 16 {
                Ok(OwnedValue::F32(codebook.clone()))
            } else if spec.name == "arg7" {
                Ok(OwnedValue::F32(vec![lr]))
            } else {
                anyhow::bail!("unmatched input {}", spec.name)
            }
        })?;
        // outputs: out.0.<p>, out.1.<p>, out.2.<p>, out.3 (loss)
        let mut loss = f64::NAN;
        for (spec, out) in info.outputs.iter().zip(outputs) {
            if let Some(p) = spec.name.strip_prefix("out.0.") {
                params.insert(p.to_string(), out);
            } else if let Some(p) = spec.name.strip_prefix("out.1.") {
                m.insert(p.to_string(), out);
            } else if let Some(p) = spec.name.strip_prefix("out.2.") {
                v.insert(p.to_string(), out);
            } else {
                loss = out[0] as f64;
            }
        }
        if step == 0 {
            loss_first = loss;
        }
        loss_last = loss;
    }
    eprintln!(
        "[qat {tag}] {steps} steps: loss {loss_first:.4} -> {loss_last:.4}"
    );
    env.qat_cache.insert(tag, params.clone());
    Ok(params)
}

// ---------------------------------------------------------------------------
// downstream proxy battery
// ---------------------------------------------------------------------------

/// Task names for the downstream proxy (OLMES substitution).
pub const TASKS: [&str; 4] = ["NextTok", "Cloze", "MC4", "XDom"];

/// Score the proxy battery for a parameter set.
pub fn downstream(
    env: &mut Env,
    size: &str,
    params: &HashMap<String, Vec<f32>>,
) -> Result<Vec<f64>> {
    let config = env.checkpoint(size)?.config.clone();
    let n = env.opts.eval_seqs;
    let eval_toks = env.tokens(size, "eval")?.take(n).to_vec();
    let xdom_toks = env.tokens(size, "xdom")?.take(n).to_vec();
    let ref_params = env.checkpoint(size)?.params();
    let runner = ModelRunner::new(&env.rt, size, config.clone())?;
    let logits = runner.logits(params, &eval_toks)?;
    let xlogits = runner.logits(params, &xdom_toks)?;
    let ref_logits = runner.logits(&ref_params, &eval_toks)?;
    let (seq, vocab) = (config.seq_len, config.vocab);
    let n_seq = eval_toks.len() / seq;

    let mut nexttok = (0usize, 0usize);
    let mut cloze = (0usize, 0usize);
    let mut mc4 = (0usize, 0usize);
    let mut rng = crate::util::rng::Rng::new(0xD05E);
    for s in 0..n_seq {
        for t in 0..seq - 1 {
            let base = (s * seq + t) * vocab;
            let row = &logits[base..base + vocab];
            let ref_row = &ref_logits[base..base + vocab];
            let target = eval_toks[s * seq + t + 1] as usize;
            let top1 = argmax(row);
            // NextTok: plain top-1 accuracy
            nexttok.1 += 1;
            nexttok.0 += (top1 == target) as usize;
            // Cloze: positions where the *reference* is confident
            let ref_top1 = argmax(ref_row);
            let conf = softmax_prob(ref_row, ref_top1);
            if conf > 0.5 {
                cloze.1 += 1;
                cloze.0 += (top1 == target) as usize;
            }
            // MC4: pick among the target + 3 seeded distractors
            let mut best = target;
            for _ in 0..3 {
                let d = rng.below(vocab);
                if row[d] > row[best] {
                    best = d;
                }
            }
            mc4.1 += 1;
            mc4.0 += (best == target) as usize;
        }
    }
    let mut xacc = (0usize, 0usize);
    let xn_seq = xdom_toks.len() / seq;
    for s in 0..xn_seq {
        for t in 0..seq - 1 {
            let base = (s * seq + t) * vocab;
            let row = &xlogits[base..base + vocab];
            let target = xdom_toks[s * seq + t + 1] as usize;
            xacc.1 += 1;
            xacc.0 += (argmax(row) == target) as usize;
        }
    }
    let acc = |c: (usize, usize)| c.0 as f64 / c.1.max(1) as f64;
    Ok(vec![acc(nexttok), acc(cloze), acc(mc4), acc(xacc)])
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

fn softmax_prob(row: &[f32], idx: usize) -> f64 {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - max).exp()).sum();
    ((row[idx] as f64) - max).exp() / z
}

/// Downstream mean-accuracy ratio vs the baseline (§D), clipped to [0,1].
fn mean_ratio(accs: &[f64], baseline: &[f64]) -> f64 {
    stats::mean(
        &accs
            .iter()
            .zip(baseline)
            .map(|(&a, &b)| (a / b.max(1e-9)).clamp(0.0, 1.0))
            .collect::<Vec<_>>(),
    )
}

// ---------------------------------------------------------------------------
// figures / tables
// ---------------------------------------------------------------------------

/// table 1 — downstream proxy under direct-cast at b≈3.
pub fn tab1_downstream_dc(env: &mut Env) -> Result<Report> {
    let size = "m".to_string();
    let mut rep = Report::new(
        "tab1",
        "downstream proxy @ b≈3, direct-cast (microllama-m)",
        &["format", "b", "KL", "NextTok", "Cloze", "MC4", "XDom"],
    );
    let baseline_params = env.checkpoint(&size)?.params();
    let base = downstream(env, &size, &baseline_params)?;
    let mut row = vec!["Baseline".to_string(), "32".into(), "0".into()];
    row.extend(base.iter().map(|&a| fmt(a)));
    rep.row(row);
    for (label, spec) in headline_schemes(3) {
        let scheme = Scheme::parse(&spec)?;
        let (params, bits, _) = env.quantise(&size, &scheme, None, false)?;
        let (kl, _) = env.evaluate(&size, &params)?;
        let accs = downstream(env, &size, &params)?;
        let mut row = vec![label, fmt(bits), fmt(kl.mean)];
        row.extend(accs.iter().map(|&a| fmt(a)));
        rep.row(row);
    }
    rep.note("paper table 1: task accuracy follows the KL ranking");
    Ok(rep)
}

/// table 2 — downstream proxy after QAT at b≈3.
pub fn tab2_downstream_qat(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "tab2",
        "downstream proxy @ b≈3 after QAT (microllama-m)",
        &["format", "b", "KL", "NextTok", "Cloze", "MC4", "XDom"],
    );
    let size = "m".to_string();
    let steps = env.opts.qat_steps;
    let baseline_params = env.checkpoint(&size)?.params();
    let base = downstream(env, &size, &baseline_params)?;
    let mut row = vec!["Baseline".to_string(), "32".into(), "0".into()];
    row.extend(base.iter().map(|&a| fmt(a)));
    rep.row(row);
    for kind in [QatKind::BlockAbsmax128, QatKind::TensorRms] {
        let masters = qat_train(env, kind, 3, steps)?;
        let scheme = Scheme::parse(&kind.scheme(3))?;
        // final model: direct-cast of the QAT masters
        let (params, bits) = quantise_masters(env, &scheme, &masters)?;
        let (kl, _) = env.evaluate(&size, &params)?;
        let accs = downstream(env, &size, &params)?;
        let mut row = vec![format!("{kind:?} (QAT)"), fmt(bits), fmt(kl.mean)];
        row.extend(accs.iter().map(|&a| fmt(a)));
        rep.row(row);
    }
    rep.note(format!(
        "paper table 2 (QAT steps: {steps} here vs 8192 in the paper)"
    ));
    Ok(rep)
}

/// Quantise externally-supplied master parameters with a scheme.
fn quantise_masters(
    env: &mut Env,
    scheme: &Scheme,
    masters: &HashMap<String, Vec<f32>>,
) -> Result<(HashMap<String, Vec<f32>>, f64)> {
    let shapes: Vec<(String, Vec<usize>, Option<usize>, usize)> = {
        let ck = env.checkpoint("m")?;
        ck.store
            .tensors
            .iter()
            .map(|t| (t.name.clone(), t.shape.clone(), t.channel_axis, t.numel()))
            .collect()
    };
    let mut out = HashMap::new();
    let mut bits_total = 0f64;
    let mut elems = 0usize;
    for (name, shape, channel_axis, numel) in shapes {
        let data = &masters[&name];
        // QAT leaves 1-D tensors unquantised (norm gains)
        if shape.len() < 2 {
            out.insert(name, data.clone());
            bits_total += 16.0 * numel as f64;
            elems += numel;
            continue;
        }
        let q = crate::eval::pipeline::qdq_tensor(
            scheme,
            data,
            &shape,
            channel_axis,
            &[],
            0xA7,
        )?;
        bits_total += q.bits * numel as f64;
        elems += numel;
        out.insert(name, q.recon);
    }
    Ok((out, bits_total / elems as f64))
}

/// fig. 7 — QAT downstream trade-off.
pub fn fig7_qat_downstream(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig7",
        "bits vs downstream mean-accuracy ratio after QAT (microllama-m)",
        &["format", "b", "KL", "downstream ratio"],
    );
    let steps = env.opts.qat_steps;
    let baseline_params = env.checkpoint("m")?.params();
    let base = downstream(env, "m", &baseline_params)?;
    for kind in [QatKind::BlockAbsmax128, QatKind::TensorRms] {
        for b in [3u32, 4] {
            let masters = qat_train(env, kind, b, steps)?;
            let scheme = Scheme::parse(&kind.scheme(b))?;
            let (params, bits) = quantise_masters(env, &scheme, &masters)?;
            let (kl, _) = env.evaluate("m", &params)?;
            let accs = downstream(env, "m", &params)?;
            rep.row(vec![
                format!("{kind:?}"),
                fmt(bits),
                fmt(kl.mean),
                fmt(mean_ratio(&accs, &base)),
            ]);
        }
    }
    rep.note("paper fig. 7: downstream saturates with b; format choice matters most at b=3");
    Ok(rep)
}

/// fig. 9 — direct-cast vs QAT side by side.
pub fn fig9_dc_vs_qat(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig9",
        "direct-cast vs QAT at b=3 (KL and downstream ratio)",
        &["format", "KL dc", "KL qat", "ds dc", "ds qat"],
    );
    let steps = env.opts.qat_steps;
    let baseline_params = env.checkpoint("m")?.params();
    let base = downstream(env, "m", &baseline_params)?;
    for kind in [QatKind::BlockAbsmax128, QatKind::TensorRms] {
        let scheme = Scheme::parse(&kind.scheme(3))?;
        let dc = env.direct_cast("m", &scheme, None, false)?;
        let (dc_params, _, _) = env.quantise("m", &scheme, None, false)?;
        let dc_ds = mean_ratio(&downstream(env, "m", &dc_params)?, &base);
        let masters = qat_train(env, kind, 3, steps)?;
        let (q_params, _) = quantise_masters(env, &scheme, &masters)?;
        let (q_kl, _) = env.evaluate("m", &q_params)?;
        let q_ds = mean_ratio(&downstream(env, "m", &q_params)?, &base);
        rep.row(vec![
            format!("{kind:?}"),
            fmt(dc.kl.mean),
            fmt(q_kl.mean),
            fmt(dc_ds),
            fmt(q_ds),
        ]);
    }
    rep.note("paper fig. 9: QAT improves everything, ranking broadly preserved");
    Ok(rep)
}

/// fig. 10 — KL ↔ downstream correlation across the sweep.
pub fn fig10_kl_downstream(env: &mut Env) -> Result<Report> {
    let mut rep = Report::new(
        "fig10",
        "correlation of KL and downstream ratio (direct-cast sweep)",
        &["format", "b", "KL", "downstream ratio"],
    );
    let baseline_params = env.checkpoint("m")?.params();
    let base = downstream(env, "m", &baseline_params)?;
    let (mut kls, mut dss) = (Vec::new(), Vec::new());
    for b in [3u32, 4] {
        for spec in [
            format!("cbrt-t7@{b}:block128-absmax"),
            format!("cbrt-t7@{b}:tensor-rms"),
            format!("cbrt-t7@{b}:tensor-rms:sparse0.001"),
        ] {
            let scheme = Scheme::parse(&spec)?;
            let (params, bits, _) =
                env.quantise("m", &scheme, None, false)?;
            let (kl, _) = env.evaluate("m", &params)?;
            let ds = mean_ratio(&downstream(env, "m", &params)?, &base);
            kls.push(kl.mean.max(1e-12).ln());
            dss.push(ds);
            rep.row(vec![spec, fmt(bits), fmt(kl.mean), fmt(ds)]);
        }
    }
    rep.note(format!(
        "pearson(log KL, downstream) = {} (paper fig. 10: strong negative)",
        fmt(stats::pearson(&kls, &dss))
    ));
    Ok(rep)
}
