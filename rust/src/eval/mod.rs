//! The figure/table reproduction harness: one function per paper artefact
//! (`fig1`..`fig35`, `tab1`/`tab2`/`tab5`), each returning a printable
//! [`Report`] whose rows mirror the series the paper plots.
//!
//! `run(id)` dispatches; `owf report <id>` is the CLI entry. Simulated-data
//! analyses ([`sim`]) are pure Rust; LLM analyses ([`llm`], [`qat`]) run the
//! microllama checkpoints through the PJRT runtime.
//!
//! Beyond the fixed figure list, [`sim::sweep_point`] and
//! [`llm::Env::sweep_row`] are the per-point entry points of the
//! [`crate::coordinator::sweep`] engine (`owf sweep`), which schedules
//! arbitrary scheme grids over both paths with JSONL resume.

pub mod llm;
pub mod pipeline;
pub mod qat;
pub mod sim;

use anyhow::{bail, Result};

use crate::coordinator::Report;

/// All report ids, in paper order.
pub const ALL_IDS: &[&str] = &[
    "fig2", "fig3", "fig4", "fig14", "fig15", "fig16", "fig18", "fig19",
    "fig20", "fig21", "fig22", "fig23", "fig24", // simulated (§3/§C)
    "fig1", "fig5", "fig6", "fig8", "fig11", "fig12", "fig13", "fig17",
    "fig25", "fig26", "fig27", "fig28", "fig29", "fig30", "fig31", "fig32",
    "fig33", "fig34", "fig35", "tab5", // LLM direct-cast (§4/§D)
    "fig7", "fig9", "fig10", "tab1", "tab2", // QAT + downstream
];

/// Ids that run without artifacts (pure simulation).
pub const SIM_IDS: &[&str] = &[
    "fig2", "fig3", "fig4", "fig14", "fig15", "fig16", "fig18", "fig19",
    "fig20", "fig21", "fig22", "fig23", "fig24",
];

/// Options shared by report runs.
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Simulated-data sample count (paper: 2^24; default here 2^20 for CPU
    /// budget — override with --samples).
    pub samples: usize,
    /// Eval sequences per LLM KL evaluation.
    pub eval_seqs: usize,
    /// QAT steps (paper: 8192; default small for CPU).
    pub qat_steps: usize,
    /// Model size for single-model figures.
    pub size: String,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            samples: 1 << 20,
            eval_seqs: 24,
            qat_steps: 60,
            size: "m".into(),
        }
    }
}

/// Run one report by id ("sim" / "all" fan out).
pub fn run(id: &str, opts: &RunOpts) -> Result<Vec<Report>> {
    let ids: Vec<&str> = match id {
        "all" => ALL_IDS.to_vec(),
        "sim" => SIM_IDS.to_vec(),
        "llm" => ALL_IDS
            .iter()
            .copied()
            .filter(|i| !SIM_IDS.contains(i))
            .collect(),
        single => vec![single],
    };
    let mut llm_env: Option<llm::Env> = None;
    let mut reports = Vec::new();
    for id in ids {
        let report = match id {
            // --- simulated ---------------------------------------------------
            "fig2" => sim::fig2_curves(opts),
            "fig3" => sim::fig3_codepoints(),
            "fig4" => sim::fig4_sim_tradeoff(opts),
            "fig14" => sim::fig14_absmax_approx(opts),
            "fig15" => sim::fig15_mixture(opts),
            "fig16" => sim::fig16_cbrt_rule(opts),
            "fig18" => sim::fig18_element_formats(opts),
            "fig19" => sim::fig19_exponent(opts),
            "fig20" => sim::fig20_scale_mantissa(opts),
            "fig21" => sim::fig21_block_size(opts),
            "fig22" => sim::fig22_alpha(opts),
            "fig23" => sim::fig23_scale_search(opts),
            "fig24" => sim::fig24_compressors(opts),
            // --- LLM direct-cast ---------------------------------------------
            other => {
                if llm_env.is_none() {
                    llm_env = Some(llm::Env::open(opts.clone())?);
                }
                let env = llm_env.as_mut().unwrap();
                match other {
                    "fig1" => llm::fig1_tradeoff(env),
                    "fig5" => llm::fig5_bits_hist(env),
                    "fig6" => llm::fig6_allocation(env),
                    "fig8" => llm::fig8_rho_grid(env),
                    "fig11" => llm::fig11_fisher_pred(env),
                    "fig12" => llm::fig12_fisher_structure(env),
                    "fig13" => llm::fig13_fisher_models(env),
                    "fig17" => llm::fig17_alloc_profile(env),
                    "fig25" => llm::fig25_weight_stats(env),
                    "fig26" => llm::fig26_kl_vs_ce(env),
                    "fig27" => llm::fig27_fisher_variants(env),
                    "fig28" => llm::fig28_compress_interaction(env),
                    "fig29" => llm::fig29_rotations(env),
                    "fig30" => llm::fig30_cross_domain(env),
                    "fig31" => llm::fig31_element_shootout(env),
                    "fig32" => llm::fig32_nf4_sf4(env),
                    "fig33" => llm::fig33_llm_block(env),
                    "fig34" => llm::fig34_signmax(env),
                    "fig35" => llm::fig35_scale_fit(env),
                    "tab5" => llm::tab5_alloc_terms(env),
                    "fig7" => qat::fig7_qat_downstream(env),
                    "fig9" => qat::fig9_dc_vs_qat(env),
                    "fig10" => qat::fig10_kl_downstream(env),
                    "tab1" => qat::tab1_downstream_dc(env),
                    "tab2" => qat::tab2_downstream_qat(env),
                    _ => bail!("unknown report id {other:?}"),
                }
            }
        }?;
        println!("{}", report.render());
        reports.push(report);
    }
    Ok(reports)
}
