//! Applying a [`Scheme`] to one parameter tensor: the full §2 pipeline —
//! optional rotation, sparse-outlier extraction, scale-multiplier search,
//! Lloyd fitting, the dense quantiser (or compressed uniform grid) and
//! honest bits-per-element accounting (element indices + scale overhead +
//! outlier storage; entropy-rate when `compress` is set).

use anyhow::{bail, Result};

use crate::compress::{entropy_bits, grid::grid_for_target_bits};
use crate::coordinator::config::{Element, Scheme};
use crate::dist::fit::{grid_then_golden, scale_search_grid};
use crate::quant::outliers::{
    qdq_outliers_with_hist, qdq_with_outliers, OutlierCriterion,
    SparseOutliers,
};
use crate::quant::rotation::{rotate_2d, rotate_2d_inverse, RandomRotation};
use crate::quant::Quantiser;
use crate::scaling::Granularity;

/// Result of quantising one tensor.
#[derive(Clone, Debug)]
pub struct TensorQdq {
    pub recon: Vec<f32>,
    /// average storage bits per element, all overheads included
    pub bits: f64,
    pub sq_err: f64,
}

/// Internal result of the per-path helpers: reconstruction + honest bits.
/// `sq_err` is *not* computed here — [`qdq_tensor`] measures it once
/// against the original (pre-rotation, pre-layout) data, so any per-path
/// error pass would be dead work.
struct Reconstructed {
    recon: Vec<f32>,
    bits: f64,
}

/// Quantise→dequantise one tensor under a scheme.
///
/// * `shape`/`channel_axis` drive channel granularity (2-D tensors with
///   `channel_axis = 1` are transposed so scale groups are contiguous);
/// * `fisher` (may be empty) enables Fisher-weighted outlier selection,
///   Lloyd weighting and weighted scale search;
/// * `seed` makes rotations deterministic per tensor.
pub fn qdq_tensor(
    scheme: &Scheme,
    data: &[f32],
    shape: &[usize],
    channel_axis: Option<usize>,
    fisher: &[f32],
    seed: u64,
) -> Result<TensorQdq> {
    // --- rotation: into the rotated basis (2-D only; fig. 29).  On any
    // other rank a `:rot` spec is a *documented identity rotation* — no
    // basis change, and the artifact writer records no rotation seed for
    // the tensor, so the packed and in-memory paths agree by construction
    // (see `EncodedTensor::rot_seed`).
    let mut work = data.to_vec();
    let rot = if scheme.rotate && shape.len() == 2 {
        let (rows, cols) = (shape[0], shape[1]);
        let (v, w) = rotation_pair(rows, cols, seed);
        rotate_2d(&mut work, rows, cols, &v, &w);
        Some((v, w))
    } else {
        None
    };

    // --- channel granularity: make scale groups contiguous -----------------
    // (`work` is moved through, so tensors that need no relayout cost no
    // extra copy on either side of the quantiser)
    let (flat, channel_len, transposed) = prepare_layout(
        work,
        shape,
        channel_axis,
        scheme.granularity,
    );

    let result = match &scheme.element {
        Element::Grid => qdq_grid(scheme, &flat)?,
        // codebook paths take the layout buffer by value: the compressed
        // path decodes straight back into it (no per-tensor recon Vec)
        _ => qdq_codebook(scheme, flat, channel_len, fisher)?,
    };

    // --- sparse outliers are patched on the *layout* buffer ---------------
    // (handled inside qdq_codebook for the dense path)

    // --- undo layout / rotation -------------------------------------------
    let mut recon = restore_layout(result.recon, shape, transposed);
    if let Some((v, w)) = rot {
        rotate_2d_inverse(&mut recon, shape[0], shape[1], &v, &w);
    }
    let sq_err = crate::util::stats::sq_err(data, &recon);
    Ok(TensorQdq {
        recon,
        bits: result.bits,
        sq_err,
    })
}

/// The deterministic rotation pair for a 2-D tensor: `V` mixes rows, `W`
/// mixes columns.  The seed-derivation constants live here and nowhere
/// else — [`qdq_tensor`], [`encode_tensor`] and the artifact reader's
/// inverse rotation all resolve (rows, cols, seed) through this one
/// helper, so the packed and in-memory paths can never disagree about
/// which basis a tensor was rotated into.
pub fn rotation_pair(
    rows: usize,
    cols: usize,
    seed: u64,
) -> (RandomRotation, RandomRotation) {
    (
        RandomRotation::new(rows, seed ^ 0xA11CE),
        RandomRotation::new(cols, seed ^ 0xB0B),
    )
}

/// Transpose 2-D data when channel scaling wants column groups.
/// `pub(crate)` for the artifact writer, which must lay tensors out
/// exactly as the in-memory path does.
pub(crate) fn prepare_layout(
    data: Vec<f32>,
    shape: &[usize],
    channel_axis: Option<usize>,
    granularity: Granularity,
) -> (Vec<f32>, usize, bool) {
    if granularity != Granularity::Channel {
        return (data, 0, false);
    }
    match (shape.len(), channel_axis) {
        (2, Some(1)) => {
            // scale per column: transpose so each column is contiguous
            let (rows, cols) = (shape[0], shape[1]);
            let mut t = vec![0f32; data.len()];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = data[r * cols + c];
                }
            }
            (t, rows, true)
        }
        (2, Some(0)) => {
            let cl = shape[1];
            (data, cl, false)
        }
        _ => {
            let n = data.len();
            (data, n, false) // 1-D: tensor fallback
        }
    }
}

/// Undo [`prepare_layout`]'s transpose (`pub(crate)` for the artifact
/// reader — same permutation, so packed reconstructions are bit-identical).
pub(crate) fn restore_layout(
    data: Vec<f32>,
    shape: &[usize],
    transposed: bool,
) -> Vec<f32> {
    if !transposed {
        return data;
    }
    let (rows, cols) = (shape[0], shape[1]);
    let mut out = vec![0f32; data.len()];
    for c in 0..cols {
        for r in 0..rows {
            out[r * cols + c] = data[c * rows + r];
        }
    }
    out
}

/// Build the fully configured quantiser for a scheme over the laid-out
/// tensor: the (possibly data-fitted) codebook, then the scale multiplier —
/// fixed, or searched to minimise (Fisher-weighted) squared error.  The one
/// construction path shared by [`qdq_tensor`] and the artifact writer's
/// [`encode_tensor`], which is what makes packed reconstructions
/// bit-identical to the in-memory pipeline.
pub(crate) fn build_quantiser(
    scheme: &Scheme,
    flat: &[f32],
    channel_len: usize,
    fisher: &[f32],
) -> Result<Quantiser> {
    let group_len = match scheme.granularity {
        Granularity::Block(b) => b,
        Granularity::Channel => channel_len.max(1),
        Granularity::Tensor => flat.len(),
    };
    let codebook = scheme.build_codebook(group_len, Some(flat), fisher)?;
    let mut quantiser = Quantiser::new(
        scheme.granularity,
        scheme.statistic,
        scheme.scale_format,
        codebook,
    );

    // multiplier: fixed, or searched to minimise (weighted) squared error
    if scheme.multiplier.is_nan() {
        let weights = if fisher.is_empty() { &[][..] } else { fisher };
        let base = quantiser.clone();
        let (best, _) = grid_then_golden(&scale_search_grid(), |m| {
            let q = base.clone().with_multiplier(m);
            let recon = q.qdq(flat, channel_len);
            crate::dist::fit::weighted_sq_err(flat, &recon, weights)
        });
        quantiser = quantiser.with_multiplier(best);
    } else {
        quantiser = quantiser.with_multiplier(scheme.multiplier);
    }
    Ok(quantiser)
}

/// Dense codebook path (everything except Grid).  Owns the layout buffer
/// so the compressed path can decode back into it zero-copy.
fn qdq_codebook(
    scheme: &Scheme,
    mut flat: Vec<f32>,
    channel_len: usize,
    fisher: &[f32],
) -> Result<Reconstructed> {
    let quantiser = build_quantiser(scheme, &flat, channel_len, fisher)?;

    let sparse = SparseOutliers {
        fraction: scheme.sparse,
        criterion: if fisher.is_empty() {
            OutlierCriterion::AbsValue
        } else {
            OutlierCriterion::FisherWeighted
        },
    };
    let (recon, bits) = if scheme.sparse > 0.0 && scheme.compress {
        // fused dense+sparse pass: one selection, one encode; the element
        // index cost is replaced by the entropy of the dense stream
        // (outliers are stored raw and zeroed before encoding, matching
        // what the coder actually sees)
        let (recon, bits, counts) = qdq_outliers_with_hist(
            &quantiser,
            &sparse,
            &flat,
            fisher,
            channel_len,
        );
        let h = entropy_bits(&counts);
        (recon, bits - quantiser.codebook.storage_bits() + h)
    } else if scheme.sparse > 0.0 {
        qdq_with_outliers(&quantiser, &sparse, &flat, fisher, channel_len)
    } else if scheme.compress {
        // fused single pass: scales, indices and the index histogram come
        // out of one kernel; the reconstruction is decoded from the same
        // indices (bit-identical to the fused qdq — both paths multiply by
        // the same reciprocal) straight back into the layout buffer, so
        // qdq never re-walks the data and never allocates a recon Vec
        let (enc, stats) = quantiser.encode_with_stats(&flat, channel_len);
        let h = entropy_bits(&stats.counts);
        let bits = quantiser.bits_per_element(flat.len(), channel_len)
            - quantiser.codebook.storage_bits()
            + h;
        quantiser.decode_into(&enc, &mut flat);
        return Ok(Reconstructed { recon: flat, bits });
    } else {
        let recon = quantiser.qdq(&flat, channel_len);
        (recon, quantiser.bits_per_element(flat.len(), channel_len))
    };

    Ok(Reconstructed { recon, bits })
}

/// The durable payload form of one encoded tensor — what the artifact
/// writer turns into sections.
pub enum EncodedForm {
    /// Codebook families: the configured quantiser (codebook + resolved
    /// multiplier) plus the per-group encoding (scales + indices).
    Codebook {
        quantiser: Quantiser,
        enc: crate::quant::Encoded,
    },
    /// Codebook-free uniform grid (§2.3): the dense-remapped symbol
    /// stream plus the occupied-bucket table.  `points[s]` is always
    /// exactly `UniformGrid::new(delta).dequantise(buckets[s])`, which is
    /// what lets the artifact reader cross-check the persisted codepoint
    /// table against the hex-exact δ before gathering.  (The reader must
    /// *not* route these points through `Codebook`, which sorts — dense
    /// slots are in first-occurrence order.)
    Grid {
        delta: f64,
        /// Dense slot → raw grid bucket, first-occurrence order.
        buckets: Vec<u16>,
        /// Dense slot → f32 codepoint (`dequantise(buckets[s])`).
        points: Vec<f32>,
        /// Per-element dense slots — the entropy-coded payload stream.
        indices: Vec<u16>,
    },
    /// Fractional-allocation mix (OWQ3): the tensor's scale blocks are
    /// partitioned across ≥2 codebook schemes; each partition's blocks
    /// are gathered into a contiguous stream (ascending block order, so
    /// the short tail block stays last and re-blocking the stream under
    /// the shared block length reproduces the original boundaries) and
    /// run through the same fused encode/decode kernels as a plain
    /// tensor.  `assign` is the per-block scheme-id stream the writer
    /// persists as the `block_schemes` section.
    Mixed {
        parts: Vec<MixedPart>,
        /// Per layout-space block: index into `parts`.
        assign: Vec<u8>,
    },
}

/// One partition of a mixed tensor: its scheme, the configured quantiser
/// (codebook + resolved multiplier, built over the partition's own
/// gathered data), the per-group encoding of that gathered stream, the
/// symbol histogram its entropy tables are built from, and the element
/// count.
pub struct MixedPart {
    pub scheme: Scheme,
    pub quantiser: Quantiser,
    pub enc: crate::quant::Encoded,
    pub counts: Vec<u64>,
    pub n: usize,
}

/// Everything the quantisation pipeline produced for one tensor, in the
/// durable form the artifact writer persists: the payload [`EncodedForm`],
/// the symbol histogram (the entropy model the coded payload is built
/// from), the sparse outlier overlay, the rotation record, the honest bits
/// accounting and the reconstruction — which is **bit-identical** to
/// [`qdq_tensor`]'s for the same scheme and seed (`decode(encode(x)) ≡
/// qdq(x)` by the fused-kernel contract, and both paths share
/// [`build_quantiser`], [`grid_for_scheme`], [`rotation_pair`], the layout
/// helpers and the same bits/sq-err expressions; enforced by
/// `rust/tests/artifact_props.rs`).
pub struct EncodedTensor {
    pub form: EncodedForm,
    /// Symbol histogram of the dense stream (codebook indices with
    /// outliers zeroed, or dense grid slots).
    pub counts: Vec<u64>,
    /// Sorted outlier positions in *layout* space, with their exact values.
    pub outlier_idx: Vec<u32>,
    pub outlier_val: Vec<f32>,
    /// Honest average storage bits per element (same accounting as
    /// [`qdq_tensor`]: entropy rate when `:compress`, outlier overhead
    /// when `:sparse`).
    pub bits: f64,
    /// Contiguous channel-group length in layout space (0 for non-channel
    /// granularities) — what `scale_groups` needs to rebuild `groups`.
    pub channel_len: usize,
    /// True when the layout pass transposed a 2-D column-scaled tensor.
    pub transposed: bool,
    /// Σ(x−x̂)² vs the original (pre-layout) data, f64 accumulation.
    pub sq_err: f64,
    /// Reconstruction in the original row-major layout.
    pub recon: Vec<f32>,
    /// `Some(seed)` iff the tensor was actually rotated (`:rot` *and*
    /// 2-D).  A `:rot` spec on any other rank is a documented identity
    /// rotation: recorded as `None` here (and absent from the manifest),
    /// so the packed and in-memory paths agree explicitly — never
    /// silently — that no basis change was applied.
    pub rot_seed: Option<u64>,
}

/// Quantise one tensor under a scheme and keep the *encoded* form — the
/// artifact-pack counterpart of [`qdq_tensor`] (which discards indices on
/// its fast paths).  Every scheme the sweep grammar can produce
/// round-trips: all codebook families, `:compress`, `:sparse`, `:search`,
/// channel layout, `:rot` (the rotation seed is recorded; the reader
/// re-derives V/W via [`rotation_pair`] and inverts after decode) and the
/// codebook-free `grid` element (dense slot stream + bucket table).
pub fn encode_tensor(
    scheme: &Scheme,
    data: &[f32],
    shape: &[usize],
    channel_axis: Option<usize>,
    fisher: &[f32],
    seed: u64,
) -> Result<EncodedTensor> {
    // rotation: the exact basis decision qdq_tensor makes (2-D only;
    // identity otherwise, recorded as rot_seed = None)
    let mut work = data.to_vec();
    let rot = if scheme.rotate && shape.len() == 2 {
        let (rows, cols) = (shape[0], shape[1]);
        let (v, w) = rotation_pair(rows, cols, seed);
        rotate_2d(&mut work, rows, cols, &v, &w);
        Some((v, w))
    } else {
        None
    };
    let rot_seed = rot.as_ref().map(|_| seed);

    let (mut flat, channel_len, transposed) = prepare_layout(
        work,
        shape,
        channel_axis,
        scheme.granularity,
    );

    if scheme.element == Element::Grid {
        // grid path: δ and the honest bits figure come from the same
        // resolution helper as qdq_tensor; sparse overlays are ignored
        // exactly as the in-memory grid path ignores them
        let (grid, bits) = grid_for_scheme(scheme, &flat)?;
        let (raw_idx, _sq) = grid.encode(&flat);
        let (counts, dense) = grid.dense_histogram(&raw_idx);
        let mut buckets = vec![0u16; counts.len()];
        for (&slot, &raw) in dense.iter().zip(&raw_idx) {
            buckets[slot as usize] = raw;
        }
        let points: Vec<f32> =
            buckets.iter().map(|&b| grid.dequantise(b)).collect();
        // recon via the same parallel kernel as qdq_tensor; the reader's
        // gather agrees bit-for-bit because
        // points[dense[i]] = dequantise(raw_idx[i]) = qdq(flat[i])
        let mut recon =
            restore_layout(grid_qdq_all(&grid, &flat), shape, transposed);
        if let Some((v, w)) = &rot {
            rotate_2d_inverse(&mut recon, shape[0], shape[1], v, w);
        }
        let sq_err = crate::util::stats::sq_err(data, &recon);
        return Ok(EncodedTensor {
            form: EncodedForm::Grid {
                delta: grid.delta,
                buckets,
                points,
                indices: dense,
            },
            counts,
            outlier_idx: Vec::new(),
            outlier_val: Vec::new(),
            bits,
            channel_len,
            transposed,
            sq_err,
            recon,
            rot_seed,
        });
    }

    let quantiser = build_quantiser(scheme, &flat, channel_len, fisher)?;

    // sparse overlay: same selection as the in-memory dense+sparse path —
    // outliers are removed before the dense encode (so they don't inflate
    // block scales) and scattered back over the decoded buffer after
    let sparse = SparseOutliers {
        fraction: scheme.sparse,
        criterion: if fisher.is_empty() {
            OutlierCriterion::AbsValue
        } else {
            OutlierCriterion::FisherWeighted
        },
    };
    let outlier_idx = if scheme.sparse > 0.0 {
        sparse.select(&flat, fisher)
    } else {
        Vec::new()
    };
    let outlier_val: Vec<f32> = outlier_idx
        .iter()
        .map(|&i| flat[i as usize])
        .collect();
    for &i in &outlier_idx {
        flat[i as usize] = 0.0;
    }

    let (enc, stats) = quantiser.encode_with_stats(&flat, channel_len);
    quantiser.decode_into(&enc, &mut flat);
    for (&i, &v) in outlier_idx.iter().zip(&outlier_val) {
        flat[i as usize] = v;
    }

    // bits accounting: term order mirrors qdq_codebook exactly so the two
    // paths agree to the last f64 bit
    let n = data.len();
    let mut bits = quantiser.bits_per_element(n, channel_len);
    if scheme.sparse > 0.0 {
        bits += sparse.overhead_bits(n);
    }
    if scheme.compress {
        let h = entropy_bits(&stats.counts);
        bits = bits - quantiser.codebook.storage_bits() + h;
    }

    let mut recon = restore_layout(flat, shape, transposed);
    if let Some((v, w)) = &rot {
        rotate_2d_inverse(&mut recon, shape[0], shape[1], v, w);
    }
    let sq_err = crate::util::stats::sq_err(data, &recon);
    Ok(EncodedTensor {
        form: EncodedForm::Codebook { quantiser, enc },
        counts: stats.counts,
        outlier_idx,
        outlier_val,
        bits,
        channel_len,
        transposed,
        sq_err,
        recon,
        rot_seed,
    })
}

/// Encode one tensor as a block-level mix of schemes — the fractional
/// allocator's realisation path ([`crate::alloc::frac`]).  `assign[b]`
/// names the scheme (index into `schemes`) owning scale block `b` of the
/// laid-out tensor.  Each partition gathers its blocks into a contiguous
/// stream and routes it through [`build_quantiser`] +
/// `encode_with_stats` + `decode_into` — exactly the plain codebook
/// path, per partition — then scatters the decode back, so the
/// reconstruction of every block is bit-identical to what a pure tensor
/// of just those blocks would produce under that scheme.
///
/// Bits accounting is honest for the container this becomes: the
/// element-weighted mean of the per-partition rates (entropy rate when
/// `:compress`) plus ⌈log2 k⌉ bits per block for the persisted scheme-id
/// stream.
///
/// Constraints (typed errors): ≥2 schemes, all sharing the base's block
/// granularity and rotation flag, no `:sparse`, no grid element, every
/// scheme owning at least one block.  Mixed tensors never transpose
/// (block granularity skips the channel layout), so `channel_len` is 0.
pub fn encode_tensor_mixed(
    schemes: &[Scheme],
    assign: &[u8],
    data: &[f32],
    shape: &[usize],
    channel_axis: Option<usize>,
    fisher: &[f32],
    seed: u64,
) -> Result<EncodedTensor> {
    if schemes.len() < 2 {
        bail!(
            "a mix needs at least two schemes \
             (pure tensors go through encode_tensor)"
        );
    }
    let granularity = schemes[0].granularity;
    if !matches!(granularity, Granularity::Block(_)) {
        bail!("mixed tensors require block granularity, got {granularity:?}");
    }
    for s in schemes {
        if s.granularity != granularity {
            bail!("mix parts must share the block granularity");
        }
        if s.rotate != schemes[0].rotate {
            bail!("mix parts must agree on rotation");
        }
        if s.element == Element::Grid {
            bail!("grid schemes cannot be mixed (no block boundary)");
        }
        if s.sparse > 0.0 {
            bail!("mixed tensors do not support :sparse");
        }
    }

    // rotation + layout: the exact decisions encode_tensor makes
    let mut work = data.to_vec();
    let rot = if schemes[0].rotate && shape.len() == 2 {
        let (rows, cols) = (shape[0], shape[1]);
        let (v, w) = rotation_pair(rows, cols, seed);
        rotate_2d(&mut work, rows, cols, &v, &w);
        Some((v, w))
    } else {
        None
    };
    let rot_seed = rot.as_ref().map(|_| seed);
    let (mut flat, channel_len, transposed) =
        prepare_layout(work, shape, channel_axis, granularity);
    debug_assert!(!transposed && channel_len == 0);

    let blocks =
        crate::scaling::scale_groups(flat.len(), granularity, channel_len);
    if assign.len() != blocks.len() {
        bail!(
            "{} scheme ids for {} blocks",
            assign.len(),
            blocks.len()
        );
    }
    if let Some(&id) =
        assign.iter().find(|&&id| (id as usize) >= schemes.len())
    {
        bail!("scheme id {id} out of range ({} schemes)", schemes.len());
    }

    let mut parts: Vec<MixedPart> = Vec::with_capacity(schemes.len());
    let mut total_bits = 0f64;
    for (p, scheme) in schemes.iter().enumerate() {
        let mut part_data: Vec<f32> = Vec::new();
        let mut part_fisher: Vec<f32> = Vec::new();
        for (&id, &(start, len)) in assign.iter().zip(&blocks) {
            if id as usize == p {
                part_data.extend_from_slice(&flat[start..start + len]);
                if !fisher.is_empty() {
                    part_fisher
                        .extend_from_slice(&fisher[start..start + len]);
                }
            }
        }
        if part_data.is_empty() {
            bail!(
                "scheme {p} ({}) is assigned no blocks",
                scheme.name()
            );
        }
        let quantiser = build_quantiser(scheme, &part_data, 0, &part_fisher)?;
        let (enc, stats) = quantiser.encode_with_stats(&part_data, 0);
        let pn = part_data.len();
        // same term order as the plain paths, per partition
        let mut part_bits = quantiser.bits_per_element(pn, 0);
        if scheme.compress {
            part_bits = part_bits - quantiser.codebook.storage_bits()
                + entropy_bits(&stats.counts);
        }
        total_bits += part_bits * pn as f64;
        quantiser.decode_into(&enc, &mut part_data);
        let mut cursor = 0usize;
        for (&id, &(start, len)) in assign.iter().zip(&blocks) {
            if id as usize == p {
                flat[start..start + len]
                    .copy_from_slice(&part_data[cursor..cursor + len]);
                cursor += len;
            }
        }
        parts.push(MixedPart {
            scheme: scheme.clone(),
            quantiser,
            enc,
            counts: stats.counts,
            n: pn,
        });
    }

    // honest accounting includes the per-block scheme-id stream the
    // container stores: ⌈log2 k⌉ bits per block (at least 1)
    let id_bits = (schemes.len() as f64).log2().ceil().max(1.0);
    let bits = (total_bits + id_bits * blocks.len() as f64)
        / flat.len() as f64;

    let mut recon = restore_layout(flat, shape, transposed);
    if let Some((v, w)) = &rot {
        rotate_2d_inverse(&mut recon, shape[0], shape[1], v, w);
    }
    let sq_err = crate::util::stats::sq_err(data, &recon);
    Ok(EncodedTensor {
        form: EncodedForm::Mixed {
            parts,
            assign: assign.to_vec(),
        },
        counts: Vec::new(),
        outlier_idx: Vec::new(),
        outlier_val: Vec::new(),
        bits,
        channel_len,
        transposed,
        sq_err,
        recon,
        rot_seed,
    })
}

/// The in-memory reference for a mixed tensor — what `owf inspect
/// --verify` and the artifact property tests compare packed decodes
/// against.  A thin wrapper over [`encode_tensor_mixed`]: the mixed
/// pipeline has exactly one encode path (per-partition fused kernels), so
/// the reference IS that path's reconstruction and accounting, the same
/// relationship `qdq_codebook`'s compress arm already has with
/// `encode_with_stats`.
pub fn qdq_tensor_mixed(
    schemes: &[Scheme],
    assign: &[u8],
    data: &[f32],
    shape: &[usize],
    channel_axis: Option<usize>,
    fisher: &[f32],
    seed: u64,
) -> Result<TensorQdq> {
    let et = encode_tensor_mixed(
        schemes,
        assign,
        data,
        shape,
        channel_axis,
        fisher,
        seed,
    )?;
    Ok(TensorQdq {
        recon: et.recon,
        bits: et.bits,
        sq_err: et.sq_err,
    })
}

/// Resolve δ and the honest bits figure for a `grid` scheme over one
/// laid-out tensor (§2.3/§4): tensor-RMS scaling is *folded into the grid
/// resolution* — one global relative resolution δ_t = c·RMS(θ_t) with
/// c = 2^(h₀ − b), h₀ the differential entropy of a unit Normal
/// (½·log2(2πe) ≈ 2.047).  Per-tensor *rates* then vary with tail weight
/// (heavier tails → higher entropy → more bits), which is exactly the
/// cross-tensor variable-length allocation the paper credits for the
/// compressed format's win; the realised entropy is reported as the
/// honest bits figure.  A per-tensor δ search to a *fixed* rate
/// (`:search` flag) is also available, and measurably worse at low b.
///
/// The single resolution path shared by [`qdq_tensor`] and
/// [`encode_tensor`]: the bits figure in particular must come from the
/// *same* histogram walk on both paths (f64 entropy summation is
/// order-sensitive, so recomputing it from, say, the dense-remapped
/// histogram would not be bit-identical).
fn grid_for_scheme(
    scheme: &Scheme,
    flat: &[f32],
) -> Result<(crate::compress::grid::UniformGrid, f64)> {
    if scheme.granularity != Granularity::Tensor {
        bail!("grid schemes use tensor granularity (scale folds into δ)");
    }
    if scheme.multiplier.is_nan() {
        // explicit per-tensor rate search (fixed-rate-per-tensor ablation)
        let r = grid_for_target_bits(flat, scheme.bits);
        let grid = crate::compress::grid::UniformGrid::new(r.delta);
        return Ok((grid, r.bits_per_element));
    }
    const H0: f64 = 2.047; // ½·log2(2πe)
    let rms = crate::util::stats::rms(flat).max(1e-30);
    let delta = rms * 2f64.powf(H0 - scheme.bits) * scheme.multiplier;
    let grid = crate::compress::grid::UniformGrid::new(delta);
    let (counts, _sq_err) = grid.count_histogram(flat);
    Ok((grid, entropy_bits(&counts)))
}

/// Compressed uniform grid path: resolve δ via [`grid_for_scheme`], then
/// reconstruct with the parallel elementwise kernel.
fn qdq_grid(scheme: &Scheme, flat: &[f32]) -> Result<Reconstructed> {
    let (grid, bits) = grid_for_scheme(scheme, flat)?;
    Ok(Reconstructed {
        recon: grid_qdq_all(&grid, flat),
        bits,
    })
}

/// Elementwise grid qdq, fanned over the pool for large tensors — the
/// compressed-format reconstruction path (codebook paths parallelise inside
/// [`crate::quant::Quantiser`]; nested calls flatten to serial when a sweep
/// already occupies the workers).
fn grid_qdq_all(
    grid: &crate::compress::grid::UniformGrid,
    flat: &[f32],
) -> Vec<f32> {
    let mut out = flat.to_vec();
    crate::util::pool::par_elementwise(&mut out, |x| *x = grid.qdq(*x));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dist, Family};
    use crate::util::rng::Rng;
    use crate::util::stats::relative_rms_error;

    fn data_2d(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        Dist::standard(Family::StudentT, 6.0).sample_vec(&mut rng, rows * cols)
    }

    fn run(spec: &str, data: &[f32], shape: &[usize]) -> TensorQdq {
        let scheme = Scheme::parse(spec).unwrap();
        qdq_tensor(&scheme, data, shape, Some(1), &[], 7).unwrap()
    }

    #[test]
    fn bits_accounting_across_paths() {
        let data = data_2d(64, 96, 1);
        let shape = [64, 96];
        let t = run("int@4:block64-absmax", &data, &shape);
        assert!((t.bits - 4.25).abs() < 1e-9, "{}", t.bits);
        let t = run("int@4:block64-absmax:sparse0.001", &data, &shape);
        assert!(t.bits > 4.25 && t.bits < 4.35, "{}", t.bits);
        let t = run("cbrt-t7@4:tensor-rms", &data, &shape);
        assert!(t.bits > 4.0 && t.bits < 4.01, "{}", t.bits);
        let t = run("grid@3.5:tensor-rms:compress", &data, &shape);
        assert!((t.bits - 3.5).abs() < 0.1, "{}", t.bits);
    }

    #[test]
    fn grid_parallel_path_matches_serial() {
        // above the parallel threshold, the fanned-out grid recon must be
        // bitwise identical to the serial path (forced via the nested-
        // parallelism guard: inside a pool worker everything runs inline)
        let data = data_2d(512, 512, 8);
        let shape = [512usize, 512];
        let par = run("grid@4:tensor-rms:compress", &data, &shape);
        let serial = crate::util::pool::par_map(&[0, 1], |i, _| {
            if i == 0 {
                Some(run("grid@4:tensor-rms:compress", &data, &shape))
            } else {
                None
            }
        })
        .swap_remove(0)
        .unwrap();
        assert_eq!(par.recon, serial.recon);
        assert_eq!(par.bits, serial.bits);
    }

    #[test]
    fn compression_reduces_bits_for_nonuniform_usage() {
        // tensor absmax INT on heavy-tailed data concentrates indices
        // near the middle ⇒ entropy ≪ 4 bits
        let data = data_2d(64, 64, 2);
        let plain = run("int@4:tensor-absmax", &data, &[64, 64]);
        let compressed = run("int@4:tensor-absmax:compress", &data, &[64, 64]);
        assert!(compressed.bits < plain.bits - 0.5);
        // identical reconstruction (compression is lossless)
        assert_eq!(plain.recon, compressed.recon);
    }

    #[test]
    fn sparse_compress_prices_the_dense_stream() {
        // with a huge spike, plain tensor-absmax compresses to near zero
        // bits (every index collapses to the middle); the sparse overlay
        // removes the spike from the dense stream, so its entropy — and
        // the honest bits figure — must be *higher*, not lower
        let mut data = data_2d(64, 64, 9);
        data[100] = 500.0;
        let shape = [64usize, 64];
        let plain_c = run("int@4:tensor-absmax:compress", &data, &shape);
        let sparse_c = run(
            "int@4:tensor-absmax:compress,sparse0.001",
            &data,
            &shape,
        );
        assert!(
            sparse_c.bits > plain_c.bits,
            "dense-stream entropy {} should exceed spiked entropy {}",
            sparse_c.bits,
            plain_c.bits
        );
        // and the sparse reconstruction is far more accurate
        assert!(sparse_c.sq_err < plain_c.sq_err * 0.5);
    }

    #[test]
    fn rotation_roundtrips_and_helps_tensor_scaling() {
        let mut data = data_2d(64, 64, 3);
        // heavy outlier to break tensor absmax
        data[100] = 80.0;
        let shape = [64, 64];
        let plain = run("cbrt-normal@4:tensor-rms", &data, &shape);
        let rotated = run("cbrt-normal@4:tensor-rms:rot", &data, &shape);
        let r_plain = relative_rms_error(&data, &plain.recon);
        let r_rot = relative_rms_error(&data, &rotated.recon);
        assert!(
            r_rot < r_plain,
            "rotation should fix the outlier: {r_rot} vs {r_plain}"
        );
    }

    #[test]
    fn rot_on_non_2d_is_an_explicit_recorded_identity() {
        // `:rot` only has a basis change for rank-2 tensors; on any other
        // rank both pipelines apply the documented identity and the
        // encoded form records rot_seed = None, so a packed container can
        // never disagree with the in-memory path about rotation
        let mut rng = Rng::new(11);
        let data = Dist::standard(Family::Normal, 0.0)
            .sample_vec(&mut rng, 128);
        let scheme = Scheme::parse("cbrt-normal@4:tensor-rms:rot").unwrap();
        let et =
            encode_tensor(&scheme, &data, &[128], None, &[], 7).unwrap();
        assert!(et.rot_seed.is_none(), "1-D :rot must record identity");
        let q = qdq_tensor(&scheme, &data, &[128], None, &[], 7).unwrap();
        assert_eq!(et.recon, q.recon);
        assert_eq!(et.bits.to_bits(), q.bits.to_bits());
        assert_eq!(et.sq_err.to_bits(), q.sq_err.to_bits());

        // rank-2 genuinely rotates and records the seed it used
        let data2 = data_2d(16, 8, 12);
        let et2 = encode_tensor(&scheme, &data2, &[16, 8], None, &[], 7)
            .unwrap();
        assert_eq!(et2.rot_seed, Some(7));
        let q2 = qdq_tensor(&scheme, &data2, &[16, 8], None, &[], 7)
            .unwrap();
        assert_eq!(et2.recon, q2.recon);
    }

    #[test]
    fn channel_scaling_handles_column_structure() {
        // columns with wildly different scales
        let (rows, cols) = (32, 8);
        let mut rng = Rng::new(4);
        let mut data = vec![0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                data[r * cols + c] =
                    rng.normal() as f32 * 10f32.powi(c as i32 % 4);
            }
        }
        let ch = run("int@4:channel-absmax", &data, &[rows, cols]);
        let tn = run("int@4:tensor-absmax", &data, &[rows, cols]);
        let r_ch = relative_rms_error(&data, &ch.recon);
        let r_tn = relative_rms_error(&data, &tn.recon);
        assert!(r_ch < r_tn * 0.5, "channel {r_ch} vs tensor {r_tn}");
    }

    #[test]
    fn search_multiplier_beats_moment_matching_for_int_rms() {
        let data = data_2d(64, 64, 5);
        let fixed = run("int@4:tensor-rms:mult2", &data, &[64, 64]);
        let searched = run("int@4:tensor-rms:search", &data, &[64, 64]);
        assert!(searched.sq_err <= fixed.sq_err * 1.001);
    }

    #[test]
    fn lloyd_fits_this_tensor() {
        let data = data_2d(64, 64, 6);
        let lloyd = run("lloyd@4:tensor-rms", &data, &[64, 64]);
        let cbrt = run("cbrt-normal@4:tensor-rms", &data, &[64, 64]);
        // data is Student-t; fitted Lloyd must beat the mismatched Normal
        assert!(lloyd.sq_err < cbrt.sq_err);
    }

    #[test]
    fn mixed_degenerate_same_scheme_matches_pure_plus_id_overhead() {
        // both partitions run the identical scheme with a *fixed*
        // multiplier: per-block encodes depend only on the block's own
        // data (int codebook is data-independent, absmax scale is
        // per-block), so the mixed reconstruction must be bit-identical
        // to the pure tensor and the bits must differ by exactly the
        // per-block scheme-id overhead (1 bit per 64-element block)
        let data = data_2d(64, 96, 12);
        let shape = [64usize, 96];
        let s = Scheme::parse("int@4:block64-absmax:mult1").unwrap();
        let schemes = vec![s.clone(), s.clone()];
        let n_blocks = data.len().div_ceil(64);
        let assign: Vec<u8> =
            (0..n_blocks).map(|b| (b % 2) as u8).collect();
        let mixed = qdq_tensor_mixed(
            &schemes, &assign, &data, &shape, Some(1), &[], 7,
        )
        .unwrap();
        let pure = run("int@4:block64-absmax:mult1", &data, &shape);
        assert_eq!(mixed.recon, pure.recon);
        assert!(
            (mixed.bits - pure.bits - 1.0 / 64.0).abs() < 1e-12,
            "mixed {} vs pure {}",
            mixed.bits,
            pure.bits
        );
    }

    #[test]
    fn mixed_blocks_match_their_pure_scheme_blockwise() {
        // each block of a 3/5-bit mix must reproduce, bit for bit, the
        // same block of the corresponding *pure* encode — partitioning
        // must not leak information across schemes
        let data = data_2d(64, 96, 13);
        let shape = [64usize, 96];
        let lo = Scheme::parse("int@3:block64-absmax:mult1").unwrap();
        let hi = Scheme::parse("int@5:block64-absmax:mult1").unwrap();
        let n = data.len();
        let n_blocks = n.div_ceil(64);
        let assign: Vec<u8> =
            (0..n_blocks).map(|b| (b % 3 == 0) as u8).collect();
        let mixed = qdq_tensor_mixed(
            &[lo.clone(), hi.clone()],
            &assign,
            &data,
            &shape,
            Some(1),
            &[],
            7,
        )
        .unwrap();
        let pure_lo = run("int@3:block64-absmax:mult1", &data, &shape);
        let pure_hi = run("int@5:block64-absmax:mult1", &data, &shape);
        for (b, &id) in assign.iter().enumerate() {
            let start = b * 64;
            let end = (start + 64).min(n);
            let want = if id == 1 { &pure_hi } else { &pure_lo };
            for i in start..end {
                assert_eq!(
                    mixed.recon[i].to_bits(),
                    want.recon[i].to_bits(),
                    "block {b} element {i}"
                );
            }
        }
        // bits: element-weighted mean of the part rates + 1 id bit/block
        let hi_elems: usize = assign
            .iter()
            .map(|&id| if id == 1 { 64 } else { 0 })
            .sum();
        let expect = (3.25 * (n - hi_elems) as f64
            + 5.25 * hi_elems as f64
            + n_blocks as f64)
            / n as f64;
        assert!(
            (mixed.bits - expect).abs() < 1e-12,
            "{} vs {expect}",
            mixed.bits
        );
    }

    #[test]
    fn mixed_rejects_malformed_mixes_typed() {
        let data = data_2d(8, 64, 14);
        let shape = [8usize, 64];
        let s = Scheme::parse("int@4:block64-absmax").unwrap();
        let two = vec![s.clone(), s.clone()];
        let blocks = data.len().div_ceil(64);
        let half: Vec<u8> =
            (0..blocks).map(|b| (b % 2) as u8).collect();
        // fewer ids than blocks
        assert!(encode_tensor_mixed(
            &two, &half[..blocks - 1], &data, &shape, Some(1), &[], 7
        )
        .is_err());
        // id out of range
        let mut bad = half.clone();
        bad[0] = 2;
        assert!(encode_tensor_mixed(
            &two, &bad, &data, &shape, Some(1), &[], 7
        )
        .is_err());
        // a scheme with no blocks
        let none = vec![0u8; blocks];
        assert!(encode_tensor_mixed(
            &two, &none, &data, &shape, Some(1), &[], 7
        )
        .is_err());
        // single scheme
        assert!(encode_tensor_mixed(
            &two[..1], &half, &data, &shape, Some(1), &[], 7
        )
        .is_err());
        // non-block granularity
        let t = Scheme::parse("int@4:tensor-absmax").unwrap();
        assert!(encode_tensor_mixed(
            &[t.clone(), t],
            &half,
            &data,
            &shape,
            Some(1),
            &[],
            7
        )
        .is_err());
        // sparse overlay
        let sp =
            Scheme::parse("int@4:block64-absmax:sparse0.01").unwrap();
        assert!(encode_tensor_mixed(
            &[sp.clone(), sp],
            &half,
            &data,
            &shape,
            Some(1),
            &[],
            7
        )
        .is_err());
    }
}
