//! JSONL result store: every experiment the coordinator runs appends one
//! JSON row; reports re-read them for aggregation.  Plain files, append-only,
//! human-greppable.  [`SweepCache`] layers a completed-row index on top so
//! `owf sweep --resume` can skip points that already finished.

use std::collections::HashSet;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Append-only JSONL sink.
pub struct ResultSink {
    path: PathBuf,
}

impl ResultSink {
    pub fn open(path: impl AsRef<Path>) -> Result<ResultSink> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(ResultSink { path })
    }

    /// Append one row.  The line (text + newline) is serialised first and
    /// written with a single `write_all` on an `O_APPEND` handle, so
    /// concurrent appends from pool workers never interleave mid-row.
    pub fn append(&self, row: &Json) -> Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("open {:?}", self.path))?;
        let mut line = row.to_string();
        line.push('\n');
        f.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Fail fast if the sink cannot be appended to (read-only mount,
    /// permissions) — resumed sweeps probe this before computing anything.
    pub fn probe_writable(&self) -> Result<()> {
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| {
                format!("output {:?} is not writable", self.path)
            })?;
        Ok(())
    }

    /// Reset the sink to an empty file (fresh, non-resumed sweeps).
    pub fn truncate(&self) -> Result<()> {
        std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&self.path)
            .with_context(|| format!("truncate {:?}", self.path))?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn read_all(&self) -> Result<Vec<Json>> {
        if !self.path.exists() {
            return Ok(Vec::new());
        }
        let text = std::fs::read_to_string(&self.path)?;
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| Json::parse(l).map_err(anyhow::Error::from))
            .collect()
    }

    /// Like [`ResultSink::read_all`] but skips unparseable lines — a sweep
    /// killed mid-append leaves a torn final line, which must not poison
    /// the resume index.
    pub fn read_valid(&self) -> Result<Vec<Json>> {
        if !self.path.exists() {
            return Ok(Vec::new());
        }
        let text = std::fs::read_to_string(&self.path)?;
        Ok(text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .filter_map(|l| Json::parse(l).ok())
            .collect())
    }

    /// [`ResultSink::read_valid`], plus repair: when damaged lines are
    /// present the file is atomically rewritten with only the valid rows
    /// (original line text, no re-serialisation).  Without this a torn
    /// trailing fragment has no newline, so the *next* append would fuse
    /// with it into one corrupt row — silently losing a finished point.
    /// Returns the valid rows and how many damaged lines were dropped.
    pub fn repair(&self) -> Result<(Vec<Json>, usize)> {
        if !self.path.exists() {
            return Ok((Vec::new(), 0));
        }
        let text = std::fs::read_to_string(&self.path)?;
        let mut rows = Vec::new();
        let mut keep = String::new();
        let mut dropped = 0usize;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            match Json::parse(line) {
                Ok(row) => {
                    rows.push(row);
                    keep.push_str(line);
                    keep.push('\n');
                }
                Err(_) => dropped += 1,
            }
        }
        if dropped > 0 {
            crate::util::fsx::atomic_write(&self.path, keep.as_bytes())
                .with_context(|| format!("repair {:?}", self.path))?;
        }
        Ok((rows, dropped))
    }
}

/// A JSONL-backed completed-work cache: rows already in the file are
/// indexed by a caller-supplied key function at open time; the engine
/// checks [`SweepCache::is_done`] before scheduling a point and appends
/// each finished row through the same sink.  Kill the process at any time —
/// the rows written so far are the resume state.
pub struct SweepCache {
    sink: ResultSink,
    done: HashSet<String>,
}

impl SweepCache {
    /// Open `path`.  With `resume` the existing rows are indexed via
    /// `key_of` (rows it maps to `None` — malformed or failed — are
    /// ignored, so they re-run); without it the file is truncated.
    pub fn open(
        path: impl AsRef<Path>,
        resume: bool,
        key_of: impl Fn(&Json) -> Option<String>,
    ) -> Result<SweepCache> {
        let sink = ResultSink::open(path)?;
        let done = if resume {
            // fail fast on an unwritable output — otherwise a long resumed
            // sweep would compute everything and drop every row
            sink.probe_writable()?;
            // lenient read + repair: a row torn by a mid-append kill is
            // simply not done (its point reruns), and the file is rewritten
            // without the fragment so later appends cannot fuse with it
            let (rows, dropped) = sink.repair()?;
            if dropped > 0 {
                eprintln!(
                    "[{:?}: dropped {dropped} torn/corrupt line(s) on \
                     resume; rewrote the {} valid rows]",
                    sink.path(),
                    rows.len()
                );
            }
            rows.iter().filter_map(key_of).collect()
        } else {
            sink.truncate()?;
            HashSet::new()
        };
        Ok(SweepCache { sink, done })
    }

    pub fn is_done(&self, key: &str) -> bool {
        self.done.contains(key)
    }

    pub fn completed(&self) -> usize {
        self.done.len()
    }

    /// Append a finished row (thread-safe: single-write append).
    pub fn append(&self, row: &Json) -> Result<()> {
        self.sink.append(row)
    }

    pub fn path(&self) -> &Path {
        self.sink.path()
    }
}

/// A printable report: the harness's unit of output (one per paper
/// figure/table).
#[derive(Clone, Debug)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "report {}", self.id);
        self.rows.push(cells);
    }

    pub fn note(&mut self, text: impl Into<String>) {
        self.notes.push(text.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.id, self.title));
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        out.push_str(&header.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(header.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Rows as JSON (for the result sink).
    pub fn to_json_rows(&self) -> Vec<Json> {
        self.rows
            .iter()
            .map(|row| {
                let mut obj = Json::obj().push("report", self.id.as_str());
                for (c, v) in self.columns.iter().zip(row) {
                    obj = obj.push(c, v.as_str());
                }
                obj
            })
            .collect()
    }
}

/// Format a float for table cells.
pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1000.0 || a < 1e-3 {
        format!("{x:.3e}")
    } else if a >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_roundtrip() {
        let path = std::env::temp_dir().join("owf_results_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let sink = ResultSink::open(&path).unwrap();
        sink.append(&Json::obj().push("a", 1.0)).unwrap();
        sink.append(&Json::obj().push("a", 2.0)).unwrap();
        let rows = sink.read_all().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("a").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn truncate_resets() {
        let path = std::env::temp_dir().join("owf_results_trunc.jsonl");
        let _ = std::fs::remove_file(&path);
        let sink = ResultSink::open(&path).unwrap();
        sink.append(&Json::obj().push("a", 1.0)).unwrap();
        sink.truncate().unwrap();
        assert!(sink.read_all().unwrap().is_empty());
        sink.append(&Json::obj().push("a", 2.0)).unwrap();
        assert_eq!(sink.read_all().unwrap().len(), 1);
    }

    #[test]
    fn sweep_cache_resume_and_fresh() {
        let path = std::env::temp_dir().join("owf_sweep_cache.jsonl");
        let _ = std::fs::remove_file(&path);
        let key_of = |row: &Json| {
            let ok = row.get("ok").and_then(Json::as_bool).unwrap_or(false);
            if !ok {
                return None;
            }
            row.get("key").and_then(Json::as_str).map(String::from)
        };
        {
            let cache = SweepCache::open(&path, false, key_of).unwrap();
            assert_eq!(cache.completed(), 0);
            cache
                .append(&Json::obj().push("key", "a").push("ok", true))
                .unwrap();
            cache
                .append(&Json::obj().push("key", "b").push("ok", false))
                .unwrap();
        }
        // resume: only the ok row counts as done
        let cache = SweepCache::open(&path, true, key_of).unwrap();
        assert_eq!(cache.completed(), 1);
        assert!(cache.is_done("a"));
        assert!(!cache.is_done("b"));
        // fresh open truncates
        let cache = SweepCache::open(&path, false, key_of).unwrap();
        assert_eq!(cache.completed(), 0);
        assert!(!cache.is_done("a"));
    }

    #[test]
    fn torn_final_line_does_not_poison_resume() {
        // a sweep killed mid-append leaves a partial JSON line; the resume
        // index must skip it (and read_all must still be strict)
        use std::io::Write as _;
        let path = std::env::temp_dir().join("owf_sweep_torn.jsonl");
        let _ = std::fs::remove_file(&path);
        let key_of = |row: &Json| {
            row.get("key").and_then(Json::as_str).map(String::from)
        };
        {
            let sink = ResultSink::open(&path).unwrap();
            sink.append(&Json::obj().push("key", "a")).unwrap();
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(&path)
                .unwrap();
            f.write_all(b"{\"key\":\"b\",\"ok\":tr").unwrap(); // torn
        }
        {
            let sink = ResultSink::open(&path).unwrap();
            assert!(
                sink.read_all().is_err(),
                "strict read must error on the torn file"
            );
            assert_eq!(sink.read_valid().unwrap().len(), 1);
        }
        let cache = SweepCache::open(&path, true, key_of).unwrap();
        assert_eq!(cache.completed(), 1);
        assert!(cache.is_done("a"));
        assert!(!cache.is_done("b"));
        // resume repaired the file: the fragment is gone, the next append
        // lands on its own line, and strict reads work again
        cache.append(&Json::obj().push("key", "c")).unwrap();
        let rows = ResultSink::open(&path).unwrap().read_all().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("key").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn repair_drops_fragment_and_preserves_valid_rows() {
        use std::io::Write as _;
        let path = std::env::temp_dir().join("owf_results_repair.jsonl");
        let _ = std::fs::remove_file(&path);
        let sink = ResultSink::open(&path).unwrap();
        sink.append(&Json::obj().push("key", "a").push("x", 1.5))
            .unwrap();
        sink.append(&Json::obj().push("key", "b")).unwrap();
        // clean file: repair is a no-op
        let (rows, dropped) = sink.repair().unwrap();
        assert_eq!((rows.len(), dropped), (2, 0));
        let before = std::fs::read_to_string(&path).unwrap();
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .unwrap();
        f.write_all(b"{\"key\":\"c\",\"x\":").unwrap(); // torn, no newline
        drop(f);
        let (rows, dropped) = sink.repair().unwrap();
        assert_eq!((rows.len(), dropped), (2, 1));
        // the valid prefix survives byte-for-byte
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before);
    }

    #[test]
    fn concurrent_appends_keep_rows_intact() {
        let path = std::env::temp_dir().join("owf_results_par.jsonl");
        let _ = std::fs::remove_file(&path);
        let sink = ResultSink::open(&path).unwrap();
        let ids: Vec<usize> = (0..200).collect();
        crate::util::pool::par_map(&ids, |_, &i| {
            sink.append(
                &Json::obj().push("id", i).push("pad", "x".repeat(64)),
            )
            .unwrap();
        });
        let rows = sink.read_all().unwrap();
        assert_eq!(rows.len(), 200);
        let mut seen: Vec<usize> = rows
            .iter()
            .map(|r| r.get("id").unwrap().as_usize().unwrap())
            .collect();
        seen.sort();
        assert_eq!(seen, ids);
    }

    #[test]
    fn report_renders() {
        let mut r = Report::new("fig1", "test", &["format", "bits", "kl"]);
        r.row(vec!["int4".into(), "4.25".into(), "0.12".into()]);
        r.note("hello");
        let text = r.render();
        assert!(text.contains("fig1"));
        assert!(text.contains("int4"));
        assert!(text.contains("note: hello"));
        assert_eq!(r.to_json_rows().len(), 1);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(0.12345), "0.1235");
        assert_eq!(fmt(12.345), "12.35");
        assert!(fmt(1e-5).contains('e'));
    }
}
