//! JSONL result store: every experiment the coordinator runs appends one
//! JSON row; reports re-read them for aggregation.  Plain files, append-only,
//! human-greppable.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Append-only JSONL sink.
pub struct ResultSink {
    path: PathBuf,
}

impl ResultSink {
    pub fn open(path: impl AsRef<Path>) -> Result<ResultSink> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(ResultSink { path })
    }

    pub fn append(&self, row: &Json) -> Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("open {:?}", self.path))?;
        writeln!(f, "{row}")?;
        Ok(())
    }

    pub fn read_all(&self) -> Result<Vec<Json>> {
        if !self.path.exists() {
            return Ok(Vec::new());
        }
        let text = std::fs::read_to_string(&self.path)?;
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| Json::parse(l).map_err(anyhow::Error::from))
            .collect()
    }
}

/// A printable report: the harness's unit of output (one per paper
/// figure/table).
#[derive(Clone, Debug)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "report {}", self.id);
        self.rows.push(cells);
    }

    pub fn note(&mut self, text: impl Into<String>) {
        self.notes.push(text.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.id, self.title));
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        out.push_str(&header.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(header.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Rows as JSON (for the result sink).
    pub fn to_json_rows(&self) -> Vec<Json> {
        self.rows
            .iter()
            .map(|row| {
                let mut obj = Json::obj().push("report", self.id.as_str());
                for (c, v) in self.columns.iter().zip(row) {
                    obj = obj.push(c, v.as_str());
                }
                obj
            })
            .collect()
    }
}

/// Format a float for table cells.
pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1000.0 || a < 1e-3 {
        format!("{x:.3e}")
    } else if a >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_roundtrip() {
        let path = std::env::temp_dir().join("owf_results_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let sink = ResultSink::open(&path).unwrap();
        sink.append(&Json::obj().push("a", 1.0)).unwrap();
        sink.append(&Json::obj().push("a", 2.0)).unwrap();
        let rows = sink.read_all().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("a").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn report_renders() {
        let mut r = Report::new("fig1", "test", &["format", "bits", "kl"]);
        r.row(vec!["int4".into(), "4.25".into(), "0.12".into()]);
        r.note("hello");
        let text = r.render();
        assert!(text.contains("fig1"));
        assert!(text.contains("int4"));
        assert!(text.contains("note: hello"));
        assert_eq!(r.to_json_rows().len(), 1);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(0.12345), "0.1235");
        assert_eq!(fmt(12.345), "12.35");
        assert!(fmt(1e-5).contains('e'));
    }
}
