//! The experiment scheduler: fans a set of experiment descriptions over a
//! worker pool, with PJRT-bound work serialised on the main thread (the
//! PJRT CPU client is not Sync; XLA multithreads internally) and CPU-bound
//! work (simulated-data sweeps, per-tensor quantisation) parallelised via
//! [`crate::util::pool`].

use std::time::Instant;

use anyhow::Result;

use crate::util::pool::par_map;

/// One schedulable unit.
pub struct Job<T: Send> {
    pub name: String,
    pub kind: JobKind,
    pub run: Box<dyn Fn() -> Result<T> + Sync + Send>,
}

/// Where a job is allowed to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// Pure CPU — safe to run on the worker pool.
    Cpu,
    /// Touches the PJRT client — must run serialised.
    Pjrt,
}

/// Outcome of one job.
pub struct JobResult<T> {
    pub name: String,
    pub seconds: f64,
    pub outcome: Result<T>,
}

/// Run all jobs: CPU jobs in parallel, PJRT jobs sequentially afterwards,
/// preserving input order in the returned vector.
pub fn run_jobs<T: Send>(jobs: Vec<Job<T>>) -> Vec<JobResult<T>> {
    run_jobs_with(jobs, |_, _| {})
}

/// [`run_jobs`] with a streaming completion hook: `on_done(index, result)`
/// fires as each job finishes (from worker threads for CPU jobs, the main
/// thread for PJRT jobs), before the batch is collected.  The sweep engine
/// uses this to append JSONL rows incrementally, so a killed run keeps
/// every completed point and `--resume` picks up from there.
pub fn run_jobs_with<T: Send>(
    jobs: Vec<Job<T>>,
    on_done: impl Fn(usize, &JobResult<T>) + Sync,
) -> Vec<JobResult<T>> {
    // index jobs, split by kind
    let mut slots: Vec<Option<JobResult<T>>> =
        jobs.iter().map(|_| None).collect();
    let mut cpu: Vec<(usize, Job<T>)> = Vec::new();
    let mut pjrt: Vec<(usize, Job<T>)> = Vec::new();
    for (i, j) in jobs.into_iter().enumerate() {
        match j.kind {
            JobKind::Cpu => cpu.push((i, j)),
            JobKind::Pjrt => pjrt.push((i, j)),
        }
    }
    let cpu_results = par_map(&cpu, |_, (i, job)| {
        let t0 = Instant::now();
        let outcome = (job.run)();
        let result = JobResult {
            name: job.name.clone(),
            seconds: t0.elapsed().as_secs_f64(),
            outcome,
        };
        on_done(*i, &result);
        (*i, result)
    });
    for (i, r) in cpu_results {
        slots[i] = Some(r);
    }
    for (i, job) in pjrt {
        let t0 = Instant::now();
        let outcome = (job.run)();
        let result = JobResult {
            name: job.name,
            seconds: t0.elapsed().as_secs_f64(),
            outcome,
        };
        on_done(i, &result);
        slots[i] = Some(result);
    }
    slots.into_iter().map(|s| s.expect("job not run")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_and_preserves_order() {
        let jobs: Vec<Job<usize>> = (0..20)
            .map(|i| Job {
                name: format!("job{i}"),
                kind: if i % 3 == 0 { JobKind::Pjrt } else { JobKind::Cpu },
                run: Box::new(move || Ok(i * 2)),
            })
            .collect();
        let results = run_jobs(jobs);
        assert_eq!(results.len(), 20);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.name, format!("job{i}"));
            assert_eq!(*r.outcome.as_ref().unwrap(), i * 2);
        }
    }

    #[test]
    fn five_hundred_jobs_order_and_error_isolation() {
        // stress: a large mixed batch must come back in input order, with
        // every 7th job failing and nothing else poisoned by it
        let jobs: Vec<Job<usize>> = (0..500)
            .map(|i| Job {
                name: format!("j{i}"),
                kind: if i % 5 == 0 { JobKind::Pjrt } else { JobKind::Cpu },
                run: Box::new(move || {
                    if i % 7 == 0 {
                        anyhow::bail!("planned failure {i}");
                    }
                    Ok(i)
                }),
            })
            .collect();
        let results = run_jobs(jobs);
        assert_eq!(results.len(), 500);
        let mut failures = 0;
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.name, format!("j{i}"), "order broken at {i}");
            if i % 7 == 0 {
                let msg =
                    r.outcome.as_ref().err().unwrap().to_string();
                assert!(msg.contains(&format!("planned failure {i}")));
                failures += 1;
            } else {
                assert_eq!(*r.outcome.as_ref().unwrap(), i);
            }
            assert!(r.seconds >= 0.0);
        }
        assert_eq!(failures, 500usize.div_ceil(7));
    }

    #[test]
    fn streaming_hook_sees_every_completion_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Mutex;
        let jobs: Vec<Job<usize>> = (0..100)
            .map(|i| Job {
                name: format!("j{i}"),
                kind: if i % 4 == 0 { JobKind::Pjrt } else { JobKind::Cpu },
                run: Box::new(move || Ok(i * 3)),
            })
            .collect();
        let calls = AtomicUsize::new(0);
        let seen = Mutex::new(vec![false; 100]);
        let results = run_jobs_with(jobs, |i, r| {
            calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(*r.outcome.as_ref().unwrap(), i * 3);
            let mut guard = seen.lock().unwrap();
            assert!(!guard[i], "duplicate completion for {i}");
            guard[i] = true;
        });
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert!(seen.lock().unwrap().iter().all(|&b| b));
        assert_eq!(results.len(), 100);
    }

    #[test]
    fn errors_do_not_poison_others() {
        let jobs: Vec<Job<()>> = vec![
            Job {
                name: "ok".into(),
                kind: JobKind::Cpu,
                run: Box::new(|| Ok(())),
            },
            Job {
                name: "bad".into(),
                kind: JobKind::Cpu,
                run: Box::new(|| anyhow::bail!("boom")),
            },
        ];
        let results = run_jobs(jobs);
        assert!(results[0].outcome.is_ok());
        assert!(results[1].outcome.is_err());
    }
}
