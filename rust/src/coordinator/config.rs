//! Experiment configuration: a parseable, printable description of a full
//! quantisation scheme (element format × scaling × extras), the unit of
//! work the coordinator schedules and the eval harness sweeps.
//!
//! Spec grammar (round-trips through `name()` / `parse()`):
//!
//! ```text
//!   <element>@<bits>:<granularity>-<statistic>[:<flags>]
//!   element      = int | int-sym | e2m1 | e3m0 | ... | nf4 | sf4 | af4
//!                | cbrt-normal | cbrt-laplace | cbrt-t[<nu>] | lloyd
//!                | grid            (uniform grid + ideal entropy coder)
//!   granularity  = tensor | channel | block<B>
//!   statistic    = rms | absmax | signmax
//!   flags        = comma list of: sym | asym | sparse<frac> | rot |
//!                  compress | mult<x> | fisher
//! ```
//!
//! e.g. `cbrt-t@4:block128-absmax`, `int@3:channel-absmax:sparse0.001`,
//! `grid@3.5:tensor-rms:compress`.

use anyhow::{bail, Context, Result};

use crate::dist::Family;
use crate::formats::cbrt::{cbrt_absmax, cbrt_rms, CBRT_ALPHA};
use crate::formats::float::float_codebook_normalised;
use crate::formats::int::int_codebook;
use crate::formats::lloyd::{LloydInit, LloydMax};
use crate::formats::quantile::{af4, nf, sf};
use crate::formats::{Codebook, Variant};
use crate::scaling::{Granularity, ScaleFormat, Statistic};

/// Element-format family of a scheme.
#[derive(Clone, Debug, PartialEq)]
pub enum Element {
    Int,
    Float { exp: u32, man: u32 },
    Cbrt { family: Family, nu: f64 },
    Nf,
    Sf { nu: f64 },
    Af4,
    Lloyd { fisher_weighted: bool },
    /// Uniform grid + ideal entropy coder (the §2.3 compressed quantiser);
    /// `bits` is the target rate and may be fractional.
    Grid,
}

/// A complete scheme.
#[derive(Clone, Debug)]
pub struct Scheme {
    pub element: Element,
    pub bits: f64,
    pub granularity: Granularity,
    pub statistic: Statistic,
    pub scale_format: ScaleFormat,
    pub variant: Variant,
    /// Sparse outlier fraction (0 = off).
    pub sparse: f64,
    /// Random rotations before quantisation (fig. 29).
    pub rotate: bool,
    /// Lossless compression of element indices (Shannon-limit model).
    pub compress: bool,
    /// Quantiser scale multiplier; NaN = search (fig. 23/35).
    pub multiplier: f64,
}

impl Scheme {
    pub fn new(element: Element, bits: f64, granularity: Granularity,
               statistic: Statistic) -> Scheme {
        Scheme {
            element,
            bits,
            granularity,
            statistic,
            scale_format: crate::scaling::DEFAULT_SCALE,
            variant: Variant::Symmetric,
            sparse: 0.0,
            rotate: false,
            compress: false,
            multiplier: 1.0,
        }
    }

    pub fn with_variant(mut self, v: Variant) -> Scheme {
        self.variant = v;
        if v == Variant::Signmax {
            self.statistic = Statistic::Signmax;
        }
        self
    }

    pub fn with_sparse(mut self, fraction: f64) -> Scheme {
        self.sparse = fraction;
        self
    }

    pub fn with_compress(mut self) -> Scheme {
        self.compress = true;
        self
    }

    pub fn with_rotate(mut self) -> Scheme {
        self.rotate = true;
        self
    }

    pub fn with_scale_format(mut self, f: ScaleFormat) -> Scheme {
        self.scale_format = f;
        self
    }

    pub fn with_multiplier(mut self, m: f64) -> Scheme {
        self.multiplier = m;
        self
    }

    /// Integer LUT width for codebook formats.
    pub fn int_bits(&self) -> u32 {
        self.bits.round().clamp(2.0, 12.0) as u32
    }

    /// The block size used by absmax-format constructions (their truncated
    /// block-maximum model needs one even for channel/tensor granularity,
    /// where the "block" is the scale-group length).
    fn model_block(&self, group_len: usize) -> usize {
        match self.granularity {
            Granularity::Block(b) => b,
            _ => group_len.max(2),
        }
    }

    /// Build the normalised codebook for this scheme.
    /// `data` is required for Lloyd-Max (fitted formats); `group_len` is
    /// the scale-group length for absmax constructions.
    pub fn build_codebook(
        &self,
        group_len: usize,
        data: Option<&[f32]>,
        weights: &[f32],
    ) -> Result<Codebook> {
        let bits = self.int_bits();
        let block = self.model_block(group_len);
        let cb = match &self.element {
            Element::Int => int_codebook(
                bits,
                if self.statistic == Statistic::Signmax {
                    Variant::Signmax
                } else {
                    self.variant
                },
            ),
            Element::Float { exp, man } => {
                let total = 1 + exp + man;
                if total as f64 != bits as f64 {
                    // allowed: caller picked e/m directly
                }
                float_codebook_normalised(*exp, *man)
            }
            Element::Cbrt { family, nu } => match self.statistic {
                Statistic::Rms => {
                    cbrt_rms(*family, *nu, bits, self.variant, CBRT_ALPHA)
                }
                Statistic::Absmax => cbrt_absmax(
                    *family, *nu, bits, block, self.variant, CBRT_ALPHA,
                ),
                Statistic::Signmax => cbrt_absmax(
                    *family, *nu, bits, block, Variant::Signmax, CBRT_ALPHA,
                ),
            },
            Element::Nf => nf(bits),
            Element::Sf { nu } => sf(bits, *nu),
            Element::Af4 => af4(block),
            Element::Lloyd { fisher_weighted } => {
                let data =
                    data.context("Lloyd-Max needs data to fit against")?;
                // fit in *scaled* space: normalise a sample by group scales
                let init = if self.statistic == Statistic::Rms {
                    LloydInit::KmeansPp
                } else {
                    LloydInit::Uniform
                };
                let scaled = scale_sample(
                    data,
                    self.granularity,
                    self.statistic,
                    group_len,
                );
                let w = if *fisher_weighted { weights } else { &[] };
                let mut cb = LloydMax::new(bits, init).fit(&scaled, w);
                if self.variant == Variant::Asymmetric {
                    cb = cb.asymmetrise();
                }
                cb
            }
            Element::Grid => bail!("grid schemes bypass codebooks"),
        };
        Ok(cb)
    }

    /// Canonical printable name.
    pub fn name(&self) -> String {
        let elem = match &self.element {
            Element::Int => "int".to_string(),
            Element::Float { exp, man } => format!("e{exp}m{man}"),
            Element::Cbrt { family, nu } => match family {
                Family::Normal => "cbrt-normal".into(),
                Family::Laplace => "cbrt-laplace".into(),
                Family::StudentT => format!("cbrt-t{nu}"),
                Family::Uniform => "cbrt-uniform".into(),
            },
            Element::Nf => "nf".to_string(),
            Element::Sf { nu } => format!("sf{nu}"),
            Element::Af4 => "af4".to_string(),
            Element::Lloyd { fisher_weighted } => {
                if *fisher_weighted {
                    "lloyd-fisher".into()
                } else {
                    "lloyd".into()
                }
            }
            Element::Grid => "grid".to_string(),
        };
        let mut flags = Vec::new();
        if self.variant == Variant::Asymmetric {
            flags.push("asym".to_string());
        }
        if self.sparse > 0.0 {
            flags.push(format!("sparse{}", self.sparse));
        }
        if self.rotate {
            flags.push("rot".into());
        }
        if self.compress {
            flags.push("compress".into());
        }
        if self.multiplier != 1.0 {
            if self.multiplier.is_nan() {
                flags.push("search".into());
            } else {
                flags.push(format!("mult{}", self.multiplier));
            }
        }
        let base = format!(
            "{elem}@{}:{}-{}",
            trim_float(self.bits),
            self.granularity.name(),
            self.statistic.name()
        );
        if flags.is_empty() {
            base
        } else {
            format!("{base}:{}", flags.join(","))
        }
    }

    /// Parse the grammar documented on the module.
    pub fn parse(spec: &str) -> Result<Scheme> {
        let mut parts = spec.split(':');
        let elem_bits = parts.next().context("empty spec")?;
        let scaling = parts
            .next()
            .with_context(|| format!("{spec}: missing scaling section"))?;
        let flags = parts.next().unwrap_or("");
        if parts.next().is_some() {
            bail!("{spec}: too many sections");
        }

        let (elem_str, bits_str) = elem_bits
            .split_once('@')
            .with_context(|| format!("{elem_bits}: missing @bits"))?;
        let bits: f64 = bits_str
            .parse()
            .with_context(|| format!("bad bits {bits_str}"))?;
        let element = parse_element(elem_str)?;

        let (gran_str, stat_str) = scaling
            .rsplit_once('-')
            .with_context(|| format!("{scaling}: want <granularity>-<stat>"))?;
        let granularity = if gran_str == "tensor" {
            Granularity::Tensor
        } else if gran_str == "channel" {
            Granularity::Channel
        } else if let Some(b) = gran_str.strip_prefix("block") {
            Granularity::Block(b.parse().context("bad block size")?)
        } else {
            bail!("unknown granularity {gran_str}");
        };
        let statistic = match stat_str {
            "rms" => Statistic::Rms,
            "absmax" => Statistic::Absmax,
            "signmax" => Statistic::Signmax,
            other => bail!("unknown statistic {other}"),
        };

        let mut scheme = Scheme::new(element, bits, granularity, statistic);
        if statistic == Statistic::Signmax {
            scheme.variant = Variant::Signmax;
        }
        for flag in flags.split(',').filter(|f| !f.is_empty()) {
            if flag == "sym" {
                scheme.variant = Variant::Symmetric;
            } else if flag == "asym" {
                scheme.variant = Variant::Asymmetric;
            } else if flag == "rot" {
                scheme.rotate = true;
            } else if flag == "compress" {
                scheme.compress = true;
            } else if flag == "fisher" {
                if let Element::Lloyd { .. } = scheme.element {
                    scheme.element = Element::Lloyd {
                        fisher_weighted: true,
                    };
                }
            } else if let Some(f) = flag.strip_prefix("sparse") {
                scheme.sparse = f.parse().context("bad sparse fraction")?;
            } else if let Some(m) = flag.strip_prefix("mult") {
                scheme.multiplier = m.parse().context("bad multiplier")?;
            } else if flag == "search" {
                scheme.multiplier = f64::NAN;
            } else {
                bail!("unknown flag {flag}");
            }
        }
        Ok(scheme)
    }
}

fn parse_element(s: &str) -> Result<Element> {
    if s == "int" {
        return Ok(Element::Int);
    }
    if s == "nf" || s == "nf4" {
        return Ok(Element::Nf);
    }
    if s == "af4" {
        return Ok(Element::Af4);
    }
    if let Some(nu) = s.strip_prefix("sf") {
        let nu: f64 = if nu.is_empty() || nu == "4" {
            5.0
        } else {
            nu.parse().context("bad sf nu")?
        };
        return Ok(Element::Sf { nu });
    }
    if s == "lloyd" {
        return Ok(Element::Lloyd {
            fisher_weighted: false,
        });
    }
    if s == "grid" {
        return Ok(Element::Grid);
    }
    if s == "cbrt-normal" {
        return Ok(Element::Cbrt {
            family: Family::Normal,
            nu: 0.0,
        });
    }
    if s == "cbrt-laplace" {
        return Ok(Element::Cbrt {
            family: Family::Laplace,
            nu: 0.0,
        });
    }
    if let Some(nu) = s.strip_prefix("cbrt-t") {
        let nu: f64 = if nu.is_empty() {
            7.0
        } else {
            nu.parse().context("bad cbrt-t nu")?
        };
        return Ok(Element::Cbrt {
            family: Family::StudentT,
            nu,
        });
    }
    // eKmM float spec
    if let Some(rest) = s.strip_prefix('e') {
        if let Some((e, m)) = rest.split_once('m') {
            return Ok(Element::Float {
                exp: e.parse().context("bad exp bits")?,
                man: m.parse().context("bad man bits")?,
            });
        }
    }
    bail!("unknown element format {s:?}")
}

/// Normalise a sample of data by its scheme scales (for Lloyd fitting).
fn scale_sample(
    data: &[f32],
    granularity: Granularity,
    statistic: Statistic,
    channel_len: usize,
) -> Vec<f32> {
    let groups =
        crate::scaling::scale_groups(data.len(), granularity, channel_len);
    let mut out = Vec::with_capacity(data.len());
    for (start, len) in groups {
        let block = &data[start..start + len];
        let s = statistic.compute(block);
        let s = if s == 0.0 { 1.0 } else { s };
        out.extend(block.iter().map(|&x| x / s));
    }
    out
}

fn trim_float(x: f64) -> String {
    if x.fract() == 0.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

// ---------------------------------------------------------------------------
// sweep-grid grammar
// ---------------------------------------------------------------------------

/// Hard cap on grid expansion (guards typo'd ranges like `{1..100000}`).
pub const MAX_GRID_POINTS: usize = 100_000;

/// Widest single `{lo..hi}` range (bit widths and block sizes never need
/// more; typos fail fast instead of allocating).
pub const MAX_RANGE_SPAN: usize = 4096;

/// Expand a sweep-grid expression into concrete scheme specs.
///
/// Grammar: a grid is one or more scheme templates separated by `;`.  Each
/// template is a spec string in which any `{...}` group expands to a set of
/// alternatives — either a comma list (`block{32,64,128}`) or an inclusive
/// integer range (`@{2..8}`) — with multiple groups combining as a
/// cartesian product (leftmost group varies slowest).
///
/// ```text
///   cbrt-t7@{2..8}:block{32,64,128}-absmax
///     → cbrt-t7@2:block32-absmax, cbrt-t7@2:block64-absmax, ...
///       cbrt-t7@8:block128-absmax                      (21 specs)
///   {int,nf}@4:block64-absmax ; grid@{3,4}:tensor-rms:compress
///     → 4 specs
/// ```
///
/// Every expanded spec must parse as a [`Scheme`] (errors name the
/// offending spec); duplicates are dropped, first occurrence wins.
/// A grid point is either a scheme spec or a fractional-allocator point
/// `frac@<bits>:<granularity>-<statistic>[:<flags>]`, which bypasses the
/// scheme grammar — its budget may be fractional and its tail is
/// validated against the allocator's own base-scheme rules.
fn validate_grid_point(s: &str) -> Result<()> {
    if let Some(rest) = s.strip_prefix("frac@") {
        let Some((bits, tail)) = rest.split_once(':') else {
            bail!(
                "frac point needs \
                 frac@<bits>:<granularity>-<statistic>[:<flags>]"
            );
        };
        bits.parse::<f64>()
            .map_err(|e| anyhow::anyhow!("frac budget {bits:?}: {e}"))?;
        let base = Scheme::parse(&format!("int@4:{tail}"))?;
        crate::alloc::frac::validate_base(&base)?;
        return Ok(());
    }
    Scheme::parse(s).map(|_| ())
}

pub fn expand_grid(grid: &str) -> Result<Vec<String>> {
    let mut out: Vec<String> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for template in grid.split(';').map(str::trim).filter(|s| !s.is_empty())
    {
        // depth-first expansion of the leftmost group keeps output order
        // lexicographic in group positions
        let mut stack = vec![template.to_string()];
        while let Some(s) = stack.pop() {
            match brace_group(&s)? {
                None => {
                    validate_grid_point(&s).with_context(|| {
                        format!("grid point {s:?} (from {template:?})")
                    })?;
                    if seen.insert(s.clone()) {
                        out.push(s);
                    }
                }
                Some((start, end, options)) => {
                    if stack.len() + options.len() > MAX_GRID_POINTS {
                        bail!(
                            "grid expands past {MAX_GRID_POINTS} points"
                        );
                    }
                    for opt in options.into_iter().rev() {
                        stack.push(format!(
                            "{}{}{}",
                            &s[..start],
                            opt,
                            &s[end + 1..]
                        ));
                    }
                }
            }
            if out.len() > MAX_GRID_POINTS {
                bail!("grid expands past {MAX_GRID_POINTS} points");
            }
        }
    }
    if out.is_empty() {
        bail!("grid {grid:?} expands to zero specs");
    }
    Ok(out)
}

/// Find the leftmost `{...}` group: returns (byte offset of `{`, byte
/// offset of `}`, expanded alternatives), or `None` when the string has no
/// group.
fn brace_group(s: &str) -> Result<Option<(usize, usize, Vec<String>)>> {
    let Some(start) = s.find('{') else {
        if s.contains('}') {
            bail!("{s}: unmatched '}}'");
        }
        return Ok(None);
    };
    let rest = &s[start + 1..];
    let end_rel = rest.find('}').with_context(|| format!("{s}: unmatched '{{'"))?;
    let inner = &rest[..end_rel];
    if inner.contains('{') {
        bail!("{s}: nested braces are not supported");
    }
    let end = start + 1 + end_rel;
    let options: Vec<String> = if !inner.contains(',') && inner.contains("..")
    {
        let (lo, hi) = inner
            .split_once("..")
            .with_context(|| format!("{s}: bad range {inner:?}"))?;
        let lo: i64 = lo.trim().parse().with_context(|| {
            format!("{s}: bad range start {lo:?}")
        })?;
        let hi: i64 = hi.trim().parse().with_context(|| {
            format!("{s}: bad range end {hi:?}")
        })?;
        if hi < lo {
            bail!("{s}: empty range {lo}..{hi}");
        }
        // i128: hi − lo can overflow i64 for absurd endpoints
        if (hi as i128 - lo as i128) >= MAX_RANGE_SPAN as i128 {
            bail!("{s}: range {lo}..{hi} too large (max {MAX_RANGE_SPAN})");
        }
        (lo..=hi).map(|v| v.to_string()).collect()
    } else {
        let opts: Vec<String> = inner
            .split(',')
            .map(|o| o.trim().to_string())
            .filter(|o| !o.is_empty())
            .collect();
        if opts.is_empty() {
            bail!("{s}: empty alternation {{{inner}}}");
        }
        opts
    };
    Ok(Some((start, end, options)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for spec in [
            "cbrt-t7@4:block128-absmax",
            "int@3:channel-absmax:sparse0.001",
            "grid@3.5:tensor-rms:compress",
            "e2m1@4:block64-absmax",
            "nf@4:block64-absmax",
            "lloyd@4:tensor-rms",
            "cbrt-normal@5:tensor-rms:asym",
            "int@4:block128-signmax",
            "cbrt-laplace@4:block128-absmax:rot",
        ] {
            let s = Scheme::parse(spec).unwrap();
            let name = s.name();
            let re = Scheme::parse(&name).unwrap();
            assert_eq!(name, re.name(), "spec {spec} → {name}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "int:tensor-rms",
            "int@4",
            "wat@4:tensor-rms",
            "int@4:tensor-wat",
            "int@4:tensor-rms:wat",
            "int@4:blockx-rms",
        ] {
            assert!(Scheme::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn signmax_statistic_forces_variant() {
        let s = Scheme::parse("int@4:block128-signmax").unwrap();
        assert_eq!(s.variant, Variant::Signmax);
        let cb = s.build_codebook(128, None, &[]).unwrap();
        assert!(cb.has_zero());
        assert_eq!(*cb.points().last().unwrap(), 1.0);
    }

    #[test]
    fn codebooks_build_for_all_elements() {
        let mut rng = crate::util::rng::Rng::new(1);
        let data: Vec<f32> =
            (0..4096).map(|_| rng.normal() as f32).collect();
        for spec in [
            "int@4:block128-absmax",
            "e2m1@4:block128-absmax",
            "cbrt-normal@4:tensor-rms",
            "cbrt-t5@4:block128-absmax",
            "cbrt-laplace@3:block64-absmax",
            "nf@4:block64-absmax",
            "sf5@4:block64-absmax",
            "af4@4:block64-absmax",
            "lloyd@4:tensor-rms",
        ] {
            let s = Scheme::parse(spec).unwrap();
            let cb = s.build_codebook(128, Some(&data), &[]).unwrap();
            assert!(cb.len() >= 4, "{spec}");
            assert!(cb.len() <= 16, "{spec}");
        }
    }

    #[test]
    fn grid_has_no_codebook() {
        let s = Scheme::parse("grid@4:tensor-rms:compress").unwrap();
        assert!(s.build_codebook(128, None, &[]).is_err());
    }

    #[test]
    fn expand_grid_range_and_list() {
        let specs =
            expand_grid("cbrt-t7@{2..8}:block{32,64,128}-absmax").unwrap();
        assert_eq!(specs.len(), 7 * 3);
        assert_eq!(specs[0], "cbrt-t7@2:block32-absmax");
        assert_eq!(specs[1], "cbrt-t7@2:block64-absmax");
        assert_eq!(specs[3], "cbrt-t7@3:block32-absmax");
        assert_eq!(specs[20], "cbrt-t7@8:block128-absmax");
        // every expansion is a valid scheme
        for s in &specs {
            Scheme::parse(s).unwrap();
        }
    }

    #[test]
    fn expand_grid_union_and_dedup() {
        let specs = expand_grid(
            "{int,nf}@4:block64-absmax ; int@4:block64-absmax",
        )
        .unwrap();
        assert_eq!(
            specs,
            vec![
                "int@4:block64-absmax".to_string(),
                "nf@4:block64-absmax".to_string(),
            ]
        );
    }

    #[test]
    fn expand_grid_plain_spec_passes_through() {
        let specs = expand_grid("grid@3.5:tensor-rms:compress").unwrap();
        assert_eq!(specs, vec!["grid@3.5:tensor-rms:compress".to_string()]);
    }

    #[test]
    fn expand_grid_hundred_plus_points() {
        // the acceptance-criteria grid shape: ≥ 100 points
        let specs = expand_grid(
            "{int,cbrt-t5,cbrt-normal,cbrt-laplace,nf}@{2..8}:block{32,64,128}-absmax",
        )
        .unwrap();
        assert_eq!(specs.len(), 5 * 7 * 3);
        let unique: std::collections::HashSet<&String> =
            specs.iter().collect();
        assert_eq!(unique.len(), specs.len());
    }

    #[test]
    fn expand_grid_rejects_garbage() {
        for bad in [
            "",
            "  ;  ",
            "int@{4..2}:tensor-rms",          // empty range
            "int@{2..8:tensor-rms",           // unmatched {
            "int@2..8}:tensor-rms",           // unmatched }
            "int@{2..{3..4}}:tensor-rms",     // nested
            "wat@{2..4}:tensor-rms",          // expands to invalid scheme
            "int@{}:tensor-rms",              // empty alternation
            "int@{1..99999}:tensor-rms",      // too large
            // span overflows i64 — must error, not panic
            "int@{-9000000000000000000..9000000000000000000}:tensor-rms",
        ] {
            assert!(expand_grid(bad).is_err(), "{bad:?} should fail");
        }
    }
}
