//! The parallel, resumable sweep engine behind `owf sweep`.
//!
//! A sweep is a grid expression (see [`crate::coordinator::config::expand_grid`])
//! crossed with a seed range.  Each `(scheme, size, seed)` point — keyed
//! together with the run parameters (`--samples`/`--eval-seqs`), so stale
//! rows never satisfy a resume — becomes one job; CPU points (simulated-data R sweeps, [`crate::eval::sim`]) fan out
//! over the [`crate::util::pool`] workers (`OWF_THREADS`), PJRT points
//! (checkpoint KL sweeps, [`crate::eval::llm`]) run serialised on the main
//! thread — both stream one JSONL row per finished point through a
//! [`SweepCache`].  Kill the process at any moment: rerunning with
//! `--resume` loads the completed keys from the output file and schedules
//! only the remainder.

use std::panic::catch_unwind;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::config::{expand_grid, Scheme};
use crate::coordinator::results::SweepCache;
use crate::coordinator::scheduler::{run_jobs_with, Job, JobKind};
use crate::eval::{llm, sim};
use crate::util::json::Json;

/// The `size` column of simulated-data rows (LLM rows carry the model
/// size).
pub const SIM_SIZE: &str = "sim";

/// What a sweep point evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepData {
    /// iid draws → R, R·2^b (pure CPU, parallel).
    Sim,
    /// microllama direct-cast → top-k KL (PJRT, serialised).
    Llm,
}

/// Sweep configuration (CLI flags map 1:1).
#[derive(Clone, Debug)]
pub struct SweepOpts {
    pub data: SweepData,
    /// JSONL output; doubles as the resume state.
    pub out: PathBuf,
    /// Skip `(scheme, size, seed)` rows already completed in `out`.
    pub resume: bool,
    /// Seeds per scheme (points = specs × seeds).
    pub seeds: u64,
    /// Samples per simulated point.
    pub samples: usize,
    /// Model size for LLM points.
    pub size: String,
    /// Eval sequences per LLM KL evaluation.
    pub eval_seqs: usize,
}

impl Default for SweepOpts {
    fn default() -> SweepOpts {
        SweepOpts {
            data: SweepData::Sim,
            out: PathBuf::from("sweep.jsonl"),
            resume: false,
            seeds: 1,
            samples: 1 << 16,
            size: "m".into(),
            eval_seqs: 24,
        }
    }
}

/// What a sweep run did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepStats {
    /// grid points × seeds
    pub planned: usize,
    /// already complete in the output file (resume hits)
    pub skipped: usize,
    /// executed this run
    pub ran: usize,
    /// executed and failed (row written with `ok: false`)
    pub failed: usize,
}

/// Bumped whenever a quantisation change alters the reported metrics for
/// the same `(scheme, size, seed)` point — e.g. the PR 3 fused encode
/// (reciprocal-multiply indices) and dense-stream `:compress` entropy —
/// so `--resume` reruns rows computed under older definitions instead of
/// silently mixing incompatible metrics in one JSONL.
pub const METRICS_VERSION: u32 = 2;

/// The run-parameter tag folded into every resume key, so rows computed
/// under different `--samples` / `--eval-seqs` — or an older
/// [`METRICS_VERSION`] — are not silently reused.  Sim tags use the
/// *effective* sample count (the engine floors tiny `--samples` at
/// [`sim::MIN_SWEEP_SAMPLES`]), so the tag always describes the
/// computation that actually ran.
pub fn params_tag(opts: &SweepOpts) -> String {
    match opts.data {
        SweepData::Sim => format!(
            "n{}-v{METRICS_VERSION}",
            opts.samples.max(sim::MIN_SWEEP_SAMPLES)
        ),
        SweepData::Llm => {
            format!("e{}-v{METRICS_VERSION}", opts.eval_seqs)
        }
    }
}

/// The resume key of one point.
pub fn point_key(spec: &str, size: &str, seed: u64, params: &str) -> String {
    format!("{spec}|{size}|{seed}|{params}")
}

/// Key of a completed row; `None` for failed/malformed rows so they rerun.
pub fn row_key(row: &Json) -> Option<String> {
    if !row.get("ok").and_then(Json::as_bool).unwrap_or(false) {
        return None;
    }
    let spec = row.get("scheme")?.as_str()?;
    let size = row.get("size")?.as_str()?;
    let seed = row.get("seed")?.as_f64()? as u64;
    let params = row.get("params")?.as_str()?;
    Some(point_key(spec, size, seed, params))
}

/// Expand `grid`, skip completed points, run the rest, stream rows to
/// `opts.out`.
pub fn run_sweep(grid: &str, opts: &SweepOpts) -> Result<SweepStats> {
    let specs = expand_grid(grid)?;
    let seeds = opts.seeds.max(1);
    if opts.data == SweepData::Llm && seeds > 1 {
        // checkpoint evaluations are deterministic per scheme: extra seeds
        // would repeat identical (expensive) PJRT runs
        bail!("--seeds > 1 is only meaningful for --data sim");
    }
    let size_tag = match opts.data {
        SweepData::Sim => SIM_SIZE.to_string(),
        SweepData::Llm => opts.size.clone(),
    };

    // all fallible setup happens BEFORE the cache opens: a fresh (non
    // --resume) open truncates the output file, and a run that then dies
    // immediately would have destroyed prior results for zero work
    let mut llm_env = match opts.data {
        SweepData::Sim => None,
        SweepData::Llm => {
            let run_opts = crate::eval::RunOpts {
                samples: opts.samples,
                eval_seqs: opts.eval_seqs,
                size: opts.size.clone(),
                ..Default::default()
            };
            Some(llm::Env::open(run_opts).context(
                "LLM sweeps need the PJRT runtime and artifacts",
            )?)
        }
    };

    let cache = SweepCache::open(&opts.out, opts.resume, row_key)?;
    let params = params_tag(opts);
    let mut todo: Vec<(String, u64)> = Vec::new();
    let mut skipped = 0usize;
    for spec in &specs {
        for seed in 0..seeds {
            if cache.is_done(&point_key(spec, &size_tag, seed, &params)) {
                skipped += 1;
            } else {
                todo.push((spec.clone(), seed));
            }
        }
    }
    let planned = specs.len() * seeds as usize;
    let ran = todo.len();

    let failed = match llm_env.as_mut() {
        None => run_sim_points(&todo, opts, &params, &cache)?,
        Some(env) => {
            run_llm_points(&todo, &size_tag, &params, env, &cache)?
        }
    };

    Ok(SweepStats {
        planned,
        skipped,
        ran,
        failed,
    })
}

/// Fan simulated-data points over the worker pool, appending each row as
/// its job completes.
fn run_sim_points(
    todo: &[(String, u64)],
    opts: &SweepOpts,
    params: &str,
    cache: &SweepCache,
) -> Result<usize> {
    let samples = opts.samples;
    let jobs: Vec<Job<Json>> = todo
        .iter()
        .map(|(spec, seed)| {
            let spec = spec.clone();
            let seed = *seed;
            Job {
                name: point_key(&spec, SIM_SIZE, seed, params),
                kind: JobKind::Cpu,
                run: Box::new(move || {
                    // a panicking scheme (e.g. an assert deep in a codebook
                    // construction) must fail its own row, not the sweep
                    match catch_unwind(|| {
                        sim::sweep_point(&spec, samples, seed)
                    }) {
                        Ok(Ok(p)) => Ok(Json::obj()
                            .push("bits", p.bits)
                            .push("r", p.r)
                            .push("r2b", p.r2b)),
                        Ok(Err(e)) => Err(e),
                        Err(_) => Err(anyhow::anyhow!(
                            "panic evaluating {spec}"
                        )),
                    }
                }),
            }
        })
        .collect();

    let failed = AtomicUsize::new(0);
    let append_failures = AtomicUsize::new(0);
    run_jobs_with(jobs, |i, r| {
        let (spec, seed) = &todo[i];
        let row = assemble_row(
            spec, SIM_SIZE, *seed, params, r.seconds, &r.outcome,
        );
        if cache.append(&row).is_err() {
            append_failures.fetch_add(1, Ordering::Relaxed);
        }
        if r.outcome.is_err() {
            failed.fetch_add(1, Ordering::Relaxed);
        }
    });
    let lost = append_failures.load(Ordering::Relaxed);
    if lost > 0 {
        bail!("failed to append {lost} rows to {:?}", cache.path());
    }
    Ok(failed.load(Ordering::Relaxed))
}

/// Run checkpoint KL points serially through one [`llm::Env`] (the PJRT
/// client is not Sync; XLA multithreads internally).
fn run_llm_points(
    todo: &[(String, u64)],
    size_tag: &str,
    params: &str,
    env: &mut llm::Env,
    cache: &SweepCache,
) -> Result<usize> {
    let mut failed = 0usize;
    for (spec, seed) in todo {
        let t0 = Instant::now();
        let outcome = Scheme::parse(spec)
            .and_then(|scheme| env.sweep_row(size_tag, &scheme));
        let row = assemble_row(
            spec,
            size_tag,
            *seed,
            params,
            t0.elapsed().as_secs_f64(),
            &outcome,
        );
        cache.append(&row)?;
        if outcome.is_err() {
            failed += 1;
        }
    }
    Ok(failed)
}

/// Identity columns + metric fragment (or error) + timing, in one row.
fn assemble_row(
    spec: &str,
    size: &str,
    seed: u64,
    params: &str,
    seconds: f64,
    outcome: &Result<Json>,
) -> Json {
    let mut row = Json::obj()
        .push("scheme", spec)
        .push("size", size)
        .push("seed", seed as usize)
        .push("params", params)
        .push("ok", outcome.is_ok());
    match outcome {
        Ok(metrics) => {
            if let Some(pairs) = metrics.as_obj() {
                for (k, v) in pairs {
                    row = row.push(k, v.clone());
                }
            }
        }
        Err(e) => {
            row = row.push("error", e.to_string());
        }
    }
    row.push("seconds", seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(name)
    }

    fn opts(out: PathBuf) -> SweepOpts {
        SweepOpts {
            out,
            samples: 1 << 12,
            ..Default::default()
        }
    }

    #[test]
    fn sim_sweep_writes_one_row_per_point() {
        let out = tmp("owf_sweep_unit.jsonl");
        let _ = std::fs::remove_file(&out);
        let stats = run_sweep(
            "cbrt-t5@{3,4}:block{32,64}-absmax",
            &opts(out.clone()),
        )
        .unwrap();
        assert_eq!(
            stats,
            SweepStats {
                planned: 4,
                skipped: 0,
                ran: 4,
                failed: 0
            }
        );
        let text = std::fs::read_to_string(&out).unwrap();
        let rows: Vec<Json> = text
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert_eq!(rows.len(), 4);
        for row in &rows {
            assert_eq!(row.get("ok").unwrap().as_bool(), Some(true));
            assert!(row.get("r").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(row.get("size").unwrap().as_str(), Some(SIM_SIZE));
        }
    }

    #[test]
    fn failing_points_are_isolated_and_rerun_on_resume() {
        let out = tmp("owf_sweep_fail.jsonl");
        let _ = std::fs::remove_file(&out);
        // cbrt-t1 panics inside the power transform (alpha(nu+1) <= 1);
        // the row must record the failure while the good point completes
        let grid = "cbrt-t{1,5}@4:block64-absmax";
        let stats = run_sweep(grid, &opts(out.clone())).unwrap();
        assert_eq!(stats.ran, 2);
        assert_eq!(stats.failed, 1);
        // resume: the failed row is not treated as done
        let mut o = opts(out.clone());
        o.resume = true;
        let again = run_sweep(grid, &o).unwrap();
        assert_eq!(again.skipped, 1);
        assert_eq!(again.ran, 1);
        assert_eq!(again.failed, 1);
    }

    #[test]
    fn seeds_multiply_points() {
        let out = tmp("owf_sweep_seeds.jsonl");
        let _ = std::fs::remove_file(&out);
        let mut o = opts(out.clone());
        o.seeds = 3;
        let stats =
            run_sweep("int@4:block64-absmax", &o).unwrap();
        assert_eq!(stats.planned, 3);
        assert_eq!(stats.ran, 3);
        let text = std::fs::read_to_string(&out).unwrap();
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn row_key_ignores_failed_rows() {
        let ok = Json::obj()
            .push("scheme", "int@4:tensor-rms")
            .push("size", "sim")
            .push("seed", 2usize)
            .push("params", "n4096")
            .push("ok", true);
        assert_eq!(
            row_key(&ok).unwrap(),
            "int@4:tensor-rms|sim|2|n4096"
        );
        let bad = Json::obj()
            .push("scheme", "int@4:tensor-rms")
            .push("size", "sim")
            .push("seed", 2usize)
            .push("params", "n4096")
            .push("ok", false);
        assert!(row_key(&bad).is_none());
        assert!(row_key(&Json::obj()).is_none());
    }

    #[test]
    fn changed_samples_invalidate_the_resume_cache() {
        let out = tmp("owf_sweep_params.jsonl");
        let _ = std::fs::remove_file(&out);
        let grid = "int@4:block64-absmax";
        run_sweep(grid, &opts(out.clone())).unwrap();
        // same grid, different --samples: the old row must NOT satisfy
        // resume (it was computed under different settings)
        let mut o = opts(out.clone());
        o.resume = true;
        o.samples = 1 << 13;
        let stats = run_sweep(grid, &o).unwrap();
        assert_eq!(stats.skipped, 0);
        assert_eq!(stats.ran, 1);
        // and rerunning with the original settings still resumes
        let mut back = opts(out.clone());
        back.resume = true;
        let again = run_sweep(grid, &back).unwrap();
        assert_eq!(again.skipped, 1);
        assert_eq!(again.ran, 0);
    }
}
