//! The experiment coordinator (L3): scheme descriptions, the sweep-grid
//! grammar and engine (`owf sweep`), the job scheduler and the JSONL
//! result store.  The paper's contribution is numeric, so the
//! coordinator is deliberately thin — configuration, fan-out, bookkeeping —
//! with all heavy compute in [`crate::quant`]/[`crate::eval`] (CPU) and the
//! PJRT runtime (model evaluation).

pub mod config;
pub mod results;
pub mod scheduler;
pub mod sweep;

pub use config::{expand_grid, Element, Scheme};
pub use results::{fmt, Report, ResultSink, SweepCache};
pub use scheduler::{run_jobs, run_jobs_with, Job, JobKind, JobResult};
pub use sweep::{run_sweep, SweepData, SweepOpts, SweepStats};
