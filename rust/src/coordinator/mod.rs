//! The experiment coordinator (L3): scheme descriptions, the job scheduler
//! and the JSONL result store.  The paper's contribution is numeric, so the
//! coordinator is deliberately thin — configuration, fan-out, bookkeeping —
//! with all heavy compute in [`crate::quant`]/[`crate::eval`] (CPU) and the
//! PJRT runtime (model evaluation).

pub mod config;
pub mod results;
pub mod scheduler;

pub use config::{Element, Scheme};
pub use results::{fmt, Report, ResultSink};
pub use scheduler::{run_jobs, Job, JobKind, JobResult};
