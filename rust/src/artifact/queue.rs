//! Deadline-aware bounded FIFO decode queue and the deadline-bounded
//! wait primitives the serving layer is built from.
//!
//! [`DecodeQueue`] replaces the shed-only admission gate: up to `permits`
//! decodes run concurrently, up to `depth` requests wait FIFO behind
//! them, and everything past that — or past a request's [`Deadline`] —
//! fails typed immediately.  [`Slot`] is the single-flight rendezvous:
//! the decode owner fills it once, every coalesced waiter shares the
//! outcome, and *no wait on it is unbounded* — waiters poll their
//! deadline every [`POLL_QUANTUM`] so a stalled owner can only hold them
//! until the deadline, and [`FillGuard`] guarantees that an owner which
//! unwinds between registering and filling still wakes every waiter with
//! a typed error instead of leaving them parked forever.
//!
//! Invariants (pinned by `rust/tests/queue_props.rs` under virtual
//! clocks):
//! * **FIFO**: permits are granted strictly in enqueue order — a later
//!   arrival never overtakes an earlier one;
//! * **typed rejection**: a full queue rejects with
//!   [`AcquireError::QueueFull`] without blocking; an expired deadline
//!   rejects with [`AcquireError::DeadlineExceeded`] within one poll
//!   quantum of expiry;
//! * **no permit leak**: an expired waiter removes its ticket and a
//!   dropped [`Permit`] always releases — there is no path (including
//!   panics) that loses a permit;
//! * **no orphaned waiters**: a dropped unfilled [`FillGuard`] fills the
//!   slot with the registered error and wakes everyone.
//!
//! Deadline checks read the injected [`Clock`], but the poll tick itself
//! uses the real condvar timeout: under a virtual clock a waiter parks in
//! ≤ one real quantum per check, so tests stay deterministic in *outcome*
//! (expiry happens exactly when virtual time passes the deadline) while
//! never sleeping unbounded.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::error::ArtifactError;
use super::retry::{Clock, Deadline};

/// How often a deadline-bounded wait re-checks its clock.  Every typed
/// wait in the serving layer resolves within `deadline + POLL_QUANTUM`.
pub const POLL_QUANTUM: Duration = Duration::from_millis(5);

/// Typed admission failure from [`DecodeQueue::acquire`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcquireError {
    /// `depth` requests were already queued when this one arrived.
    QueueFull { depth: usize },
    /// The deadline passed before a permit freed; `waited` is the time
    /// spent queued (zero if the request arrived already expired).
    DeadlineExceeded { waited: Duration },
}

/// A granted decode permit.  Dropping it releases the permit and wakes
/// the queue head — drop-based release means a panicking owner can never
/// leak one.
pub struct Permit<'a> {
    queue: &'a DecodeQueue,
    /// True when the request waited in the FIFO before being granted.
    pub waited: bool,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.queue.release();
    }
}

struct QueueState {
    active: usize,
    next_ticket: u64,
    /// Tickets in arrival order; only the front may take a permit.
    waiting: VecDeque<u64>,
}

/// Bounded FIFO admission: `permits` concurrent holders, `depth` queued
/// waiters, deadline-bounded waiting.  `permits == 0` means unbounded
/// (every acquire grants immediately); `depth == 0` degenerates to the
/// old shed-only gate (an unavailable permit rejects at once).
pub struct DecodeQueue {
    permits: usize,
    depth: usize,
    clock: Arc<dyn Clock>,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl DecodeQueue {
    pub fn new(
        permits: usize,
        depth: usize,
        clock: Arc<dyn Clock>,
    ) -> DecodeQueue {
        DecodeQueue {
            permits,
            depth,
            clock,
            state: Mutex::new(QueueState {
                active: 0,
                next_ticket: 0,
                waiting: VecDeque::new(),
            }),
            cv: Condvar::new(),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn permits(&self) -> usize {
        self.permits
    }

    /// Requests currently parked in the FIFO (test observability).
    pub fn waiting(&self) -> usize {
        self.state.lock().unwrap().waiting.len()
    }

    /// Permits currently held (test observability).
    pub fn active(&self) -> usize {
        self.state.lock().unwrap().active
    }

    /// Acquire a permit, waiting FIFO behind busy ones up to `deadline`.
    ///
    /// Grant rules: unbounded queues (`permits == 0`) grant immediately;
    /// otherwise a request grants at once only when no one is queued
    /// ahead of it and a permit is free.  A request that must wait
    /// rejects typed if the FIFO already holds `depth` tickets or its
    /// deadline has already passed, and while queued it re-checks the
    /// deadline every [`POLL_QUANTUM`].
    pub fn acquire(
        &self,
        deadline: Option<Deadline>,
    ) -> Result<Permit<'_>, AcquireError> {
        let mut st = self.state.lock().unwrap();
        if self.permits == 0 {
            st.active += 1;
            return Ok(Permit {
                queue: self,
                waited: false,
            });
        }
        if st.waiting.is_empty() && st.active < self.permits {
            st.active += 1;
            return Ok(Permit {
                queue: self,
                waited: false,
            });
        }
        if st.waiting.len() >= self.depth {
            return Err(AcquireError::QueueFull { depth: self.depth });
        }
        if let Some(d) = deadline {
            if d.expired(&*self.clock) {
                return Err(AcquireError::DeadlineExceeded {
                    waited: Duration::ZERO,
                });
            }
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.waiting.push_back(ticket);
        let start = self.clock.now();
        loop {
            if st.waiting.front() == Some(&ticket)
                && st.active < self.permits
            {
                st.waiting.pop_front();
                st.active += 1;
                // the new head may also have a free permit already
                self.cv.notify_all();
                return Ok(Permit {
                    queue: self,
                    waited: true,
                });
            }
            if let Some(d) = deadline {
                if d.expired(&*self.clock) {
                    // remove our ticket wherever it sits so the FIFO
                    // never blocks on a ghost and the permit can't leak
                    st.waiting.retain(|&t| t != ticket);
                    self.cv.notify_all();
                    return Err(AcquireError::DeadlineExceeded {
                        waited: self
                            .clock
                            .now()
                            .saturating_sub(start),
                    });
                }
            }
            let (g, _) =
                self.cv.wait_timeout(st, POLL_QUANTUM).unwrap();
            st = g;
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.active = st.active.saturating_sub(1);
        drop(st);
        self.cv.notify_all();
    }
}

/// Outcome of a deadline-bounded wait on a [`Slot`].
#[derive(Debug)]
pub enum WaitOutcome<T> {
    /// The owner filled the slot; the outcome is shared verbatim.
    Filled(Result<T, ArtifactError>),
    /// The deadline passed before the owner filled the slot.
    DeadlineExceeded { waited: Duration },
}

/// Single-flight rendezvous: the owner fills once, waiters share the
/// outcome.  All waits are deadline-bounded polls — there is no untimed
/// condvar wait left in the serving layer.
pub struct Slot<T: Clone> {
    result: Mutex<Option<Result<T, ArtifactError>>>,
    cv: Condvar,
}

impl<T: Clone> Default for Slot<T> {
    fn default() -> Self {
        Slot::new()
    }
}

impl<T: Clone> Slot<T> {
    pub fn new() -> Slot<T> {
        Slot {
            result: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    /// Fill the slot and wake every waiter.  First fill wins: a second
    /// fill (e.g. the owner's normal outcome racing its own drop guard)
    /// is ignored, so waiters observe exactly one outcome.
    pub fn fill(&self, outcome: Result<T, ArtifactError>) {
        let mut r = self.result.lock().unwrap();
        if r.is_none() {
            *r = Some(outcome);
        }
        drop(r);
        self.cv.notify_all();
    }

    pub fn is_filled(&self) -> bool {
        self.result.lock().unwrap().is_some()
    }

    /// Wait for the owner's outcome, bounded by `deadline` on `clock`.
    /// With no deadline the wait still polls (never untimed), relying on
    /// the owner's [`FillGuard`] to guarantee an eventual fill.
    pub fn wait_deadline(
        &self,
        clock: &dyn Clock,
        deadline: Option<Deadline>,
    ) -> WaitOutcome<T> {
        let start = clock.now();
        let mut r = self.result.lock().unwrap();
        loop {
            if let Some(outcome) = r.as_ref() {
                return WaitOutcome::Filled(outcome.clone());
            }
            if let Some(d) = deadline {
                if d.expired(clock) {
                    return WaitOutcome::DeadlineExceeded {
                        waited: clock.now().saturating_sub(start),
                    };
                }
            }
            let (g, _) =
                self.cv.wait_timeout(r, POLL_QUANTUM).unwrap();
            r = g;
        }
    }
}

/// Owner-side unwind protection: between registering a slot and filling
/// it, any panic/unwind must still wake the waiters.  Create the guard
/// right after registration; `fill` through it on the normal path.  If
/// the guard drops unfilled (the owner unwound), it fills the slot with
/// the registered fallback error so no waiter can hang on a dead owner.
pub struct FillGuard<'a, T: Clone> {
    slot: &'a Slot<T>,
    fallback: Option<ArtifactError>,
}

impl<'a, T: Clone> FillGuard<'a, T> {
    pub fn new(slot: &'a Slot<T>, fallback: ArtifactError) -> Self {
        FillGuard {
            slot,
            fallback: Some(fallback),
        }
    }

    /// Normal-path fill: disarms the guard.
    pub fn fill(mut self, outcome: Result<T, ArtifactError>) {
        self.fallback = None;
        self.slot.fill(outcome);
    }
}

impl<T: Clone> Drop for FillGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(err) = self.fallback.take() {
            self.slot.fill(Err(err));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::retry::RecordingClock;

    fn clock() -> Arc<RecordingClock> {
        Arc::new(RecordingClock::new())
    }

    #[test]
    fn unbounded_queue_always_grants() {
        let q = DecodeQueue::new(0, 0, clock());
        let a = q.acquire(None).unwrap();
        let b = q.acquire(None).unwrap();
        assert!(!a.waited && !b.waited);
        assert_eq!(q.active(), 2);
        drop(a);
        drop(b);
        assert_eq!(q.active(), 0);
    }

    #[test]
    fn depth_zero_rejects_like_the_old_gate() {
        let q = DecodeQueue::new(1, 0, clock());
        let held = q.acquire(None).unwrap();
        assert_eq!(
            q.acquire(None).unwrap_err(),
            AcquireError::QueueFull { depth: 0 }
        );
        drop(held);
        assert!(q.acquire(None).is_ok(), "released permit grants again");
    }

    #[test]
    fn already_expired_deadline_rejects_before_enqueue() {
        let c = clock();
        let q = DecodeQueue::new(1, 4, c.clone());
        let _held = q.acquire(None).unwrap();
        let d = Deadline::after(&*c, Duration::ZERO);
        match q.acquire(Some(d)).unwrap_err() {
            AcquireError::DeadlineExceeded { waited } => {
                assert_eq!(waited, Duration::ZERO)
            }
            other => panic!("expected deadline, got {other:?}"),
        }
        assert_eq!(q.waiting(), 0, "expired request never enqueued");
    }

    #[test]
    fn permit_drop_releases_even_under_panic() {
        let q = Arc::new(DecodeQueue::new(1, 0, clock()));
        let q2 = q.clone();
        let res =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                move || {
                    let _p = q2.acquire(None).unwrap();
                    panic!("owner dies holding the permit");
                },
            ));
        assert!(res.is_err());
        assert_eq!(q.active(), 0, "unwound owner released its permit");
        assert!(q.acquire(None).is_ok());
    }

    #[test]
    fn slot_fill_is_first_write_wins() {
        let s: Slot<u32> = Slot::new();
        s.fill(Ok(7));
        s.fill(Ok(8));
        let c = clock();
        match s.wait_deadline(&*c, None) {
            WaitOutcome::Filled(Ok(v)) => assert_eq!(v, 7),
            other => panic!("expected first fill, got {other:?}"),
        }
    }

    #[test]
    fn fill_guard_fallback_fires_only_when_unfilled() {
        let s: Slot<u32> = Slot::new();
        {
            let g = FillGuard::new(
                &s,
                ArtifactError::corrupt("t", "decode", "unwound"),
            );
            g.fill(Ok(3));
        }
        assert!(matches!(
            s.wait_deadline(&*clock(), None),
            WaitOutcome::Filled(Ok(3))
        ));
        let s2: Slot<u32> = Slot::new();
        {
            let _g = FillGuard::new(
                &s2,
                ArtifactError::corrupt("t", "decode", "unwound"),
            );
            // dropped unfilled
        }
        match s2.wait_deadline(&*clock(), None) {
            WaitOutcome::Filled(Err(e)) => assert!(e.is_corrupt()),
            other => panic!("expected fallback error, got {other:?}"),
        }
    }
}
