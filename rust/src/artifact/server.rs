//! Concurrent serving front-end over an [`Artifact`]: thread-safe decode
//! requests, an LRU decoded-tensor cache, single-flight decode
//! coalescing, a corruption quarantine, a deadline-aware bounded decode
//! queue and a slow-decode watchdog with per-tensor circuit breakers —
//! the piece `owf serve-bench` drives and `owf quantise --from` feeds
//! into the KL evaluation harness.  The server is scheme-agnostic: `:rot`
//! and `grid` tensors (container v2) flow through the same
//! [`Artifact::decode_tensor_into`] path — inverse rotation and the grid
//! gather happen inside the artifact decode, so caching, coalescing and
//! quarantine need no per-scheme handling.
//!
//! Concurrency model: the artifact itself is immutable, so decodes run
//! in parallel outside the lock; one mutex guards the cache map, the
//! in-flight table, the quarantine map and the breaker map, held only
//! for map operations (never across a decode).  Decode permits live in a
//! separate [`DecodeQueue`] with its own lock, so a request parked in
//! the queue never blocks cache hits.
//!
//! **Single-flight**: concurrent cold misses on one tensor coalesce onto
//! a single decode.  The first requester registers an in-flight slot and
//! decodes; later requesters wait on the slot and share the resulting
//! `Arc` (or the owner's error, verbatim).  N threads missing on a cold
//! tensor perform exactly one decode — enforced by
//! `rust/tests/server_props.rs` via `misses`/`decoded_bytes`.
//!
//! **Deadlines — no unbounded wait**: requests may carry a [`Deadline`]
//! (an absolute instant on the artifact's injected [`Clock`]).  Both the
//! decode queue and the coalescing slot wait are deadline-bounded polls
//! ([`queue::POLL_QUANTUM`]): a request whose deadline passes while
//! queued resolves [`ArtifactError::DeadlineExceeded`] without leaking
//! its queue ticket, and one whose deadline passes while waiting on a
//! stalled owner resolves the same way within one quantum.  An owner
//! that *unwinds* between registering its slot and filling it trips a
//! drop guard that fills the slot with a typed `Corrupt`, so waiters
//! without deadlines still never hang on a dead owner.
//!
//! **Queue + admission**: `with_max_decodes(n)` bounds concurrent
//! decodes; `with_queue_depth(d)` lets up to `d` requests wait FIFO for
//! a permit instead of being shed.  With `d == 0` (the default) the
//! behaviour degenerates to the PR 6 gate: excess load is rejected with
//! a typed [`ArtifactError::Overloaded`].  With `d > 0`, the `d+1`-th
//! waiter is rejected with [`ArtifactError::QueueFull`].  Coalesced
//! waiters hold no permit and occupy no queue slot.
//!
//! **Watchdog + circuit breaker**: with `with_slow_budget(b)`, a decode
//! taking longer than `b` (on the injected clock — a retry backoff
//! counts) increments `slow_decodes`, logs the tensor, and strikes it.
//! `threshold` consecutive slow decodes open the tensor's breaker: new
//! *cold* requests shed fast with [`ArtifactError::BreakerOpen`] while
//! cached copies keep serving (the same graceful-degradation contract as
//! quarantine).  After `cooldown`, exactly one request is admitted as a
//! half-open probe: a fast probe closes the breaker, a slow one re-opens
//! it.
//!
//! **Quarantine**: a decode that fails with [`ArtifactError::Corrupt`]
//! poisons the tensor; subsequent requests fail fast with
//! [`ArtifactError::Quarantined`] carrying the original cause, without
//! re-decoding damaged bytes.  Clean tensors — including still-cached
//! copies — keep serving.  Transient I/O is the artifact layer's job: it
//! retries with backoff and never quarantines.
//!
//! Cache invariants (also in `EXPERIMENTS.md` §Artifact / §Serving):
//! * resident bytes never exceed `cap_bytes` plus the most recently
//!   inserted tensor (which is always kept, even alone over cap);
//! * eviction is strict LRU by request stamp, and the stamp clock
//!   advances **only** on a cache hit or insert — requests that
//!   coalesce, shed or fail leave the clock untouched, so stamps stay
//!   dense and auditable ([`ArtifactServer::cache_audit`] asserts
//!   uniqueness and the clock bound);
//! * `cap_bytes == 0` disables caching (every served buffer comes from a
//!   decode, though concurrent requests still coalesce onto one).
//!
//! Stats partition (once every request has resolved):
//!
//! ```text
//! requests == hits + misses
//!           + coalesced_errors + quarantine_hits
//!           + overloads + queue_full
//!           + deadline_exceeded_queued + deadline_exceeded_waiting
//!           + breaker_open + not_found
//! ```
//!
//! On the fault-free unbounded path this collapses to the PR 5 identity
//! `hits + misses == requests`.  `queued`, `slow_decodes` and
//! `breaker_probes` are sub-counts of requests that went on to resolve
//! through another leg, not partition legs themselves.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::queue::{AcquireError, DecodeQueue, Permit, Slot, WaitOutcome};
use super::retry::{Clock, Deadline};
use super::{AResult, Artifact, ArtifactError};

type DecodeSlot = Slot<Arc<Vec<f32>>>;

struct CacheEntry {
    data: Arc<Vec<f32>>,
    last_used: u64,
}

#[derive(Default)]
struct Cache {
    entries: HashMap<String, CacheEntry>,
    clock: u64,
    bytes: usize,
}

/// Per-tensor circuit-breaker state (driven by the slow-decode watchdog).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Breaker {
    /// Serving normally; `strikes` consecutive slow decodes so far.
    Closed { strikes: u32 },
    /// Shedding new cold decodes since `since` (clock timeline).
    Open { since: Duration },
    /// One probe decode is in flight; its outcome closes or re-opens.
    HalfOpen,
}

#[derive(Default)]
struct ServerState {
    cache: Cache,
    inflight: HashMap<String, Arc<DecodeSlot>>,
    quarantine: HashMap<String, ArtifactError>,
    breakers: HashMap<String, Breaker>,
}

/// A point-in-time view of the server counters.
#[derive(Clone, Copy, Debug)]
pub struct ServerStats {
    pub requests: u64,
    /// Requests served without this thread decoding: cache hits plus
    /// coalesced waits that received the owner's buffer.
    pub hits: u64,
    /// Decodes this server performed (successful or not).
    pub misses: u64,
    pub evictions: u64,
    /// Bytes produced by successful decodes (4·elements each).
    pub decoded_bytes: u64,
    /// Requests that attached to another thread's in-flight decode.
    pub coalesced: u64,
    /// Coalesced waits that inherited the owner's error.
    pub coalesced_errors: u64,
    /// Own decodes that returned an error.
    pub decode_errors: u64,
    /// Requests rejected fast because the tensor was quarantined.
    pub quarantine_hits: u64,
    /// Requests shed because permits were busy and `queue_depth == 0`.
    pub overloads: u64,
    /// Requests rejected because the wait queue was at capacity.
    pub queue_full: u64,
    /// Requests that waited in the decode queue before being granted.
    pub queued: u64,
    /// Requests whose deadline expired while queued for a permit.
    pub deadline_exceeded_queued: u64,
    /// Requests whose deadline expired waiting on a coalesced decode.
    pub deadline_exceeded_waiting: u64,
    /// Decodes that exceeded the slow budget (watchdog).
    pub slow_decodes: u64,
    /// Requests shed by an open circuit breaker.
    pub breaker_open: u64,
    /// Half-open probe decodes admitted.
    pub breaker_probes: u64,
    /// Requests for names not in the manifest.
    pub not_found: u64,
    /// Transient I/O retries performed by the artifact layer.
    pub io_retries: u64,
    /// Tensors currently poisoned in the quarantine map.
    pub quarantined: usize,
    /// Tensors whose breaker is currently open or half-open.
    pub breakers_open: usize,
    pub cached_tensors: usize,
    pub cached_bytes: usize,
}

impl ServerStats {
    /// The resolved-request partition: every request lands in exactly
    /// one leg.  Holds once all requests have resolved.
    pub fn partition_closed(&self) -> bool {
        self.hits
            + self.misses
            + self.coalesced_errors
            + self.quarantine_hits
            + self.overloads
            + self.queue_full
            + self.deadline_exceeded_queued
            + self.deadline_exceeded_waiting
            + self.breaker_open
            + self.not_found
            == self.requests
    }
}

/// Thread-safe serving reader: LRU cache + single-flight + quarantine +
/// deadline-aware decode queue + slow-decode watchdog.
pub struct ArtifactServer {
    artifact: Artifact,
    cap_bytes: usize,
    /// Max concurrent decodes; 0 = unbounded.
    max_decodes: usize,
    /// Requests allowed to wait for a permit; 0 = shed immediately.
    queue_depth: usize,
    /// Decodes slower than this strike their tensor; zero disables the
    /// watchdog (and thus the breaker).
    slow_budget: Duration,
    /// Consecutive slow decodes that open a tensor's breaker.
    breaker_threshold: u32,
    /// Open duration before a half-open probe is admitted.
    breaker_cooldown: Duration,
    clock: Arc<dyn Clock>,
    queue: DecodeQueue,
    state: Mutex<ServerState>,
    requests: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    decoded_bytes: AtomicU64,
    coalesced: AtomicU64,
    coalesced_errors: AtomicU64,
    decode_errors: AtomicU64,
    quarantine_hits: AtomicU64,
    overloads: AtomicU64,
    queue_full: AtomicU64,
    queued: AtomicU64,
    deadline_exceeded_queued: AtomicU64,
    deadline_exceeded_waiting: AtomicU64,
    slow_decodes: AtomicU64,
    breaker_open: AtomicU64,
    breaker_probes: AtomicU64,
    not_found: AtomicU64,
}

/// Drop guard held by a decode owner from slot registration to outcome
/// publication.  If the owner unwinds in between, `Drop` removes the
/// inflight entry, fails a half-open probe back to `Open`, and fills the
/// slot with a typed `Corrupt` so every waiter wakes instead of hanging
/// on a dead owner.
struct OwnerGuard<'a> {
    server: &'a ArtifactServer,
    name: String,
    slot: Arc<DecodeSlot>,
    is_probe: bool,
    armed: bool,
}

impl<'a> OwnerGuard<'a> {
    fn new(
        server: &'a ArtifactServer,
        name: &str,
        slot: Arc<DecodeSlot>,
        is_probe: bool,
    ) -> Self {
        OwnerGuard {
            server,
            name: name.to_string(),
            slot,
            is_probe,
            armed: true,
        }
    }

    /// Normal completion: publish to cache/quarantine, feed the
    /// watchdog, then wake every waiter with the outcome.
    fn finish(
        mut self,
        outcome: &AResult<Arc<Vec<f32>>>,
        elapsed: Duration,
    ) {
        self.armed = false;
        let mut st = self.server.state.lock().unwrap();
        st.inflight.remove(&self.name);
        match outcome {
            Ok(data) => {
                if self.server.cap_bytes > 0 {
                    self.server.cache_insert(
                        &mut st.cache,
                        &self.name,
                        data.clone(),
                    );
                }
            }
            Err(e) => {
                if e.is_corrupt() {
                    st.quarantine
                        .insert(self.name.clone(), e.clone());
                }
            }
        }
        self.server
            .watchdog_note(&mut st, &self.name, elapsed, self.is_probe);
        drop(st);
        self.slot.fill(outcome.clone());
    }
}

impl Drop for OwnerGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut st = match self.server.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.inflight.remove(&self.name);
        if self.is_probe {
            st.breakers.insert(
                self.name.clone(),
                Breaker::Open {
                    since: self.server.clock.now(),
                },
            );
        }
        drop(st);
        self.slot.fill(Err(ArtifactError::corrupt(
            &self.name,
            "decode",
            "decode owner panicked before publishing an outcome",
        )));
    }
}

/// What the breaker says about admitting a new cold decode.
enum BreakerVerdict {
    /// Proceed; not a probe.
    Admit,
    /// Proceed as the single half-open probe (only returned when the
    /// caller holds a permit and may commit).
    Probe,
    /// Shed with `BreakerOpen`.
    Shed,
}

impl ArtifactServer {
    pub fn new(artifact: Artifact, cap_bytes: usize) -> ArtifactServer {
        let clock = artifact.clock();
        ArtifactServer {
            queue: DecodeQueue::new(0, 0, clock.clone()),
            artifact,
            cap_bytes,
            max_decodes: 0,
            queue_depth: 0,
            slow_budget: Duration::ZERO,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            clock,
            state: Mutex::new(ServerState::default()),
            requests: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            decoded_bytes: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            coalesced_errors: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            quarantine_hits: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
            queue_full: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            deadline_exceeded_queued: AtomicU64::new(0),
            deadline_exceeded_waiting: AtomicU64::new(0),
            slow_decodes: AtomicU64::new(0),
            breaker_open: AtomicU64::new(0),
            breaker_probes: AtomicU64::new(0),
            not_found: AtomicU64::new(0),
        }
    }

    /// Bound concurrent decodes.  With `queue_depth == 0` the
    /// `n+1`-th simultaneous cold decode is rejected with a typed
    /// [`ArtifactError::Overloaded`]; with a queue, it waits FIFO.
    /// `0` (the default) leaves admission unbounded.
    pub fn with_max_decodes(mut self, n: usize) -> ArtifactServer {
        self.max_decodes = n;
        self.rebuild_queue();
        self
    }

    /// Let up to `depth` requests wait FIFO for a decode permit instead
    /// of being shed; the `depth+1`-th is rejected with a typed
    /// [`ArtifactError::QueueFull`].  `0` (the default) sheds
    /// immediately (the PR 6 gate behaviour).
    pub fn with_queue_depth(mut self, depth: usize) -> ArtifactServer {
        self.queue_depth = depth;
        self.rebuild_queue();
        self
    }

    /// Arm the slow-decode watchdog: decodes slower than `budget` (on
    /// the injected clock) count as strikes toward the tensor's circuit
    /// breaker.  `Duration::ZERO` (the default) disables both.
    pub fn with_slow_budget(mut self, budget: Duration) -> ArtifactServer {
        self.slow_budget = budget;
        self
    }

    /// Breaker tuning: `threshold` consecutive slow decodes open a
    /// tensor's breaker; after `cooldown` one probe is admitted.
    pub fn with_breaker(
        mut self,
        threshold: u32,
        cooldown: Duration,
    ) -> ArtifactServer {
        self.breaker_threshold = threshold.max(1);
        self.breaker_cooldown = cooldown;
        self
    }

    fn rebuild_queue(&mut self) {
        self.queue = DecodeQueue::new(
            self.max_decodes,
            self.queue_depth,
            self.clock.clone(),
        );
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// The server's time source (the artifact's injected clock) — mint
    /// [`Deadline`]s against this.
    pub fn clock(&self) -> Arc<dyn Clock> {
        self.clock.clone()
    }

    /// The admission queue (test observability: a test can wait until a
    /// request is provably parked in the FIFO before advancing a
    /// virtual clock).
    pub fn decode_queue(&self) -> &DecodeQueue {
        &self.queue
    }

    /// Serve one tensor with no deadline (waits are still bounded by the
    /// owner's drop guard — see [`ArtifactServer::get_deadline`]).
    pub fn get(&self, name: &str) -> AResult<Arc<Vec<f32>>> {
        self.get_deadline(name, None)
    }

    /// Serve one tensor.  Quarantined names fail fast with the recorded
    /// cause; a cache hit returns the shared buffer; a miss either
    /// attaches to an in-flight decode of the same tensor (sharing its
    /// outcome, bounded by `deadline`) or acquires a decode permit —
    /// waiting FIFO up to `deadline` if permits are busy — and decodes
    /// outside the lock, fills the cache and wakes every waiter.
    pub fn get_deadline(
        &self,
        name: &str,
        deadline: Option<Deadline>,
    ) -> AResult<Arc<Vec<f32>>> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let t_start = self.clock.now();
        let Some(i) = self.artifact.position(name) else {
            self.not_found.fetch_add(1, Ordering::Relaxed);
            return Err(ArtifactError::NotFound {
                tensor: name.to_string(),
            });
        };
        // Admission loop: runs at most twice — once without a permit
        // (terminal paths: quarantine/hit/coalesce/shed, or fall through
        // to acquire one) and once holding it (the permit-held pass
        // re-checks everything, since the world may have changed while
        // we queued, then registers the in-flight slot).
        let mut permit: Option<Permit<'_>> = None;
        let (slot, is_probe) = loop {
            let mut st = self.state.lock().unwrap();
            if let Some(cause) = st.quarantine.get(name) {
                self.quarantine_hits.fetch_add(1, Ordering::Relaxed);
                return Err(ArtifactError::Quarantined {
                    tensor: name.to_string(),
                    cause: Box::new(cause.clone()),
                });
            }
            if self.cap_bytes > 0 && st.cache.entries.contains_key(name)
            {
                // the stamp clock moves only on hit/insert so LRU
                // stamps stay dense (see cache_audit)
                st.cache.clock += 1;
                let now = st.cache.clock;
                let e = st.cache.entries.get_mut(name).unwrap();
                e.last_used = now;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(e.data.clone());
            }
            if let Some(existing) = st.inflight.get(name) {
                // coalesce: counted at attach (before the wait) so tests
                // can observe waiters deterministically
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                let slot = existing.clone();
                drop(st);
                // never wait on another owner while holding a permit
                drop(permit);
                return self.share(&slot, name, deadline, t_start);
            }
            match self.breaker_gate(&mut st, name, permit.is_some()) {
                BreakerVerdict::Shed => {
                    self.breaker_open.fetch_add(1, Ordering::Relaxed);
                    return Err(ArtifactError::BreakerOpen {
                        tensor: name.to_string(),
                    });
                }
                BreakerVerdict::Probe => {
                    let slot = Arc::new(DecodeSlot::new());
                    st.inflight.insert(name.to_string(), slot.clone());
                    break (slot, true);
                }
                BreakerVerdict::Admit => {
                    if permit.is_some() {
                        let slot = Arc::new(DecodeSlot::new());
                        st.inflight
                            .insert(name.to_string(), slot.clone());
                        break (slot, false);
                    }
                }
            }
            drop(st);
            permit = Some(self.acquire_permit(name, deadline, t_start)?);
        };

        // own decode, outside every lock; `permit` (if bounded) is held
        // for the duration and released by Drop even on unwind
        self.misses.fetch_add(1, Ordering::Relaxed);
        if is_probe {
            self.breaker_probes.fetch_add(1, Ordering::Relaxed);
        }
        let guard = OwnerGuard::new(self, name, slot, is_probe);
        let t_decode = self.clock.now();
        let outcome = match self.artifact.decode_tensor(i) {
            Ok(data) => {
                let data = Arc::new(data);
                self.decoded_bytes
                    .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
                Ok(data)
            }
            Err(e) => {
                self.decode_errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        };
        let elapsed = self.clock.now().saturating_sub(t_decode);
        guard.finish(&outcome, elapsed);
        drop(permit);
        outcome
    }

    /// Wait (deadline-bounded) on another owner's slot and account the
    /// outcome.
    fn share(
        &self,
        slot: &DecodeSlot,
        name: &str,
        deadline: Option<Deadline>,
        t_start: Duration,
    ) -> AResult<Arc<Vec<f32>>> {
        match slot.wait_deadline(&*self.clock, deadline) {
            WaitOutcome::Filled(Ok(data)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Ok(data)
            }
            WaitOutcome::Filled(Err(e)) => {
                self.coalesced_errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
            WaitOutcome::DeadlineExceeded { .. } => {
                self.deadline_exceeded_waiting
                    .fetch_add(1, Ordering::Relaxed);
                Err(ArtifactError::DeadlineExceeded {
                    tensor: name.to_string(),
                    waited_ms: self
                        .clock
                        .now()
                        .saturating_sub(t_start)
                        .as_millis() as u64,
                })
            }
        }
    }

    /// Acquire a decode permit through the queue, mapping the typed
    /// rejections onto server errors and counters.
    fn acquire_permit(
        &self,
        name: &str,
        deadline: Option<Deadline>,
        t_start: Duration,
    ) -> AResult<Permit<'_>> {
        match self.queue.acquire(deadline) {
            Ok(p) => {
                if p.waited {
                    self.queued.fetch_add(1, Ordering::Relaxed);
                }
                Ok(p)
            }
            Err(AcquireError::QueueFull { depth }) => {
                if self.queue_depth == 0 {
                    // no queueing configured: the legacy shed gate
                    self.overloads.fetch_add(1, Ordering::Relaxed);
                    Err(ArtifactError::Overloaded {
                        limit: self.max_decodes,
                    })
                } else {
                    self.queue_full.fetch_add(1, Ordering::Relaxed);
                    Err(ArtifactError::QueueFull { depth })
                }
            }
            Err(AcquireError::DeadlineExceeded { .. }) => {
                self.deadline_exceeded_queued
                    .fetch_add(1, Ordering::Relaxed);
                Err(ArtifactError::DeadlineExceeded {
                    tensor: name.to_string(),
                    waited_ms: self
                        .clock
                        .now()
                        .saturating_sub(t_start)
                        .as_millis() as u64,
                })
            }
        }
    }

    /// Should a new cold decode of `name` proceed?  `commit` is true
    /// when the caller holds a permit and may take the half-open probe
    /// slot; without it an open-but-cooled breaker reports `Admit` and
    /// the transition happens on the permit-held pass.
    fn breaker_gate(
        &self,
        st: &mut ServerState,
        name: &str,
        commit: bool,
    ) -> BreakerVerdict {
        if self.slow_budget.is_zero() {
            return BreakerVerdict::Admit;
        }
        match st.breakers.get(name).copied() {
            None | Some(Breaker::Closed { .. }) => BreakerVerdict::Admit,
            Some(Breaker::HalfOpen) => BreakerVerdict::Shed,
            Some(Breaker::Open { since }) => {
                let cooled = self
                    .clock
                    .now()
                    .saturating_sub(since)
                    >= self.breaker_cooldown;
                if !cooled {
                    BreakerVerdict::Shed
                } else if commit {
                    st.breakers
                        .insert(name.to_string(), Breaker::HalfOpen);
                    BreakerVerdict::Probe
                } else {
                    BreakerVerdict::Admit
                }
            }
        }
    }

    /// Watchdog bookkeeping after an own decode: strike or reset the
    /// tensor's breaker, resolve a half-open probe.
    fn watchdog_note(
        &self,
        st: &mut ServerState,
        name: &str,
        elapsed: Duration,
        is_probe: bool,
    ) {
        if self.slow_budget.is_zero() {
            return;
        }
        let slow = elapsed > self.slow_budget;
        if slow {
            self.slow_decodes.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "[artifact-server] slow decode: {name:?} took {}ms \
                 (budget {}ms)",
                elapsed.as_millis(),
                self.slow_budget.as_millis(),
            );
        }
        let cur = st
            .breakers
            .get(name)
            .copied()
            .unwrap_or(Breaker::Closed { strikes: 0 });
        let next = match cur {
            Breaker::Closed { strikes } => {
                if !slow {
                    Breaker::Closed { strikes: 0 }
                } else if strikes + 1 >= self.breaker_threshold {
                    eprintln!(
                        "[artifact-server] circuit breaker OPEN for \
                         {name:?} after {} consecutive slow decodes",
                        strikes + 1,
                    );
                    Breaker::Open {
                        since: self.clock.now(),
                    }
                } else {
                    Breaker::Closed {
                        strikes: strikes + 1,
                    }
                }
            }
            Breaker::HalfOpen if is_probe => {
                if slow {
                    Breaker::Open {
                        since: self.clock.now(),
                    }
                } else {
                    Breaker::Closed { strikes: 0 }
                }
            }
            // a non-probe decode finishing while the breaker moved
            // under it (e.g. admitted before the trip): leave the state
            other => other,
        };
        st.breakers.insert(name.to_string(), next);
    }

    /// Insert under the state lock, then strict-LRU evict down to cap.
    /// Single-flight guarantees no concurrent insert of the same name.
    fn cache_insert(
        &self,
        cache: &mut Cache,
        name: &str,
        data: Arc<Vec<f32>>,
    ) {
        cache.clock += 1;
        let now = cache.clock;
        cache.bytes += 4 * data.len();
        cache.entries.insert(
            name.to_string(),
            CacheEntry {
                data,
                last_used: now,
            },
        );
        // the entry just inserted is `now` and is never selected while
        // anything older remains
        while cache.bytes > self.cap_bytes && cache.entries.len() > 1 {
            let victim = cache
                .entries
                .iter()
                .filter(|(_, e)| e.last_used != now)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            if let Some(e) = cache.entries.remove(&victim) {
                cache.bytes -= 4 * e.data.len();
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Cache-bypassing decode into a caller-owned buffer (the zero-copy
    /// serving path).  Counted as a request + miss; respects the
    /// quarantine, the queue/deadline admission and the circuit breaker,
    /// and quarantines on corruption, exactly like
    /// [`ArtifactServer::get`] — but never coalesces (the caller owns
    /// the output buffer, so there is nothing to share).
    pub fn decode_into(&self, name: &str, out: &mut [f32]) -> AResult<()> {
        self.decode_into_deadline(name, out, None)
    }

    /// [`ArtifactServer::decode_into`] with a deadline bounding any time
    /// spent queued for a decode permit.
    pub fn decode_into_deadline(
        &self,
        name: &str,
        out: &mut [f32],
        deadline: Option<Deadline>,
    ) -> AResult<()> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let t_start = self.clock.now();
        let Some(i) = self.artifact.position(name) else {
            self.not_found.fetch_add(1, Ordering::Relaxed);
            return Err(ArtifactError::NotFound {
                tensor: name.to_string(),
            });
        };
        let mut permit: Option<Permit<'_>> = None;
        let is_probe = loop {
            let mut st = self.state.lock().unwrap();
            if let Some(cause) = st.quarantine.get(name) {
                self.quarantine_hits.fetch_add(1, Ordering::Relaxed);
                return Err(ArtifactError::Quarantined {
                    tensor: name.to_string(),
                    cause: Box::new(cause.clone()),
                });
            }
            match self.breaker_gate(&mut st, name, permit.is_some()) {
                BreakerVerdict::Shed => {
                    self.breaker_open.fetch_add(1, Ordering::Relaxed);
                    return Err(ArtifactError::BreakerOpen {
                        tensor: name.to_string(),
                    });
                }
                BreakerVerdict::Probe => break true,
                BreakerVerdict::Admit => {
                    if permit.is_some() {
                        break false;
                    }
                }
            }
            drop(st);
            permit = Some(self.acquire_permit(name, deadline, t_start)?);
        };
        self.misses.fetch_add(1, Ordering::Relaxed);
        if is_probe {
            self.breaker_probes.fetch_add(1, Ordering::Relaxed);
        }
        let t_decode = self.clock.now();
        let result = self.artifact.decode_tensor_into(i, out);
        let elapsed = self.clock.now().saturating_sub(t_decode);
        let mut st = self.state.lock().unwrap();
        match &result {
            Ok(()) => {
                self.decoded_bytes
                    .fetch_add(4 * out.len() as u64, Ordering::Relaxed);
            }
            Err(e) => {
                self.decode_errors.fetch_add(1, Ordering::Relaxed);
                if e.is_corrupt() {
                    st.quarantine.insert(name.to_string(), e.clone());
                }
            }
        }
        self.watchdog_note(&mut st, name, elapsed, is_probe);
        drop(st);
        drop(permit);
        result
    }

    /// Decode every tensor into a name → values map — the adapter that
    /// lets the LLM evaluation harness ([`crate::eval::llm::Env::evaluate`])
    /// score a packed artifact exactly like an in-memory quantisation.
    /// Routes through [`ArtifactServer::get`], so quarantine, the
    /// breaker, the admission queue and the stats counters all apply —
    /// a quarantined tensor fails the whole map typed instead of
    /// re-decoding damaged bytes.
    pub fn params(&self) -> AResult<HashMap<String, Vec<f32>>> {
        let mut out = HashMap::new();
        for rec in &self.artifact.tensors {
            let data = self.get(&rec.name)?;
            // sole owner when the cache is disabled; otherwise copy out
            // of the shared entry
            let values = Arc::try_unwrap(data)
                .unwrap_or_else(|shared| (*shared).clone());
            out.insert(rec.name.clone(), values);
        }
        Ok(out)
    }

    /// Drop every cached tensor (bench/ops tool: forces the next round of
    /// requests cold).  Quarantine, in-flight decodes and counters are
    /// untouched; the drops are not counted as evictions.
    pub fn clear_cache(&self) {
        let mut st = self.state.lock().unwrap();
        st.cache.entries.clear();
        st.cache.bytes = 0;
    }

    /// Lift a tensor's quarantine (ops tool — e.g. after `owf fsck`
    /// verified a repaired container).  Returns the recorded cause.
    pub fn clear_quarantine(&self, name: &str) -> Option<ArtifactError> {
        self.state.lock().unwrap().quarantine.remove(name)
    }

    /// Reset a tensor's circuit breaker to closed (ops override, the
    /// breaker analogue of [`ArtifactServer::clear_quarantine`]).
    /// Returns true if a breaker state existed.
    pub fn clear_breaker(&self, name: &str) -> bool {
        self.state
            .lock()
            .unwrap()
            .breakers
            .remove(name)
            .is_some()
    }

    /// Recompute cache occupancy from the entries themselves — test
    /// support for proving the incremental `cached_bytes` accounting
    /// exact under racing insert/evict.  Also asserts the LRU stamp
    /// invariants: stamps are unique (strict LRU is well-defined) and
    /// never exceed the stamp clock.
    pub fn cache_audit(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        let mut stamps: Vec<u64> = st
            .cache
            .entries
            .values()
            .map(|e| e.last_used)
            .collect();
        stamps.sort_unstable();
        for w in stamps.windows(2) {
            assert!(
                w[0] < w[1],
                "cache stamps must be unique for strict LRU"
            );
        }
        if let Some(&newest) = stamps.last() {
            assert!(
                newest <= st.cache.clock,
                "cache stamp {newest} beyond clock {}",
                st.cache.clock
            );
        }
        let bytes: usize = st
            .cache
            .entries
            .values()
            .map(|e| 4 * e.data.len())
            .sum();
        (st.cache.entries.len(), bytes)
    }

    /// Current LRU stamp clock (test support: the clock must advance
    /// only on cache hits and inserts, never on coalesced/shed/failed
    /// requests).
    pub fn cache_clock(&self) -> u64 {
        self.state.lock().unwrap().cache.clock
    }

    pub fn stats(&self) -> ServerStats {
        let (cached_tensors, cached_bytes, quarantined, breakers_open) = {
            let st = self.state.lock().unwrap();
            (
                st.cache.entries.len(),
                st.cache.bytes,
                st.quarantine.len(),
                st.breakers
                    .values()
                    .filter(|b| {
                        matches!(
                            b,
                            Breaker::Open { .. } | Breaker::HalfOpen
                        )
                    })
                    .count(),
            )
        };
        ServerStats {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            decoded_bytes: self.decoded_bytes.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            coalesced_errors: self.coalesced_errors.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            quarantine_hits: self.quarantine_hits.load(Ordering::Relaxed),
            overloads: self.overloads.load(Ordering::Relaxed),
            queue_full: self.queue_full.load(Ordering::Relaxed),
            queued: self.queued.load(Ordering::Relaxed),
            deadline_exceeded_queued: self
                .deadline_exceeded_queued
                .load(Ordering::Relaxed),
            deadline_exceeded_waiting: self
                .deadline_exceeded_waiting
                .load(Ordering::Relaxed),
            slow_decodes: self.slow_decodes.load(Ordering::Relaxed),
            breaker_open: self.breaker_open.load(Ordering::Relaxed),
            breaker_probes: self.breaker_probes.load(Ordering::Relaxed),
            not_found: self.not_found.load(Ordering::Relaxed),
            io_retries: self.artifact.io_retries(),
            quarantined,
            breakers_open,
            cached_tensors,
            cached_bytes,
        }
    }
}
