//! Concurrent serving front-end over an [`Artifact`]: thread-safe decode
//! requests, an LRU decoded-tensor cache, single-flight decode
//! coalescing, a corruption quarantine and a bounded admission gate — the
//! piece `owf serve-bench` drives and `owf quantise --from` feeds into the
//! KL evaluation harness.  The server is scheme-agnostic: `:rot` and
//! `grid` tensors (container v2) flow through the same
//! [`Artifact::decode_tensor_into`] path — inverse rotation and the grid
//! gather happen inside the artifact decode, so caching, coalescing and
//! quarantine need no per-scheme handling.
//!
//! Concurrency model: the artifact itself is immutable, so decodes run
//! in parallel outside the lock; one mutex guards the cache map, the
//! in-flight table, the quarantine map and the decode-permit count, held
//! only for map operations (never across a decode).
//!
//! **Single-flight**: concurrent cold misses on one tensor coalesce onto
//! a single decode.  The first requester registers an in-flight slot and
//! decodes; later requesters block on the slot's condvar and share the
//! resulting `Arc` (or the owner's error, verbatim).  N threads missing
//! on a cold tensor perform exactly one decode — enforced by
//! `rust/tests/server_props.rs` via `misses`/`decoded_bytes`.
//!
//! **Quarantine**: a decode that fails with [`ArtifactError::Corrupt`]
//! poisons the tensor; subsequent requests fail fast with
//! [`ArtifactError::Quarantined`] carrying the original cause, without
//! re-decoding damaged bytes.  Clean tensors — including still-cached
//! copies — keep serving (graceful degradation).  Transient I/O is the
//! artifact layer's job: it retries with backoff and never quarantines.
//!
//! **Admission gate**: with `with_max_decodes(n)`, at most `n` decodes
//! run concurrently; requests that would exceed the bound are rejected
//! with a typed [`ArtifactError::Overloaded`] instead of queueing without
//! bound (coalesced waiters don't hold permits — they consume no decode
//! resources).
//!
//! Cache invariants (also in `EXPERIMENTS.md` §Artifact / §Fault-model):
//! * resident bytes never exceed `cap_bytes` plus the most recently
//!   inserted tensor (which is always kept, even alone over cap);
//! * eviction is strict LRU by request stamp;
//! * `cap_bytes == 0` disables caching (every served buffer comes from a
//!   decode, though concurrent requests still coalesce onto one);
//! * on the fault-free path `hits + misses == requests`: coalesced
//!   waiters count as hits (they got a shared buffer without decoding),
//!   misses count decodes this server performed.  With faults the full
//!   partition is `requests == hits + misses + coalesced_errors +
//!   quarantine_hits + overloads + not_found` once all requests resolve.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::{AResult, Artifact, ArtifactError};

struct CacheEntry {
    data: Arc<Vec<f32>>,
    last_used: u64,
}

#[derive(Default)]
struct Cache {
    entries: HashMap<String, CacheEntry>,
    clock: u64,
    bytes: usize,
}

/// One in-flight decode: waiters block on the condvar until the owner
/// fills the result, then share it (data `Arc` or error, cloned verbatim).
struct Slot {
    result: Mutex<Option<AResult<Arc<Vec<f32>>>>>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            result: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    fn wait(&self) -> AResult<Arc<Vec<f32>>> {
        let mut r = self.result.lock().unwrap();
        while r.is_none() {
            r = self.cv.wait(r).unwrap();
        }
        r.as_ref().unwrap().clone()
    }

    fn fill(&self, outcome: AResult<Arc<Vec<f32>>>) {
        *self.result.lock().unwrap() = Some(outcome);
        self.cv.notify_all();
    }
}

#[derive(Default)]
struct ServerState {
    cache: Cache,
    inflight: HashMap<String, Arc<Slot>>,
    quarantine: HashMap<String, ArtifactError>,
    active_decodes: usize,
}

/// A point-in-time view of the server counters.
#[derive(Clone, Copy, Debug)]
pub struct ServerStats {
    pub requests: u64,
    /// Requests served without this thread decoding: cache hits plus
    /// coalesced waits that received the owner's buffer.
    pub hits: u64,
    /// Decodes this server performed (successful or not).
    pub misses: u64,
    pub evictions: u64,
    /// Bytes produced by successful decodes (4·elements each).
    pub decoded_bytes: u64,
    /// Requests that attached to another thread's in-flight decode.
    pub coalesced: u64,
    /// Coalesced waits that inherited the owner's error.
    pub coalesced_errors: u64,
    /// Own decodes that returned an error.
    pub decode_errors: u64,
    /// Requests rejected fast because the tensor was quarantined.
    pub quarantine_hits: u64,
    /// Requests rejected by the admission gate.
    pub overloads: u64,
    /// Requests for names not in the manifest.
    pub not_found: u64,
    /// Transient I/O retries performed by the artifact layer.
    pub io_retries: u64,
    /// Tensors currently poisoned in the quarantine map.
    pub quarantined: usize,
    pub cached_tensors: usize,
    pub cached_bytes: usize,
}

/// Thread-safe serving reader: LRU cache + single-flight + quarantine +
/// admission gate.
pub struct ArtifactServer {
    artifact: Artifact,
    cap_bytes: usize,
    /// Max concurrent decodes; 0 = unbounded.
    max_decodes: usize,
    state: Mutex<ServerState>,
    requests: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    decoded_bytes: AtomicU64,
    coalesced: AtomicU64,
    coalesced_errors: AtomicU64,
    decode_errors: AtomicU64,
    quarantine_hits: AtomicU64,
    overloads: AtomicU64,
    not_found: AtomicU64,
}

impl ArtifactServer {
    pub fn new(artifact: Artifact, cap_bytes: usize) -> ArtifactServer {
        ArtifactServer {
            artifact,
            cap_bytes,
            max_decodes: 0,
            state: Mutex::new(ServerState::default()),
            requests: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            decoded_bytes: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            coalesced_errors: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            quarantine_hits: AtomicU64::new(0),
            overloads: AtomicU64::new(0),
            not_found: AtomicU64::new(0),
        }
    }

    /// Bound concurrent decodes: the `n+1`-th simultaneous cold decode is
    /// rejected with a typed [`ArtifactError::Overloaded`].  `0` (the
    /// default) leaves admission unbounded.
    pub fn with_max_decodes(mut self, n: usize) -> ArtifactServer {
        self.max_decodes = n;
        self
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Serve one tensor.  Quarantined names fail fast with the recorded
    /// cause; a cache hit returns the shared buffer; a miss either
    /// attaches to an in-flight decode of the same tensor (sharing its
    /// outcome) or — admission gate permitting — decodes outside the
    /// lock, fills the cache and wakes every waiter.
    pub fn get(&self, name: &str) -> AResult<Arc<Vec<f32>>> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let Some(i) = self.artifact.position(name) else {
            self.not_found.fetch_add(1, Ordering::Relaxed);
            return Err(ArtifactError::NotFound {
                tensor: name.to_string(),
            });
        };
        let slot = {
            let mut st = self.state.lock().unwrap();
            if let Some(cause) = st.quarantine.get(name) {
                self.quarantine_hits.fetch_add(1, Ordering::Relaxed);
                return Err(ArtifactError::Quarantined {
                    tensor: name.to_string(),
                    cause: Box::new(cause.clone()),
                });
            }
            if self.cap_bytes > 0 {
                st.cache.clock += 1;
                let now = st.cache.clock;
                if let Some(e) = st.cache.entries.get_mut(name) {
                    e.last_used = now;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(e.data.clone());
                }
            }
            if let Some(existing) = st.inflight.get(name) {
                // coalesce: counted at attach (before the wait) so tests
                // can observe waiters deterministically
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                let slot = existing.clone();
                drop(st);
                return match slot.wait() {
                    Ok(data) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        Ok(data)
                    }
                    Err(e) => {
                        self.coalesced_errors
                            .fetch_add(1, Ordering::Relaxed);
                        Err(e)
                    }
                };
            }
            if self.max_decodes > 0
                && st.active_decodes >= self.max_decodes
            {
                self.overloads.fetch_add(1, Ordering::Relaxed);
                return Err(ArtifactError::Overloaded {
                    limit: self.max_decodes,
                });
            }
            st.active_decodes += 1;
            let slot = Arc::new(Slot::new());
            st.inflight.insert(name.to_string(), slot.clone());
            slot
        };

        // own decode, outside the lock
        self.misses.fetch_add(1, Ordering::Relaxed);
        let outcome = match self.artifact.decode_tensor(i) {
            Ok(data) => {
                let data = Arc::new(data);
                self.decoded_bytes
                    .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
                Ok(data)
            }
            Err(e) => {
                self.decode_errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        };
        {
            let mut st = self.state.lock().unwrap();
            st.active_decodes -= 1;
            st.inflight.remove(name);
            match &outcome {
                Ok(data) => {
                    if self.cap_bytes > 0 {
                        self.cache_insert(&mut st.cache, name, data.clone());
                    }
                }
                Err(e) => {
                    if e.is_corrupt() {
                        st.quarantine
                            .insert(name.to_string(), e.clone());
                    }
                }
            }
        }
        slot.fill(outcome.clone());
        outcome
    }

    /// Insert under the state lock, then strict-LRU evict down to cap.
    /// Single-flight guarantees no concurrent insert of the same name.
    fn cache_insert(
        &self,
        cache: &mut Cache,
        name: &str,
        data: Arc<Vec<f32>>,
    ) {
        cache.clock += 1;
        let now = cache.clock;
        cache.bytes += 4 * data.len();
        cache.entries.insert(
            name.to_string(),
            CacheEntry {
                data,
                last_used: now,
            },
        );
        // the entry just inserted is `now` and is never selected while
        // anything older remains
        while cache.bytes > self.cap_bytes && cache.entries.len() > 1 {
            let victim = cache
                .entries
                .iter()
                .filter(|(_, e)| e.last_used != now)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            if let Some(e) = cache.entries.remove(&victim) {
                cache.bytes -= 4 * e.data.len();
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Cache-bypassing decode into a caller-owned buffer (the zero-copy
    /// serving path).  Counted as a request + miss; respects the
    /// quarantine and the admission gate, and quarantines on corruption,
    /// exactly like [`ArtifactServer::get`] — but never coalesces (the
    /// caller owns the output buffer, so there is nothing to share).
    pub fn decode_into(&self, name: &str, out: &mut [f32]) -> AResult<()> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let Some(i) = self.artifact.position(name) else {
            self.not_found.fetch_add(1, Ordering::Relaxed);
            return Err(ArtifactError::NotFound {
                tensor: name.to_string(),
            });
        };
        {
            let mut st = self.state.lock().unwrap();
            if let Some(cause) = st.quarantine.get(name) {
                self.quarantine_hits.fetch_add(1, Ordering::Relaxed);
                return Err(ArtifactError::Quarantined {
                    tensor: name.to_string(),
                    cause: Box::new(cause.clone()),
                });
            }
            if self.max_decodes > 0
                && st.active_decodes >= self.max_decodes
            {
                self.overloads.fetch_add(1, Ordering::Relaxed);
                return Err(ArtifactError::Overloaded {
                    limit: self.max_decodes,
                });
            }
            st.active_decodes += 1;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let result = self.artifact.decode_tensor_into(i, out);
        let mut st = self.state.lock().unwrap();
        st.active_decodes -= 1;
        match &result {
            Ok(()) => {
                self.decoded_bytes
                    .fetch_add(4 * out.len() as u64, Ordering::Relaxed);
            }
            Err(e) => {
                self.decode_errors.fetch_add(1, Ordering::Relaxed);
                if e.is_corrupt() {
                    st.quarantine.insert(name.to_string(), e.clone());
                }
            }
        }
        result
    }

    /// Decode every tensor into a name → values map — the adapter that
    /// lets the LLM evaluation harness ([`crate::eval::llm::Env::evaluate`])
    /// score a packed artifact exactly like an in-memory quantisation.
    pub fn params(&self) -> AResult<HashMap<String, Vec<f32>>> {
        let mut out = HashMap::new();
        for (i, rec) in self.artifact.tensors.iter().enumerate() {
            out.insert(rec.name.clone(), self.artifact.decode_tensor(i)?);
        }
        Ok(out)
    }

    /// Drop every cached tensor (bench/ops tool: forces the next round of
    /// requests cold).  Quarantine, in-flight decodes and counters are
    /// untouched; the drops are not counted as evictions.
    pub fn clear_cache(&self) {
        let mut st = self.state.lock().unwrap();
        st.cache.entries.clear();
        st.cache.bytes = 0;
    }

    /// Lift a tensor's quarantine (ops tool — e.g. after `owf fsck`
    /// verified a repaired container).  Returns the recorded cause.
    pub fn clear_quarantine(&self, name: &str) -> Option<ArtifactError> {
        self.state.lock().unwrap().quarantine.remove(name)
    }

    /// Recompute cache occupancy from the entries themselves — test
    /// support for proving the incremental `cached_bytes` accounting
    /// exact under racing insert/evict.
    pub fn cache_audit(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        let bytes: usize = st
            .cache
            .entries
            .values()
            .map(|e| 4 * e.data.len())
            .sum();
        (st.cache.entries.len(), bytes)
    }

    pub fn stats(&self) -> ServerStats {
        let (cached_tensors, cached_bytes, quarantined) = {
            let st = self.state.lock().unwrap();
            (
                st.cache.entries.len(),
                st.cache.bytes,
                st.quarantine.len(),
            )
        };
        ServerStats {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            decoded_bytes: self.decoded_bytes.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            coalesced_errors: self.coalesced_errors.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
            quarantine_hits: self.quarantine_hits.load(Ordering::Relaxed),
            overloads: self.overloads.load(Ordering::Relaxed),
            not_found: self.not_found.load(Ordering::Relaxed),
            io_retries: self.artifact.io_retries(),
            quarantined,
            cached_tensors,
            cached_bytes,
        }
    }
}
