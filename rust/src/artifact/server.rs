//! Concurrent serving front-end over an [`Artifact`]: thread-safe decode
//! requests, an LRU decoded-tensor cache and per-request statistics — the
//! piece `owf serve-bench` drives and `owf quantise --from` feeds into the
//! KL evaluation harness.
//!
//! Concurrency model: the artifact itself is immutable, so decodes run
//! lock-free in parallel; only the cache map sits behind a mutex, held for
//! map operations (never across a decode).  Two threads missing on the
//! same tensor may both decode it — the second insert defers to the first,
//! so at most one copy is ever resident — a deliberate trade of duplicate
//! work for zero convoying on the decode path.
//!
//! Cache invariants (also in `EXPERIMENTS.md` §Artifact):
//! * resident bytes never exceed `cap_bytes` plus the most recently
//!   inserted tensor (which is always kept, even alone over cap);
//! * eviction is strict LRU by request stamp;
//! * `cap_bytes == 0` disables caching entirely (every get decodes);
//! * hits + misses == requests, and every miss adds exactly one decode's
//!   bytes to `decoded_bytes`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::Artifact;

struct CacheEntry {
    data: Arc<Vec<f32>>,
    last_used: u64,
}

#[derive(Default)]
struct Cache {
    entries: HashMap<String, CacheEntry>,
    clock: u64,
    bytes: usize,
}

/// A point-in-time view of the server counters.
#[derive(Clone, Copy, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Bytes produced by cache-miss decodes (4·elements each).
    pub decoded_bytes: u64,
    pub cached_tensors: usize,
    pub cached_bytes: usize,
}

/// Thread-safe serving reader with an LRU decoded-tensor cache.
pub struct ArtifactServer {
    artifact: Artifact,
    cap_bytes: usize,
    cache: Mutex<Cache>,
    requests: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    decoded_bytes: AtomicU64,
}

impl ArtifactServer {
    pub fn new(artifact: Artifact, cap_bytes: usize) -> ArtifactServer {
        ArtifactServer {
            artifact,
            cap_bytes,
            cache: Mutex::new(Cache::default()),
            requests: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            decoded_bytes: AtomicU64::new(0),
        }
    }

    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Serve one tensor: cache hit returns the shared buffer; a miss
    /// decodes outside the lock, then inserts (first inserter wins on a
    /// race) and evicts LRU entries down to the capacity.
    pub fn get(&self, name: &str) -> Result<Arc<Vec<f32>>> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let i = self
            .artifact
            .position(name)
            .with_context(|| format!("tensor {name:?} not in artifact"))?;
        if self.cap_bytes > 0 {
            let mut c = self.cache.lock().unwrap();
            c.clock += 1;
            let now = c.clock;
            if let Some(e) = c.entries.get_mut(name) {
                e.last_used = now;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(e.data.clone());
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let data = Arc::new(self.artifact.decode_tensor(i)?);
        self.decoded_bytes
            .fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        if self.cap_bytes == 0 {
            return Ok(data);
        }
        let mut c = self.cache.lock().unwrap();
        c.clock += 1;
        let now = c.clock;
        if let Some(e) = c.entries.get_mut(name) {
            // another thread inserted while we decoded: keep its copy so
            // only one buffer stays resident
            e.last_used = now;
            return Ok(e.data.clone());
        }
        c.bytes += 4 * data.len();
        c.entries.insert(
            name.to_string(),
            CacheEntry {
                data: data.clone(),
                last_used: now,
            },
        );
        // strict-LRU eviction; the entry just inserted is `now` and is
        // never selected while anything older remains
        while c.bytes > self.cap_bytes && c.entries.len() > 1 {
            let victim = c
                .entries
                .iter()
                .filter(|(_, e)| e.last_used != now)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            if let Some(e) = c.entries.remove(&victim) {
                c.bytes -= 4 * e.data.len();
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(data)
    }

    /// Cache-bypassing decode into a caller-owned buffer (the zero-copy
    /// serving path).  Counted as a request + miss.
    pub fn decode_into(&self, name: &str, out: &mut [f32]) -> Result<()> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let i = self
            .artifact
            .position(name)
            .with_context(|| format!("tensor {name:?} not in artifact"))?;
        self.artifact.decode_tensor_into(i, out)?;
        self.decoded_bytes
            .fetch_add(4 * out.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Decode every tensor into a name → values map — the adapter that
    /// lets the LLM evaluation harness ([`crate::eval::llm::Env::evaluate`])
    /// score a packed artifact exactly like an in-memory quantisation.
    pub fn params(&self) -> Result<HashMap<String, Vec<f32>>> {
        let mut out = HashMap::new();
        for (i, rec) in self.artifact.tensors.iter().enumerate() {
            out.insert(rec.name.clone(), self.artifact.decode_tensor(i)?);
        }
        Ok(out)
    }

    pub fn stats(&self) -> ServerStats {
        let (cached_tensors, cached_bytes) = {
            let c = self.cache.lock().unwrap();
            (c.entries.len(), c.bytes)
        };
        ServerStats {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            decoded_bytes: self.decoded_bytes.load(Ordering::Relaxed),
            cached_tensors,
            cached_bytes,
        }
    }
}
